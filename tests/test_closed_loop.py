"""Tests for the drift-aware closed-loop pipeline and the
benchmark-strategy suite (ISSUE 5 tentpole).

* the online per-round control plane (``solve_rounds``) agrees with the
  one-shot trajectory solve (the problem is separable per (i, k)) and
  actually warm-starts rounds 1..K-1;
* every strategy produces a valid per-round state the scan engine
  consumes, and the grid driver's comparison table has the paper's
  qualitative ordering (proposed beats uniform-at-P^max on energy);
* the Lyapunov scheduler's virtual queues satisfy their defining
  recursion and throttle over-budget devices;
* the greedy scheduler tracks the instantaneous channel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GreedyChannelScheduler,
    LyapunovScheduler,
    make_problem,
    make_scheduler,
    solve_joint_fused,
)
from repro.core.schedulers import _round_preserving_count
from repro.fl.closed_loop import (
    CLOSED_LOOP_STRATEGIES,
    ClosedLoopConfig,
    format_closed_loop_table,
    run_closed_loop_grid,
    solve_rounds,
    strategy_state,
)
from repro.fl.scan_engine import plan_trajectory
from repro.serve import FleetControlService, ServiceConfig

N, K = 16, 6


@pytest.fixture(scope="module")
def problem():
    return make_problem("drifting_metro", seed=0, n_devices=N, n_rounds=K,
                        tau_th=0.5)


@pytest.fixture(scope="module")
def control(problem):
    return solve_rounds(problem)


class TestSolveRounds:
    def test_agrees_with_one_shot_trajectory_solve(self, problem, control):
        """Separability: the stream of per-round online solves lands on
        the trajectory-wide solution (float32 solver tolerance)."""
        one = solve_joint_fused(problem)
        np.testing.assert_allclose(control.a, np.asarray(one.a), atol=1e-5)
        np.testing.assert_allclose(control.power, np.asarray(one.power),
                                   atol=1e-5)

    def test_warm_starts_after_round_zero(self, problem, control):
        assert control.a.shape == (N, K)
        assert control.warm_rounds == K - 1      # round 0 is cold
        assert control.service.stats.n_solved == K

    def test_rejects_static_problem(self):
        static = make_problem("paper_static", seed=0, n_devices=N)
        with pytest.raises(ValueError, match="fading"):
            solve_rounds(static)

    def test_solutions_feasible_per_round(self, problem, control):
        ok = problem.constraints_satisfied(jnp.asarray(control.a),
                                           jnp.asarray(control.power))
        assert bool(np.asarray(ok).all())


class TestStrategyStates:
    @pytest.mark.parametrize("name", CLOSED_LOOP_STRATEGIES)
    def test_state_valid_and_plannable(self, problem, control, name):
        cfg = ClosedLoopConfig(n_devices=N, n_rounds=K)
        sch, state = strategy_state(name, problem, control, cfg)
        a = np.asarray(state.a)
        assert ((a >= 0) & (a <= 1)).all()
        parts = [np.arange(4)] * N
        from repro.fl.engine import FLConfig
        plan = plan_trajectory(problem, sch, parts,
                               FLConfig(n_rounds=K, batch_per_client=2),
                               state=state)
        assert plan.probs.shape == (K, N)
        assert np.isfinite(np.asarray(plan.tx_time)).all()
        assert np.isfinite(np.asarray(plan.round_energy)).all()

    def test_unknown_strategy_raises(self, problem, control):
        with pytest.raises(KeyError, match="unknown closed-loop strategy"):
            strategy_state("nope", problem, control,
                           ClosedLoopConfig(n_devices=N, n_rounds=K))

    def test_deterministic_tracks_rounds(self, problem, control):
        """Per-round top-k: each round's count matches that round's
        expected count (not round 0's broadcast)."""
        cfg = ClosedLoopConfig(n_devices=N, n_rounds=K)
        _, state = strategy_state("deterministic", problem, control, cfg)
        a_bin = np.asarray(state.a)
        for k in range(K):
            expect = np.clip(round(float(control.a[:, k].sum())), 1, N)
            assert a_bin[:, k].sum() == expect


class TestGreedyChannel:
    def test_selects_best_channels_per_round(self, problem):
        sch = GreedyChannelScheduler(m=4)
        st = sch.precompute(problem)
        gain = np.asarray(problem.path_gain())
        a = np.asarray(st.a)
        for k in range(K):
            sel = a[:, k] > 0
            assert sel.sum() == 4
            assert gain[sel, k].min() >= gain[~sel, k].max()

    def test_m_clamped_to_fleet(self, problem):
        st = GreedyChannelScheduler(m=10 * N).precompute(problem)
        assert np.asarray(st.a).sum(axis=0).max() == N


class TestLyapunov:
    def test_queue_recursion(self, problem):
        sch = LyapunovScheduler(v=1e-4)
        st = sch.precompute(problem)
        q = np.asarray(sch.queue_trajectory(problem))
        a = np.asarray(st.a)
        power = np.asarray(st.power)
        e = np.asarray(problem.round_energy(jnp.asarray(power)))
        emax = np.asarray(problem.energy_budget_j)
        w = np.asarray(problem.weights)
        assert (q[0] == 0).all() and (q >= 0).all()
        for k in range(K):
            sel = sch.v * w > q[k] * e[:, k]
            np.testing.assert_array_equal(a[:, k] > 0, sel)
            np.testing.assert_allclose(
                q[k + 1],
                np.maximum(q[k] + np.where(sel, e[:, k], 0.0) - emax, 0.0),
                rtol=1e-6)

    def test_round0_selects_every_weighted_device(self, problem):
        st = LyapunovScheduler(v=1.0).precompute(problem)
        w = np.asarray(problem.weights)
        np.testing.assert_array_equal(np.asarray(st.a)[:, 0] > 0, w > 0)

    def test_throttles_overbudget_devices(self):
        """On an energy-starved fleet the queues must bite: later rounds
        select strictly fewer devices than round 0."""
        prob = make_problem("drifting_metro", seed=1, n_devices=N,
                            n_rounds=K, energy_budget_range=(1e-4, 1e-3))
        a = np.asarray(LyapunovScheduler(v=1e-4).precompute(prob).a)
        assert a[:, 1:].sum(axis=0).max() < a[:, 0].sum()

    def test_static_problem_schedule_length(self):
        prob = make_problem("paper_static", seed=0, n_devices=N)
        st = LyapunovScheduler(v=1e-4, n_rounds=7).precompute(prob)
        assert np.asarray(st.a).shape == (N, 7)


class TestRoundPreservingPerRound:
    def test_per_round_vs_broadcast(self, control):
        a = jnp.asarray(control.a)
        per = np.asarray(_round_preserving_count(a, per_round=True))
        broad = np.asarray(_round_preserving_count(a))
        # broadcast mode repeats round 0's selection; per-round mode
        # matches it at k=0 and may differ later
        np.testing.assert_array_equal(per[:, 0], broad[:, 0])
        assert (broad == broad[:, :1]).all()


class TestGrid:
    @pytest.fixture(scope="class")
    def grid(self):
        cfg = ClosedLoopConfig(n_devices=N, n_rounds=K, n_train=512,
                               n_test=128, eval_every=3)
        return run_closed_loop_grid(cfg)

    def test_all_strategies_reported(self, grid):
        assert set(grid["strategies"]) == set(CLOSED_LOOP_STRATEGIES)
        for row in grid["strategies"].values():
            assert row["total_energy_j"] > 0
            assert row["completion_time_s"] > 0
            assert 0.0 <= row["final_acc"] <= 1.0

    def test_proposed_beats_uniform_on_energy(self, grid):
        """The ISSUE 5 acceptance ordering: the proposed scheme beats the
        constraint-oblivious uniform-at-P^max baseline on energy (and the
        expected participation is count-matched by construction)."""
        prop = grid["strategies"]["probabilistic"]
        uni = grid["strategies"]["uniform"]
        assert prop["total_energy_j"] < uni["total_energy_j"]
        assert prop["expected_participants"] == pytest.approx(
            uni["expected_participants"], abs=1.0)

    def test_control_plane_warm(self, grid):
        ctrl = grid["control"]
        assert ctrl["warm_rounds"] == ctrl["n_rounds"] - 1
        assert ctrl["service"]["warm_fraction"] > 0.5

    def test_table_formats(self, grid):
        table = format_closed_loop_table(grid)
        for name in CLOSED_LOOP_STRATEGIES:
            assert name in table
        assert "energy(J)" in table and "warm-started" in table

    def test_config_service_settings_used(self):
        """ClosedLoopConfig.service configures the control plane when no
        explicit service is passed (regression: the field was dead)."""
        cfg = ClosedLoopConfig(n_devices=8, n_rounds=3, n_train=256,
                               n_test=64, eval_every=3,
                               service=ServiceConfig(
                                   method="alternating",
                                   power_solver="dinkelbach"))
        out = run_closed_loop_grid(cfg, strategies=("probabilistic",))
        # the fused/analytic default reports 0 inner iterations; the
        # configured Dinkelbach mode must report some
        assert out["control"]["inner_iters"] > 0
        # provenance: the result records the service config actually used
        assert out["config"]["service"]["power_solver"] == "dinkelbach"

    def test_explicit_service_recorded(self):
        """An explicit service argument overrides config.service in the
        result record too."""
        cfg = ClosedLoopConfig(n_devices=N, n_rounds=3, n_train=256,
                               n_test=64, eval_every=3)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        out = run_closed_loop_grid(cfg, strategies=("uniform",),
                                   service=svc)
        assert out["config"]["service"]["max_batch"] == 2

    def test_seed_average_runs(self):
        cfg = ClosedLoopConfig(n_devices=8, n_rounds=4, n_train=256,
                               n_test=64, eval_every=2, n_seeds=2)
        out = run_closed_loop_grid(cfg, strategies=("probabilistic",
                                                    "uniform"))
        assert set(out["strategies"]) == {"probabilistic", "uniform"}


class TestEngineIntegration:
    def test_scan_engine_accepts_new_schedulers(self, problem):
        """Greedy/Lyapunov ride the scan engine's fixed-mask mode and the
        reference engine's sample() contract."""
        from repro.fl.scan_engine import _scheduler_mode, MODE_FIXED

        for sch in (GreedyChannelScheduler(m=3), LyapunovScheduler(v=1e-4)):
            mode, m, unbiased = _scheduler_mode(sch)
            assert mode == MODE_FIXED
            st = sch.precompute(problem)
            draw = sch.sample(st, jax.random.PRNGKey(0), k=1)
            assert draw.mask.shape == (N,)
            assert draw.power.shape == (N,)

    def test_make_scheduler_registry(self):
        assert isinstance(make_scheduler("greedy_channel", m=3),
                          GreedyChannelScheduler)
        assert isinstance(make_scheduler("lyapunov", v=2.0),
                          LyapunovScheduler)

    def test_dinkelbach_service_collapses_inner_iters(self, problem):
        """The drift-tracking claim the bench gates: warm-started
        per-round solves use strictly fewer inner iterations than cold
        per-round solves."""
        def run(warm):
            svc = FleetControlService(ServiceConfig(
                method="alternating", power_solver="dinkelbach",
                warm_start=warm))
            return solve_rounds(problem, svc)

        warm, cold = run(True), run(False)
        assert warm.warm_rounds == K - 1 and cold.warm_rounds == 0
        assert warm.inner_iters < cold.inner_iters
        # identical solutions either way (warm start is iteration-only)
        np.testing.assert_allclose(warm.a, cold.a, atol=1e-6)
