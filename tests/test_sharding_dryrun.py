"""Distribution tests: sharding rules produce valid specs, and the
dry-run machinery lowers + compiles on a small host-device mesh.

The small-mesh dry-runs execute in a subprocess because the production
dryrun module pins XLA_FLAGS (512 host devices) at import, which must not
leak into this test process (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch, get_shape
from repro.models import transformer as T
from repro.sharding.rules import batch_specs, cache_specs, param_specs

REPO = Path(__file__).resolve().parents[1]


def _fake_mesh(data=4, model=4):
    """AbstractMesh carries names/sizes without needing real devices."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((data, model), ("data", "model"))
    except TypeError:
        # older jax (<= 0.4.x): AbstractMesh((("data", 4), ("model", 4)))
        return AbstractMesh((("data", data), ("model", model)))


class TestRules:
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_param_specs_match_structure(self, arch):
        cfg = ARCHS[arch]
        mesh = _fake_mesh()
        specs = param_specs(cfg, mesh)
        shapes = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                jax.random.PRNGKey(0))
        sl = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
        hl = jax.tree_util.tree_leaves(shapes)
        assert len(sl) == len(hl)
        for spec, shape in zip(sl, hl):
            assert isinstance(spec, P)
            assert len(spec) <= len(shape.shape)
            # every named axis divides its dimension
            for dim, ax in zip(shape.shape, tuple(spec)):
                if ax is None:
                    continue
                size = 4 if ax in ("data", "model") else 1
                axes = (ax,) if isinstance(ax, str) else ax
                total = 1
                for a in axes:
                    total *= {"data": 4, "model": 4}.get(a, 1)
                assert dim % total == 0, (arch, shape.shape, spec)

    def test_embed_vocab_sharded_when_divisible(self):
        cfg = get_arch("gemma3-1b")           # vocab 262144 divisible
        specs = param_specs(cfg, _fake_mesh())
        assert tuple(specs["embed"]) [0] == "model"

    def test_stacked_params_have_lead_none(self):
        cfg = get_arch("phi3-medium-14b")
        specs = param_specs(cfg, _fake_mesh())
        stack = specs["stack"]["l0"]["attn"]["wq"]
        assert tuple(stack)[0] is None

    @pytest.mark.parametrize("shape", ["train_4k", "decode_32k", "long_500k"])
    def test_cache_and_batch_specs_build(self, shape):
        cfg = get_arch("gemma2-27b")
        mesh = _fake_mesh()
        bs = batch_specs(cfg, get_shape(shape), mesh)
        assert "tokens" in bs
        if get_shape(shape).mode == "decode":
            cs = cache_specs(cfg, get_shape(shape), mesh)
            leaves = jax.tree_util.tree_leaves(
                cs, is_leaf=lambda x: isinstance(x, P))
            assert leaves

    def test_long500k_cache_sequence_sharded(self):
        """batch=1 cannot shard over data -> the sequence axis must."""
        cfg = get_arch("gemma2-27b")
        cs = cache_specs(cfg, get_shape("long_500k"), _fake_mesh())
        kv = cs["stack"]["l0"]["kv"]
        spec = tuple(kv.k)
        assert spec[0] is None          # stacked lead
        assert spec[1] is None          # batch=1
        assert spec[2] is not None      # sequence sharded over fsdp


@pytest.mark.slow
class TestSmallMeshDryrun:
    """End-to-end lower+compile on a 2x4 host mesh (subprocess)."""

    @pytest.mark.parametrize("arch,shape", [
        ("gemma3-1b", "train_4k"),
        ("mamba2-780m", "decode_32k"),
        ("deepseek-v2-lite-16b", "prefill_32k"),
    ])
    def test_dryrun_small(self, arch, shape, tmp_path):
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        env.pop("XLA_FLAGS", None)
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", arch, "--shape", shape, "--mesh-shape", "2,4",
             "--out", str(tmp_path)],
            env=env, capture_output=True, text=True, timeout=1200,
            cwd=str(REPO))
        assert res.returncode == 0, res.stdout + res.stderr
        arts = list(tmp_path.glob("*.json"))
        assert len(arts) == 1
        rec = json.loads(arts[0].read_text())
        assert rec["status"] == "ok"
        assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
