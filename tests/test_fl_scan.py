"""Scan-fused sweep engine vs the python-loop reference (Algorithm 3).

The scanned trajectory must reproduce ``run_fl`` — same participation
stream, same minibatch stream, same eq.-4 update, same time/energy
accounting — across aggregation modes, renormalisation settings,
strategies, and a fading scenario from the registry.

Parameter comparisons use short horizons: the two engines compile the
round step differently, so ulp-level rounding differences can be
amplified through ReLU sign flips over long runs; the accounting
(time/energy/participants) is independent of the model state and stays
exact at any horizon.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProbabilisticScheduler, make_scheduler, sample_problem
from repro.core.scenarios import make_batch, make_problem
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_mnist_like
from repro.fl.engine import FLConfig, run_fl
from repro.fl.scan_engine import (init_sweep_params, plan_trajectory,
                                  plans_from_batch, run_fl_scan, run_fl_sweep,
                                  stack_plans)

N_DEV = 16


@pytest.fixture(scope="module")
def setup():
    train, test = make_mnist_like(900, 200, seed=0)
    parts = dirichlet_partition(train, N_DEV, beta=0.3, seed=1)
    sizes = np.array([len(p) for p in parts])
    prob = sample_problem(0, N_DEV, tau_th=0.5, dirichlet_sizes=sizes)
    return prob, train, parts, test


def assert_matches(ref, scan, *, param_tol=1e-5):
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(scan.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=param_tol, atol=param_tol)
    hr, hs = ref.history, scan.history
    np.testing.assert_allclose(hr.sim_time, hs.sim_time, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(hr.energy, hs.energy, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(hr.participants, hs.participants)
    np.testing.assert_array_equal(hr.rounds, hs.rounds)
    np.testing.assert_array_equal(hr.eval_rounds, hs.eval_rounds)
    np.testing.assert_allclose(hr.eval_time, hs.eval_time, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(hr.eval_acc, hs.eval_acc, atol=0.02)


@pytest.mark.parametrize("aggregate", ["fused", "stacked"])
def test_scan_matches_loop_aggregation_modes(setup, aggregate):
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=15, eval_every=5, batch_per_client=4,
                   aggregate=aggregate, seed=11)
    sch = ProbabilisticScheduler()
    ref = run_fl(prob, sch, train, parts, test, cfg)
    scan = run_fl_scan(prob, sch, train, parts, test, cfg)
    assert_matches(ref, scan)


@pytest.mark.parametrize("renormalize", [True, False])
def test_scan_matches_loop_renormalize(setup, renormalize):
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=12, eval_every=6, batch_per_client=4,
                   renormalize=renormalize, seed=3)
    sch = ProbabilisticScheduler()
    assert_matches(run_fl(prob, sch, train, parts, test, cfg),
                   run_fl_scan(prob, sch, train, parts, test, cfg))


@pytest.mark.parametrize("strategy", ["deterministic", "uniform",
                                      "equally_weighted"])
def test_scan_matches_loop_strategies(setup, strategy):
    prob, train, parts, test = setup
    sch = (make_scheduler(strategy, m=5) if strategy == "uniform"
           else make_scheduler(strategy))
    cfg = FLConfig(n_rounds=12, eval_every=6, batch_per_client=4, seed=5)
    assert_matches(run_fl(prob, sch, train, parts, test, cfg),
                   run_fl_scan(prob, sch, train, parts, test, cfg))


def test_scan_matches_loop_fading_registry(setup):
    """Rayleigh fading from the scenario registry: per-round powers and
    tx-times ([N, K] tables) flow through both engines identically."""
    _, train, parts, test = setup
    sizes = np.array([len(p) for p in parts])
    prob = make_problem("rayleigh_fading", seed=2, n_devices=N_DEV,
                        n_rounds=12, dirichlet_sizes=sizes)
    cfg = FLConfig(n_rounds=12, eval_every=4, batch_per_client=4, seed=7)
    sch = ProbabilisticScheduler()
    ref = run_fl(prob, sch, train, parts, test, cfg)
    scan = run_fl_scan(prob, sch, train, parts, test, cfg)
    assert_matches(ref, scan)
    # fading must actually vary the per-round accounting
    rt = np.diff(ref.history.sim_time)
    active = rt[rt > 0]
    assert len(np.unique(np.round(active, 9))) > 1


def test_scan_kernel_aggregation(setup):
    """masked_aggregate Pallas kernel as the stacked reduction inside the
    scan agrees with the tensordot reference path."""
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=8, eval_every=8, batch_per_client=4,
                   aggregate="stacked", seed=9)
    sch = ProbabilisticScheduler()
    ref = run_fl_scan(prob, sch, train, parts, test, cfg)
    krn = run_fl_scan(prob, sch, train, parts, test, cfg, use_kernel=True,
                      kernel_interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(krn.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_sweep_grid_matches_individual_runs(setup):
    """A mixed (strategy x seed) sweep: every vmapped trajectory equals its
    individually-run loop counterpart."""
    prob, train, parts, test = setup
    grid = [(ProbabilisticScheduler(), 0), (ProbabilisticScheduler(), 1),
            (make_scheduler("deterministic"), 0),
            (make_scheduler("uniform", m=4), 2)]
    cfgs = [FLConfig(n_rounds=10, eval_every=5, batch_per_client=2, seed=s)
            for _, s in grid]
    plans = [plan_trajectory(prob, sch, parts, cfg)
             for (sch, _), cfg in zip(grid, cfgs)]
    sweep = run_fl_sweep(stack_plans(plans), train, test, cfgs[0],
                         init_sweep_params(cfgs))
    assert len(sweep.histories) == len(grid)
    for t, ((sch, _), cfg) in enumerate(zip(grid, cfgs)):
        ref = run_fl(prob, sch, train, parts, test, cfg)
        assert_matches(ref, sweep.result(t))


def test_plans_from_batch_registry(setup):
    """PR 1's batched solve (precompute_batch over a ProblemBatch) feeds
    the sweep: plans from one batched solve match per-instance planning
    to solver tolerance, and drive a runnable sweep."""
    _, train, parts, test = setup
    sizes = np.array([len(p) for p in parts])
    batch = make_batch("paper_static", n_instances=3, seed=0,
                       n_devices=N_DEV, dirichlet_sizes=sizes)
    sch = ProbabilisticScheduler()
    cfgs = [FLConfig(n_rounds=6, eval_every=6, batch_per_client=2, seed=s)
            for s in range(3)]
    batched = plans_from_batch(batch, sch, [parts] * 3, cfgs)
    for i, problem in enumerate(batch.unstack()):
        single = plan_trajectory(problem, sch, parts, cfgs[i], dataset_id=i)
        for field in ("probs", "tx_time", "round_energy", "agg_weights"):
            np.testing.assert_allclose(
                np.asarray(getattr(single, field)),
                np.asarray(getattr(batched[i], field)),
                rtol=2e-4, atol=1e-6, err_msg=f"instance {i} field {field}")
    sweep = run_fl_sweep(stack_plans(batched), [train] * 3, [test] * 3,
                         cfgs[0], init_sweep_params(cfgs))
    for h in sweep.histories:
        assert np.all(np.isfinite(h.sim_time))
        assert 0 <= h.participants.min() and h.participants.max() <= N_DEV


def test_sweep_rejects_mismatched_plans(setup):
    prob, train, parts, test = setup
    cfg_a = FLConfig(n_rounds=6, eval_every=6, batch_per_client=2, seed=0)
    cfg_b = FLConfig(n_rounds=8, eval_every=8, batch_per_client=2, seed=0)
    sch = ProbabilisticScheduler()
    pa = plan_trajectory(prob, sch, parts, cfg_a)
    pb = plan_trajectory(prob, sch, parts, cfg_b)
    with pytest.raises(ValueError):
        stack_plans([pa, pb])


def test_scan_supports_uplink_quantisation(setup):
    """Used to raise NotImplementedError; the scan engine now lowers
    config.uplink_bits to a uniform per-device bit table (the full
    run_fl parity check lives in tests/test_bit_allocation.py)."""
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=4, eval_every=4, batch_per_client=2,
                   aggregate="stacked", uplink_bits=8)
    res = run_fl_scan(prob, ProbabilisticScheduler(), train, parts, test,
                      cfg)
    for leaf in jax.tree_util.tree_leaves(res.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # fused aggregation has no per-client stack to quantise
    cfg_fused = FLConfig(n_rounds=2, aggregate="fused", uplink_bits=8)
    with pytest.raises(ValueError):
        run_fl_scan(prob, ProbabilisticScheduler(), train, parts, test,
                    cfg_fused)


# ------------------------------------------------- determinism (ISSUE 4)

def _scan_digest(prob, train, parts, test, cfg):
    import hashlib
    res = run_fl_scan(prob, ProbabilisticScheduler(), train, parts, test,
                      cfg)
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(res.params):
        h.update(np.asarray(leaf).tobytes())
    h.update(np.asarray(res.history.energy).tobytes())
    h.update(np.asarray(res.history.participants).tobytes())
    return h.hexdigest()


def test_scan_repeat_runs_bitwise_identical(setup):
    """Same seed, same process: the scanned trajectory is exactly
    reproducible (params, accounting, participation stream)."""
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=4, eval_every=4, batch_per_client=2, seed=3)
    d1 = _scan_digest(prob, train, parts, test, cfg)
    d2 = _scan_digest(prob, train, parts, test, cfg)
    assert d1 == d2


@pytest.mark.slow
def test_scan_cross_process_bitwise(setup, tmp_path):
    """A fresh interpreter with the same seed reproduces the scanned
    trajectory digest bit for bit (same XLA, same machine)."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=4, eval_every=4, batch_per_client=2, seed=3)
    parent = _scan_digest(prob, train, parts, test, cfg)
    repo = Path(__file__).resolve().parents[1]
    script = textwrap.dedent("""
        import hashlib
        import jax, numpy as np
        from repro.core import ProbabilisticScheduler, sample_problem
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_mnist_like
        from repro.fl.engine import FLConfig
        from repro.fl.scan_engine import run_fl_scan
        train, test = make_mnist_like(900, 200, seed=0)
        parts = dirichlet_partition(train, 16, beta=0.3, seed=1)
        sizes = np.array([len(p) for p in parts])
        prob = sample_problem(0, 16, tau_th=0.5, dirichlet_sizes=sizes)
        cfg = FLConfig(n_rounds=4, eval_every=4, batch_per_client=2, seed=3)
        res = run_fl_scan(prob, ProbabilisticScheduler(), train, parts,
                          test, cfg)
        h = hashlib.sha256()
        for leaf in jax.tree_util.tree_leaves(res.params):
            h.update(np.asarray(leaf).tobytes())
        h.update(np.asarray(res.history.energy).tobytes())
        h.update(np.asarray(res.history.participants).tobytes())
        print(h.hexdigest())
    """)
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=str(repo))
    assert res.returncode == 0, res.stdout + res.stderr
    assert res.stdout.strip() == parent
