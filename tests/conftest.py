"""Shared pytest configuration: the ``slow`` marker and its gate.

``slow`` marks the mega-fleet and subprocess tests (fresh-interpreter
sharding / determinism checks each pay a full jax import + compile).
They are *skipped by default* so the tier-1 loop

    PYTHONPATH=src python -m pytest -x -q

stays snappy; CI runs them in a dedicated job with ``--runslow`` (see
.github/workflows/ci.yml), so everything still runs on every PR.

    python -m pytest -q --runslow              # everything
    python -m pytest -q --runslow -m slow      # only the slow tier
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (mega-fleet scale, subprocess "
             "sharding/determinism) instead of skipping them")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: mega-fleet / subprocess tests, skipped unless --runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow: needs --runslow (CI slow job)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
