"""Per-architecture smoke tests (deliverable f): reduced variants of each
assigned architecture run a real forward/train step on CPU, asserting
output shapes and the absence of NaNs; decode consistency checks that
prefill-then-decode matches the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import InputShape
from repro.models import transformer as T
from repro.models.zoo import lm_loss, make_batch
from repro.optim.optimizers import adamw, apply_updates

SMOKE = InputShape("smoke", 64, 2, "train")
ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def _setup(name, rng):
    cfg = ARCHS[name].reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE, rng)
    return cfg, params, batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name, rng):
    cfg, params, batch = _setup(name, rng)
    logits, aux = T.forward(cfg, params, batch, q_chunk=32)
    b = SMOKE.global_batch
    s = SMOKE.seq_len
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.all(jnp.isfinite(aux)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_updates_and_finite(name, rng):
    cfg, params, batch = _setup(name, rng)
    opt = adamw(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, s):
        (loss, _), g = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, q_chunk=32), has_aux=True)(p)
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s, loss

    p1, opt_state, loss1 = step(params, opt_state)
    p2, _, loss2 = step(p1, opt_state)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    # params actually moved
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p1)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0
    for leaf in jax.tree_util.tree_leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(name, rng):
    """Teacher-forced decode over a short sequence reproduces the full
    forward logits (validates KV caches, ring buffers, SSM recurrence and
    the SSD chunked scan against each other)."""
    cfg = ARCHS[name].reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 32
    shape = InputShape("tiny", s, b, "train")
    batch = make_batch(cfg, shape, rng, with_weights=False)
    logits_full, _ = T.forward(cfg, params, batch, q_chunk=1024)

    cache = T.init_cache(cfg, b, cache_len=s, dtype=jnp.float32)
    # vision prefix tokens are part of forward-only context; decode loop
    # replays the text tokens one by one.
    offset = cfg.frontend.n_prefix if (cfg.frontend and cfg.frontend.kind == "vision") else 0
    if offset:
        pytest.skip("decode parity with vision prefix covered in VLM test")
    if cfg.enc_layers:
        cache = T.prefill_encoder(cfg, params, cache, batch)
    step = jax.jit(lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))
    outs = []
    for i in range(s):
        tok = batch["tokens"][:, i:i + 1]
        logits, cache = step(params, cache, tok, jnp.int32(i))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=0.05, atol=0.05)


@pytest.mark.parametrize("name", ["gemma3-1b", "h2o-danube-3-4b"])
def test_sliding_window_cache_smaller_than_context(name, rng):
    """Ring-buffer caches stay window-sized: decoding past the window works
    and matches a full forward on the last positions."""
    cfg = ARCHS[name].reduced()
    w = cfg.attn.window
    assert w is not None and w <= 64
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    b, s = 1, w * 2
    shape = InputShape("tiny", s, b, "train")
    batch = make_batch(cfg, shape, rng, with_weights=False)
    logits_full, _ = T.forward(cfg, params, batch, q_chunk=1024)
    cache = T.init_cache(cfg, b, cache_len=s, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))
    for i in range(s):
        logits, cache = step(params, cache, batch["tokens"][:, i:i + 1],
                             jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=0.05, atol=0.05)


def test_moe_aux_losses_populated(rng):
    cfg = ARCHS["deepseek-v2-lite-16b"].reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE, rng)
    _, aux = T.forward(cfg, params, batch, q_chunk=32)
    assert float(aux[0]) > 0.0          # load balance ~ E[f*P] * E >= 1
    assert float(aux[1]) > 0.0          # z-loss


def test_vlm_prefix_changes_logits(rng):
    cfg = ARCHS["internvl2-2b"].reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE, rng, with_weights=False)
    l1, _ = T.forward(cfg, params, batch, q_chunk=32)
    batch2 = dict(batch, vision=batch["vision"] + 1.0)
    l2, _ = T.forward(cfg, params, batch2, q_chunk=32)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3


def test_whisper_encoder_conditions_decoder(rng):
    cfg = ARCHS["whisper-large-v3"].reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE, rng, with_weights=False)
    l1, _ = T.forward(cfg, params, batch, q_chunk=32)
    batch2 = dict(batch, audio=batch["audio"] * 0.0)
    l2, _ = T.forward(cfg, params, batch2, q_chunk=32)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3
