"""Beyond-paper uplink quantization: statistical correctness + FL
integration (EXPERIMENTS.md §Perf iteration 3 / compression study)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.engine import FLConfig, quantize_stochastic, run_fl


class TestQuantizer:
    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_unbiased(self, bits):
        g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                        jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(1), 400)
        qs = jax.vmap(lambda k: quantize_stochastic(g, k, bits))(keys)
        bias = np.asarray(jnp.abs(qs.mean(0) - g))
        scale = float(jnp.max(jnp.abs(g))) / (2 ** (bits - 1) - 1)
        assert bias.max() < 4 * scale / np.sqrt(400) + 1e-6

    def test_error_bounded_by_one_level(self):
        g = jnp.asarray(np.random.default_rng(1).normal(size=(512,)),
                        jnp.float32)
        q = quantize_stochastic(g, jax.random.PRNGKey(0), 8)
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert float(jnp.max(jnp.abs(q - g))) <= scale + 1e-7

    def test_fewer_bits_more_error(self):
        g = jnp.asarray(np.random.default_rng(2).normal(size=(2048,)),
                        jnp.float32)
        errs = {b: float(jnp.mean(jnp.square(
            quantize_stochastic(g, jax.random.PRNGKey(3), b) - g)))
            for b in (4, 8, 16)}
        assert errs[4] > errs[8] > errs[16]


class TestFLIntegration:
    def test_fused_mode_rejects_quantization(self):
        from repro.core import ProbabilisticScheduler, sample_problem
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_mnist_like
        train, test = make_mnist_like(300, 100, seed=0)
        parts = dirichlet_partition(train, 5, 0.5, seed=0)
        prob = sample_problem(0, 5, dirichlet_sizes=np.array(
            [len(p) for p in parts]))
        cfg = FLConfig(n_rounds=1, aggregate="fused", uplink_bits=8)
        with pytest.raises(ValueError):
            run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)

    def test_quantized_training_stays_finite_and_learns(self):
        from repro.core import ProbabilisticScheduler, sample_problem
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_mnist_like
        train, test = make_mnist_like(1200, 300, seed=0)
        parts = dirichlet_partition(train, 10, 0.5, seed=0)
        prob = sample_problem(0, 10, tau_th=0.5,
                              dirichlet_sizes=np.array([len(p) for p in parts]))
        cfg = FLConfig(n_rounds=60, eval_every=30, batch_per_client=8,
                       lr=0.1, aggregate="stacked", uplink_bits=8, seed=1)
        res = run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)
        for leaf in jax.tree_util.tree_leaves(res.params):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        assert res.history.eval_acc[-1] > 0.2
