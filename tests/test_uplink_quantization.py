"""Beyond-paper uplink quantization: statistical correctness + FL
integration (EXPERIMENTS.md §Perf iteration 3 / compression study)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.engine import (FLConfig, quantize_levels, quantize_stochastic,
                             run_fl)


class TestQuantizer:
    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_unbiased(self, bits):
        g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                        jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(1), 400)
        qs = jax.vmap(lambda k: quantize_stochastic(g, k, bits))(keys)
        bias = np.asarray(jnp.abs(qs.mean(0) - g))
        scale = float(jnp.max(jnp.abs(g))) / (2 ** (bits - 1) - 1)
        assert bias.max() < 4 * scale / np.sqrt(400) + 1e-6

    def test_error_bounded_by_one_level(self):
        g = jnp.asarray(np.random.default_rng(1).normal(size=(512,)),
                        jnp.float32)
        q = quantize_stochastic(g, jax.random.PRNGKey(0), 8)
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert float(jnp.max(jnp.abs(q - g))) <= scale + 1e-7

    def test_fewer_bits_more_error(self):
        g = jnp.asarray(np.random.default_rng(2).normal(size=(2048,)),
                        jnp.float32)
        errs = {b: float(jnp.mean(jnp.square(
            quantize_stochastic(g, jax.random.PRNGKey(3), b) - g)))
            for b in (4, 8, 16)}
        assert errs[4] > errs[8] > errs[16]

    @pytest.mark.parametrize("bits", [1, 2])
    def test_low_bit_finite_and_clipped(self, bits):
        """Regression: bits=1 used to make levels = 2^0 - 1 = 0, so
        scale = max|g| / 0 = inf and the output was NaN."""
        g = jnp.asarray(np.random.default_rng(3).normal(size=(512,)),
                        jnp.float32)
        q = quantize_stochastic(g, jax.random.PRNGKey(0), bits)
        assert bool(jnp.all(jnp.isfinite(q)))
        levels = float(quantize_levels(bits))
        scale = float(jnp.max(jnp.abs(g))) / levels
        # symmetric range clip and at most 2*levels + 1 distinct values
        assert float(jnp.max(jnp.abs(q))) <= levels * scale + 1e-6
        assert len(np.unique(np.asarray(q))) <= 2 * int(levels) + 1

    def test_bits1_is_ternary_sign_quantizer(self):
        g = jnp.asarray([-3.0, -0.01, 0.0, 0.01, 3.0], jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(7), 500)
        qs = np.asarray(jax.vmap(
            lambda k: quantize_stochastic(g, k, 1))(keys))
        assert set(np.unique(qs)) <= {-3.0, 0.0, 3.0}
        # extremes are deterministic; near-zero entries stay unbiased
        assert (qs[:, 0] == -3.0).all() and (qs[:, 4] == 3.0).all()
        np.testing.assert_allclose(qs.mean(0), np.asarray(g), atol=0.15)

    def test_bits32_is_near_lossless(self):
        g = jnp.asarray(np.random.default_rng(5).normal(size=(512,)),
                        jnp.float32)
        q = quantize_stochastic(g, jax.random.PRNGKey(0), 32)
        # one level at 2^31 - 1 steps: relative error below f32 epsilon
        np.testing.assert_allclose(np.asarray(q), np.asarray(g),
                                   rtol=1e-6, atol=1e-6)

    def test_rejects_bits_below_one(self):
        g = jnp.zeros((4,))
        with pytest.raises(ValueError, match="bits >= 1"):
            quantize_stochastic(g, jax.random.PRNGKey(0), 0)

    def test_traced_bits_matches_static(self):
        """Array-valued bits (the scan engine's per-device tables) take
        the jnp branch of quantize_levels; same result as python ints."""
        g = jnp.asarray(np.random.default_rng(6).normal(size=(64,)),
                        jnp.float32)
        key = jax.random.PRNGKey(2)
        for b in (1, 4, 8):
            np.testing.assert_array_equal(
                np.asarray(quantize_stochastic(g, key, b)),
                np.asarray(quantize_stochastic(g, key,
                                               jnp.float32(b))))

    def test_property_unbiased_and_clipped(self):
        """Hypothesis property when available (the CI image may not ship
        it): for any gradient and bits, E[q] ~ g and |q| <= max|g|+level."""
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hyp.given(st.lists(st.floats(-100, 100, allow_nan=False,
                                      width=32),
                            min_size=2, max_size=32),
                   st.integers(min_value=1, max_value=16),
                   st.integers(min_value=0, max_value=2**31 - 1))
        @hyp.settings(max_examples=50, deadline=None)
        def prop(vals, bits, seed):
            g = jnp.asarray(vals, jnp.float32)
            q = quantize_stochastic(g, jax.random.PRNGKey(seed), bits)
            assert bool(jnp.all(jnp.isfinite(q)))
            gmax = float(jnp.max(jnp.abs(g)))
            assert float(jnp.max(jnp.abs(q))) <= gmax + 1e-6 \
                + gmax / float(quantize_levels(bits))

        prop()


class TestFLIntegration:
    def test_fused_mode_rejects_quantization(self):
        from repro.core import ProbabilisticScheduler, sample_problem
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_mnist_like
        train, test = make_mnist_like(300, 100, seed=0)
        parts = dirichlet_partition(train, 5, 0.5, seed=0)
        prob = sample_problem(0, 5, dirichlet_sizes=np.array(
            [len(p) for p in parts]))
        cfg = FLConfig(n_rounds=1, aggregate="fused", uplink_bits=8)
        with pytest.raises(ValueError):
            run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)

    def test_quantized_training_stays_finite_and_learns(self):
        from repro.core import ProbabilisticScheduler, sample_problem
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_mnist_like
        train, test = make_mnist_like(1200, 300, seed=0)
        parts = dirichlet_partition(train, 10, 0.5, seed=0)
        prob = sample_problem(0, 10, tau_th=0.5,
                              dirichlet_sizes=np.array([len(p) for p in parts]))
        cfg = FLConfig(n_rounds=60, eval_every=30, batch_per_client=8,
                       lr=0.1, aggregate="stacked", uplink_bits=8, seed=1)
        res = run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)
        for leaf in jax.tree_util.tree_leaves(res.params):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        assert res.history.eval_acc[-1] > 0.2
