"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
against the pure-jnp oracles in each kernel's ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sample_problem, solve_joint_optimal


# ------------------------------------------------------------- selection

class TestSelectionSolveKernel:
    @pytest.mark.parametrize("m", [256, 1024])
    def test_matches_ref(self, m):
        from repro.kernels.selection_solve.kernel import selection_solve_tiled
        from repro.kernels.selection_solve.ref import selection_solve_ref
        rng = np.random.default_rng(m)
        pg = jnp.asarray(rng.uniform(1e4, 1e8, (m, 128)), jnp.float32)
        bw = jnp.asarray(rng.uniform(5e4, 5e6, (m, 128)), jnp.float32)
        emax = jnp.asarray(np.exp(rng.uniform(-7, 4, (m, 128))), jnp.float32)
        ec = jnp.asarray(np.exp(rng.uniform(-8, -2, (m, 128))), jnp.float32)
        kw = dict(s_bits=6.4e6, tau=0.08, p_max=1.0)
        a_k, p_k = selection_solve_tiled(pg, bw, emax, ec, interpret=True, **kw)
        a_r, p_r = selection_solve_ref(pg, bw, emax, ec, **kw)
        np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r),
                                   rtol=1e-5, atol=1e-8)

    def test_ops_wrapper_matches_core_solver(self):
        from repro.kernels.selection_solve.ops import solve_joint_kernel
        prob = sample_problem(5, 100)
        k = solve_joint_kernel(prob, interpret=True)
        o = solve_joint_optimal(prob)
        np.testing.assert_allclose(np.asarray(k.a), np.asarray(o.a),
                                   rtol=1e-4, atol=1e-6)
        assert bool(prob.constraints_satisfied(k.a, k.power).all())


class TestFusedSolveKernel:
    @pytest.mark.parametrize("m", [256, 1024])
    def test_matches_ref(self, m):
        from repro.kernels.selection_solve.kernel import fused_solve_tiled
        from repro.kernels.selection_solve.ref import fused_solve_ref
        rng = np.random.default_rng(m + 1)
        pg = jnp.asarray(rng.uniform(1e4, 1e8, (m, 128)), jnp.float32)
        bw = jnp.asarray(rng.uniform(5e4, 5e6, (m, 128)), jnp.float32)
        emax = jnp.asarray(np.exp(rng.uniform(-7, 4, (m, 128))), jnp.float32)
        ec = jnp.asarray(np.exp(rng.uniform(-8, -2, (m, 128))), jnp.float32)
        kw = dict(s_bits=6.4e6, tau=0.08, p_max=1.0)
        a_k, p_k = fused_solve_tiled(pg, bw, emax, ec, interpret=True, **kw)
        a_r, p_r = fused_solve_ref(pg, bw, emax, ec, **kw)
        np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r),
                                   rtol=1e-5, atol=1e-8)

    def test_ops_wrapper_matches_solve_joint(self):
        from repro.core import solve_joint
        from repro.kernels.selection_solve.ops import solve_joint_fused_kernel
        prob = sample_problem(6, 100)
        k = solve_joint_fused_kernel(prob, interpret=True)
        ref = solve_joint(prob)
        np.testing.assert_allclose(np.asarray(k.a), np.asarray(ref.a),
                                   atol=1e-5, rtol=0)
        np.testing.assert_allclose(np.asarray(k.power),
                                   np.asarray(ref.power),
                                   atol=1e-5, rtol=1e-5)
        assert bool(prob.constraints_satisfied(k.a, k.power,
                                               rtol=1e-3).all())

    def test_ops_wrapper_fading(self):
        from repro.core import solve_joint
        from repro.kernels.selection_solve.ops import solve_joint_fused_kernel
        prob = sample_problem(2, 40, with_fading=True, n_rounds=5)
        k = solve_joint_fused_kernel(prob, interpret=True)
        ref = solve_joint(prob)
        assert k.a.shape == (40, 5)
        np.testing.assert_allclose(np.asarray(k.a), np.asarray(ref.a),
                                   atol=1e-5, rtol=0)


# -------------------------------------------------------------- aggregate

class TestMaskedAggregateKernel:
    @pytest.mark.parametrize("n,d", [(64, 512), (128, 2048), (192, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, n, d, dtype):
        from repro.kernels.masked_aggregate.kernel import masked_aggregate_tiled
        from repro.kernels.masked_aggregate.ref import masked_aggregate_ref
        rng = np.random.default_rng(n + d)
        g = jnp.asarray(rng.normal(size=(n, d)), dtype)
        coef = jnp.asarray(rng.uniform(0, 1, n) * (rng.random(n) > 0.5),
                           jnp.float32)
        out_k = masked_aggregate_tiled(g, coef, interpret=True)
        out_r = masked_aggregate_ref(g, coef)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2   # summation order
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=tol, atol=tol)

    def test_pytree_wrapper_unpadded_shapes(self):
        from repro.kernels.masked_aggregate.ops import masked_aggregate_pytree
        from repro.kernels.masked_aggregate.ref import masked_aggregate_ref
        rng = np.random.default_rng(0)
        tree = {"w": jnp.asarray(rng.normal(size=(10, 33, 7)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(10, 5)), jnp.float32)}
        coef = jnp.asarray(rng.uniform(0, 1, 10), jnp.float32)
        out = masked_aggregate_pytree(tree, coef, interpret=True)
        for kname, g in tree.items():
            ref = masked_aggregate_ref(g.reshape(10, -1), coef).reshape(g.shape[1:])
            np.testing.assert_allclose(np.asarray(out[kname]), ref,
                                       rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ swa decode

class TestSWADecodeKernel:
    @pytest.mark.parametrize("w,hkv,g,dh,window", [
        (512, 4, 4, 64, None),
        (1024, 2, 8, 128, 300),
        (512, 1, 4, 128, 128),
        (256, 8, 1, 64, None),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, w, hkv, g, dh, window, dtype):
        from repro.kernels.swa_decode.kernel import swa_decode_tiled
        from repro.kernels.swa_decode.ref import swa_decode_ref
        rng = np.random.default_rng(w + hkv)
        b = 2
        q = jnp.asarray(rng.normal(size=(b, hkv, g, dh)), dtype) * dh ** -0.5
        k = jnp.asarray(rng.normal(size=(b, w, hkv, dh)), dtype)
        v = jnp.asarray(rng.normal(size=(b, w, hkv, dh)), dtype)
        qpos = jnp.int32(w + 5)
        pos = jnp.where(jnp.arange(w) < w - 3, jnp.arange(w), -1).astype(jnp.int32)
        blk = 128 if w % 128 == 0 else w
        out_k = swa_decode_tiled(q, k, v, pos, qpos, window=window,
                                 kv_blk=min(blk, w), interpret=True)
        out_r = swa_decode_ref(q, k, v, pos, qpos, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=tol, atol=tol)

    def test_ops_matches_layer_attention(self):
        """decode_attention == layers._attend_block on a ring cache."""
        from repro.kernels.swa_decode.ops import decode_attention
        from repro.models import layers as L
        rng = np.random.default_rng(3)
        b, h, hkv, dh, w = 2, 8, 2, 64, 256
        spec = L.AttnLayerSpec(n_heads=h, n_kv_heads=hkv, d_head=dh,
                               theta=1e4, window=100, softcap=None,
                               qk_norm=False, use_rope=False)
        q = jnp.asarray(rng.normal(size=(b, 1, h, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, w, hkv, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, w, hkv, dh)), jnp.float32)
        pos_buf = jnp.arange(w, dtype=jnp.int32)
        qpos = jnp.int32(w - 1)
        ref = L._attend_block(q, L._repeat_kv(k, h), L._repeat_kv(v, h),
                              qpos[None], pos_buf, spec)
        out = decode_attention(q, k, v, pos_buf, qpos, window=100,
                               n_heads=h, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


# --------------------------------------------------------------- ssd scan

class TestSSDScanKernel:
    @pytest.mark.parametrize("s,p,n,chunk", [
        (256, 64, 32, 64),
        (512, 32, 64, 128),
        (128, 64, 16, 128),   # single chunk
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_sequential_ref(self, s, p, n, chunk, dtype):
        from repro.kernels.ssd_scan.kernel import ssd_scan_tiled
        from repro.kernels.ssd_scan.ref import ssd_scan_ref
        rng = np.random.default_rng(s + p)
        bh = 3
        x = jnp.asarray(rng.normal(size=(bh, s, p)), dtype)
        dt = jnp.asarray(rng.uniform(0.001, 0.1, (bh, s)), jnp.float32)
        a = jnp.asarray(-rng.uniform(0.5, 4.0, bh), jnp.float32)
        b_mat = jnp.asarray(rng.normal(size=(bh, s, n)) * 0.3, dtype)
        c_mat = jnp.asarray(rng.normal(size=(bh, s, n)) * 0.3, dtype)
        d_skip = jnp.asarray(rng.normal(size=bh), jnp.float32)
        y_k = ssd_scan_tiled(x, dt, a, b_mat, c_mat, d_skip, chunk=chunk,
                             interpret=True)
        y_r = ssd_scan_ref(x, dt, a, b_mat, c_mat, d_skip)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_r, np.float32),
                                   rtol=tol, atol=tol)

    def test_ops_matches_model_ssd(self):
        """Kernel wrapper == models.mamba2.ssd_chunked on mamba-shaped ops."""
        from repro.kernels.ssd_scan.ops import ssd_apply
        from repro.models.mamba2 import ssd_chunked
        rng = np.random.default_rng(1)
        b, s, h, p, n = 2, 256, 4, 32, 16
        x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
        a = jnp.asarray(-rng.uniform(0.5, 4, h), jnp.float32)
        b_mat = jnp.asarray(rng.normal(size=(b, s, n)) * 0.3, jnp.float32)
        c_mat = jnp.asarray(rng.normal(size=(b, s, n)) * 0.3, jnp.float32)
        d_skip = jnp.asarray(rng.normal(size=h), jnp.float32)
        y_model, _ = ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, chunk=64)
        y_kernel = ssd_apply(x, dt, a, b_mat, c_mat, d_skip, chunk=64,
                             interpret=True)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                                   rtol=2e-4, atol=2e-4)
