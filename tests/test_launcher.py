"""End-to-end launcher test: repro.launch.train on a reduced arch."""
import pytest

from repro.launch.train import main as train_main


@pytest.mark.slow
def test_train_driver_reduced(tmp_path):
    hist = train_main([
        "--arch", "demo-100m", "--reduced", "--steps", "8",
        "--batch", "4", "--seq", "64", "--n-clients", "8",
        "--log-every", "4", "--ckpt-dir", str(tmp_path / "ckpt"),
        "--ckpt-every", "4",
        "--out", str(tmp_path / "hist.json")])
    assert len(hist) >= 2
    assert all(h["loss"] == h["loss"] for h in hist)   # no NaN
    assert (tmp_path / "hist.json").exists()
    ckpts = list((tmp_path / "ckpt").glob("ckpt_*.npz"))
    assert ckpts


@pytest.mark.slow
def test_train_driver_resume(tmp_path):
    train_main(["--arch", "demo-100m", "--reduced", "--steps", "4",
                "--batch", "2", "--seq", "32", "--n-clients", "4",
                "--ckpt-dir", str(tmp_path / "c"), "--ckpt-every", "100"])
    hist = train_main(["--arch", "demo-100m", "--reduced", "--steps", "6",
                       "--batch", "2", "--seq", "32", "--n-clients", "4",
                       "--ckpt-dir", str(tmp_path / "c"), "--resume",
                       "--log-every", "1"])
    assert hist[-1]["step"] == 6
