"""Fused single-level solver: agreement with Algorithm 2 (``solve_joint``)
across scenarios, fading, ragged batches and padded slots; chunked ==
unchunked; the chunked/sharded mega-fleet path under 2 virtual devices;
and the trace/while-loop iteration-count parity.  The randomised
hypothesis property suite lives in ``test_fused_properties.py`` (kept
separate so this file runs even without hypothesis installed)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import (
    ProbabilisticScheduler,
    make_batch,
    make_problem,
    sample_problem,
    solve_joint,
    solve_joint_batch,
    solve_joint_fused,
    solve_joint_trace,
    stack_problems,
)

REPO = Path(__file__).resolve().parents[1]
TOL = 1e-5


def assert_agrees(fused, ref, *, tol=TOL):
    np.testing.assert_allclose(np.asarray(fused.a), np.asarray(ref.a),
                               atol=tol, rtol=0)
    np.testing.assert_allclose(np.asarray(fused.power), np.asarray(ref.power),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(float(fused.objective), float(ref.objective),
                               atol=tol, rtol=0)


class TestFusedAgreement:
    @pytest.mark.parametrize("name", ["paper_static", "hetero_bandwidth",
                                      "sparse_energy_starved"])
    def test_matches_solve_joint(self, name):
        prob = make_problem(name, seed=0, n_devices=48)
        assert_agrees(solve_joint_fused(prob), solve_joint(prob))

    def test_fading(self):
        prob = sample_problem(3, 24, with_fading=True, n_rounds=6)
        fused = solve_joint_fused(prob)
        assert fused.a.shape == (24, 6)
        assert_agrees(fused, solve_joint(prob))

    def test_dinkelbach_reference_mode(self):
        prob = sample_problem(7, 32)
        assert_agrees(solve_joint_fused(prob, power_solver="dinkelbach"),
                      solve_joint(prob, power_solver="dinkelbach"))

    def test_typo_mode_collapses(self):
        """The verbatim eq.-13 typo contracts a by 1/S per sweep, so the
        iteration's only fixed point is the collapse; the fused solver's
        per-element stopping rule reaches it (solve_joint's *global*
        objective rule stops a couple of sweeps earlier — the two agree
        only on the corrected formula, where the interior fixed point is
        reached in one step)."""
        prob = sample_problem(1, 32)
        fixed = solve_joint_fused(prob)
        typo = solve_joint_fused(prob, faithful_eq13_typo=True)
        assert float(typo.a.sum()) < float(fixed.a.sum()) * 1e-2

    def test_feasible_and_converged(self):
        prob = sample_problem(11, 64)
        sol = solve_joint_fused(prob)
        assert bool(sol.converged)
        assert bool(prob.constraints_satisfied(sol.a, sol.power,
                                               rtol=1e-3).all())

    def test_jit_and_eager_agree(self):
        prob = sample_problem(5, 32)
        assert_agrees(jax.jit(solve_joint_fused)(prob),
                      solve_joint_fused(prob), tol=1e-6)


class TestFusedBatch:
    def test_ragged_batch_matches_loop(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 24, 16, 24])]
        batch = stack_problems(probs)
        sol = solve_joint_batch(batch, method="fused")
        for b, prob in enumerate(probs):
            assert_agrees(sol.instance(b), solve_joint(prob))
        # padded slots self-deselect: a = power = 0
        pad = ~np.asarray(batch.mask)
        assert np.all(np.asarray(sol.a)[pad] == 0.0)
        assert np.all(np.asarray(sol.power)[pad] == 0.0)

    def test_fading_batch(self):
        probs = [sample_problem(i, 10, with_fading=True, n_rounds=4)
                 for i in range(4)]
        sol = solve_joint_batch(stack_problems(probs), method="fused")
        assert sol.a.shape == (4, 10, 4)
        for b, prob in enumerate(probs):
            assert_agrees(sol.instance(b), solve_joint(prob))

    def test_fused_kernel_method(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 24, 16])]
        batch = stack_problems(probs)
        sol = solve_joint_batch(batch, method="fused_kernel")
        for b, prob in enumerate(probs):
            assert_agrees(sol.instance(b), solve_joint(prob))

    def test_chunked_equals_unchunked(self):
        batch = make_batch("paper_static", 8, seed=0, n_devices=48)
        ref = solve_joint_batch(batch, method="fused")
        for chunk in (64, 1000, 16_384):   # misaligned + oversized chunks
            sol = solve_joint_batch(batch, method="fused",
                                    chunk_elements=chunk)
            np.testing.assert_allclose(np.asarray(sol.a), np.asarray(ref.a),
                                       atol=1e-6, rtol=0)
            np.testing.assert_allclose(np.asarray(sol.power),
                                       np.asarray(ref.power),
                                       atol=1e-6, rtol=1e-6)

    def test_chunk_elements_rejected_elsewhere(self):
        batch = make_batch("paper_static", 2, seed=0, n_devices=8)
        with pytest.raises(ValueError, match="chunk_elements"):
            solve_joint_batch(batch, method="optimal", chunk_elements=128)

    def test_scheduler_fused_solver(self):
        batch = make_batch("paper_static", 4, seed=0, n_devices=16)
        state = ProbabilisticScheduler(solver="fused").precompute_batch(batch)
        ref = ProbabilisticScheduler().precompute_batch(batch)
        np.testing.assert_allclose(np.asarray(state.a), np.asarray(ref.a),
                                   atol=TOL, rtol=0)


class TestMegaFleet:
    @pytest.mark.slow
    def test_mega_fleet_100k_chunked(self):
        """The acceptance-scale check: a 100k-device instance solves on the
        chunked path (fixed ~chunk_elements working set) and agrees with
        the unchunked flat solve."""
        prob = make_problem("mega_fleet_100k", seed=0)
        assert prob.n_devices == 100_000
        sol = jax.jit(lambda p: solve_joint_fused(p, chunk_elements=16_384))(prob)
        assert bool(sol.converged)
        ref = solve_joint_fused(prob)
        np.testing.assert_allclose(np.asarray(sol.a), np.asarray(ref.a),
                                   atol=1e-6, rtol=0)
        assert bool(prob.constraints_satisfied(sol.a, sol.power,
                                               rtol=1e-3).all())

    def test_metro_1m_registry_small_draw(self):
        # the full 1M draw is example/benchmark territory; registry + a
        # downscaled solve keep CI honest about the entry itself
        prob = make_problem("metro_1m_users", seed=0, n_devices=512)
        sol = solve_joint_fused(prob, chunk_elements=128)
        assert_agrees(sol, solve_joint(prob))


class TestTwoVirtualDevices:
    @pytest.mark.slow
    def test_chunked_sharded_equals_unchunked(self, tmp_path):
        """Element-axis sharding on a 2-device host mesh: same solution as
        the local unchunked solve (subprocess: XLA device count is fixed
        at backend init, so the flag must not leak into this process)."""
        script = textwrap.dedent("""
            import jax, jax.numpy as jnp, numpy as np
            assert jax.device_count() == 2, jax.device_count()
            from repro.core import (sample_problem, solve_joint_fused,
                                    solve_joint_batch, stack_problems)
            prob = sample_problem(0, 1000)
            ref = solve_joint_fused(prob)
            mesh = jax.sharding.Mesh(np.array(jax.devices()), ("elements",))
            for kw in (dict(chunk_elements=256, shard=True),
                       dict(shard=True, mesh=mesh),
                       dict(chunk_elements=300, shard=True, mesh=mesh)):
                sol = jax.jit(lambda p: solve_joint_fused(p, **kw))(prob)
                np.testing.assert_allclose(np.asarray(sol.a),
                                           np.asarray(ref.a),
                                           atol=1e-6, rtol=0)
            # batched driver on the same mesh
            batch = stack_problems([sample_problem(i, 64) for i in range(8)])
            b_ref = solve_joint_batch(batch, method="fused", shard=False)
            b_sh = solve_joint_batch(batch, method="fused", mesh=mesh,
                                     chunk_elements=128)
            np.testing.assert_allclose(np.asarray(b_sh.a),
                                       np.asarray(b_ref.a),
                                       atol=1e-6, rtol=0)
            print("OK")
        """)
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=2")
        res = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=600,
                             cwd=str(REPO))
        assert res.returncode == 0, res.stdout + res.stderr
        assert "OK" in res.stdout


class TestScanEngineBridge:
    def test_plans_from_batch_fused(self):
        """The PR-2 sweep bridge consumes the fused path unchanged:
        ``plans_from_batch(..., method='fused')`` produces the same
        trajectory plans (probabilities, powers, energy tables, RNG
        streams) as the PR-1 alternating solve."""
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_mnist_like
        from repro.fl.engine import FLConfig
        from repro.fl.scan_engine import plans_from_batch

        n_dev = 8
        train, _ = make_mnist_like(256, 64, seed=0)
        parts = dirichlet_partition(train, n_dev, beta=0.3, seed=1)
        sizes = np.array([len(p) for p in parts])
        batch = make_batch("paper_static", n_instances=3, seed=0,
                           n_devices=n_dev, dirichlet_sizes=sizes)
        sch = ProbabilisticScheduler()
        cfgs = [FLConfig(n_rounds=4, eval_every=4, batch_per_client=2,
                         seed=s) for s in range(3)]
        ref = plans_from_batch(batch, sch, [parts] * 3, cfgs)
        fused = plans_from_batch(batch, sch, [parts] * 3, cfgs,
                                 method="fused")
        for pr, pf in zip(ref, fused):
            np.testing.assert_allclose(np.asarray(pf.probs),
                                       np.asarray(pr.probs),
                                       atol=TOL, rtol=0)
            np.testing.assert_allclose(np.asarray(pf.tx_time),
                                       np.asarray(pr.tx_time),
                                       rtol=1e-4, atol=1e-7)
            np.testing.assert_allclose(np.asarray(pf.round_energy),
                                       np.asarray(pr.round_energy),
                                       rtol=1e-4, atol=1e-9)
            np.testing.assert_array_equal(np.asarray(pf.batch_idx),
                                          np.asarray(pr.batch_idx))


class TestDeterminism:
    """ISSUE-4 satellite: the fused solver is reproducible — repeated
    jitted calls are bitwise identical, eager tracks jit to f32 ulp (the
    compiled fusion may reassociate), and a fresh process with the same
    seed reproduces the jitted results bit for bit."""

    def test_repeat_jit_calls_bitwise_identical(self):
        sol1 = jax.jit(solve_joint_fused)(sample_problem(3, 48))
        sol2 = jax.jit(solve_joint_fused)(sample_problem(3, 48))
        np.testing.assert_array_equal(np.asarray(sol1.a), np.asarray(sol2.a))
        np.testing.assert_array_equal(np.asarray(sol1.power),
                                      np.asarray(sol2.power))
        assert int(sol1.n_iters) == int(sol2.n_iters)

    def test_eager_tracks_jit_to_ulp(self):
        prob = sample_problem(4, 48)
        eager, jitted = solve_joint_fused(prob), jax.jit(solve_joint_fused)(prob)
        np.testing.assert_allclose(np.asarray(eager.a),
                                   np.asarray(jitted.a), atol=1e-6, rtol=0)
        np.testing.assert_allclose(np.asarray(eager.power),
                                   np.asarray(jitted.power),
                                   atol=1e-6, rtol=1e-6)

    @pytest.mark.slow
    def test_cross_process_bitwise(self):
        """A fresh interpreter with the same seed reproduces the jitted
        solution digests exactly (same XLA, same machine)."""
        import hashlib

        def digests():
            out = []
            for seed, n in ((0, 32), (7, 64)):
                sol = jax.jit(solve_joint_fused)(sample_problem(seed, n))
                out.append(hashlib.sha256(
                    np.asarray(sol.a).tobytes()
                    + np.asarray(sol.power).tobytes()).hexdigest())
            return out

        script = textwrap.dedent("""
            import hashlib
            import jax, numpy as np
            from repro.core import sample_problem, solve_joint_fused
            for seed, n in ((0, 32), (7, 64)):
                sol = jax.jit(solve_joint_fused)(sample_problem(seed, n))
                print(hashlib.sha256(
                    np.asarray(sol.a).tobytes()
                    + np.asarray(sol.power).tobytes()).hexdigest())
        """)
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        res = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=600,
                             cwd=str(REPO))
        assert res.returncode == 0, res.stdout + res.stderr
        assert res.stdout.split() == digests()


class TestTraceParity:
    @pytest.mark.parametrize("seed,n", [(42, 64), (0, 16), (9, 32)])
    def test_iteration_counts_match(self, seed, n):
        """solve_joint_trace shares solve_joint's step and stopping rule:
        identical n_iters and converged flag (no off-by-one)."""
        prob = sample_problem(seed, n)
        sol = solve_joint(prob)
        tr_sol, trace = solve_joint_trace(prob)
        assert int(sol.n_iters) == int(tr_sol.n_iters)
        assert bool(sol.converged) == bool(tr_sol.converged)
        # the trace records obj(a^0) plus one entry per step taken
        assert len(trace) == int(tr_sol.n_iters) + 1
        np.testing.assert_allclose(float(sol.objective), trace[-1],
                                   rtol=1e-6, atol=1e-9)
