"""Property-based tests on model-zoo invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import layers as L
from repro.models.moe import capacity, moe_apply, moe_init
from repro.configs.base import MoEConfig


class TestRoPE:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([32, 64, 128]))
    def test_norm_preserving(self, pos, dh):
        rng = np.random.default_rng(dh + pos)
        x = jnp.asarray(rng.normal(size=(1, 4, 2, dh)), jnp.float32)
        y = L.rope(x, jnp.full((4,), pos, jnp.int32), 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)

        def score(m, n):
            qm = L.rope(q, jnp.asarray([m], jnp.int32), 1e4)
            kn = L.rope(k, jnp.asarray([n], jnp.int32), 1e4)
            return float(jnp.sum(qm * kn))

        np.testing.assert_allclose(score(5, 3), score(105, 103), rtol=1e-4)
        np.testing.assert_allclose(score(17, 0), score(1017, 1000), rtol=1e-4)


class TestRMSNorm:
    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.1, 100.0))
    def test_scale_invariant(self, scale):
        # scale-invariance holds up to the eps regulariser, so the scale
        # range keeps mean(x^2 * s^2) >> eps
        x = jnp.asarray(np.random.default_rng(1).normal(size=(3, 16)),
                        jnp.float32)
        p = L.rmsnorm_init(16)
        a = L.rmsnorm(p, x)
        b = L.rmsnorm(p, x * scale)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)


class TestMoEInvariants:
    def _setup(self, t=96, d=32, e=4, k=2, cf=1.25, seed=0):
        cfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=d * 2,
                        capacity_factor=cf)
        params = moe_init(jax.random.PRNGKey(seed), d, cfg)
        x = jnp.asarray(np.random.default_rng(seed).normal(size=(t, d)),
                        jnp.float32)
        return cfg, params, x

    def test_output_finite_and_shaped(self):
        cfg, params, x = self._setup()
        y, aux = moe_apply(params, x, cfg)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_dropped_fraction_in_unit_interval(self):
        cfg, params, x = self._setup(cf=0.5)   # forced drops
        _, aux = moe_apply(params, x, cfg)
        assert 0.0 <= float(aux.dropped_frac) <= 1.0
        assert float(aux.dropped_frac) > 0.0

    def test_huge_capacity_no_drops(self):
        cfg, params, x = self._setup(cf=16.0)
        _, aux = moe_apply(params, x, cfg)
        assert float(aux.dropped_frac) == 0.0

    def test_load_balance_lower_bound(self):
        """Switch LB loss satisfies E*sum(f*P) >= 1 (Cauchy-Schwarz at
        uniform routing)... approximately, for any router."""
        cfg, params, x = self._setup(seed=3)
        _, aux = moe_apply(params, x, cfg)
        assert float(aux.load_balance) >= 0.9

    def test_capacity_rounding(self):
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16)
        assert capacity(1024, cfg) % 8 == 0
        assert capacity(1024, cfg) >= 1024 * 2 / 8


class TestRingBufferCache:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(5, 40))
    def test_prefill_roll_slots(self, s):
        """kv_cache_from_prefill places position p at slot p % W."""
        w = 16
        spec = L.AttnLayerSpec(n_heads=2, n_kv_heads=1, d_head=8, theta=1e4,
                               window=w, softcap=None, qk_norm=False,
                               use_rope=False)
        k = jnp.arange(s, dtype=jnp.float32)[None, :, None, None] * jnp.ones((1, s, 1, 8))
        cache = L.kv_cache_from_prefill(k, k, spec, cache_len=s)
        pos = np.asarray(cache.pos)
        kv = np.asarray(cache.k)[0, :, 0, 0]
        for slot in range(min(w, s)):
            if pos[slot] >= 0:
                assert pos[slot] % min(w, s if s < w else w) == slot % min(w, s if s < w else w) \
                    or pos[slot] == kv[slot]
                assert kv[slot] == pos[slot]       # value tags its position
