"""Seed-era roofline modules (``repro.roofline``): smoke + golden tests.

These modules predate the test suite (they shipped with the v0 seed and
were only exercised manually via ``repro.roofline.report``); this file
pins their arithmetic so estimator refactors cannot silently change the
EXPERIMENTS.md tables:

* ``analysis.py`` — ``model_flops`` closed forms per mode, the
  ``Roofline.finalize`` term/dominance algebra, ``build_roofline``
  wiring (cost-dict key fallback, per-chip normalisation);
* ``hlo.py`` — ``shape_bytes`` on dtype/tuple strings, the collective
  inventory on a synthetic optimized-HLO text (incl. async start/done
  dedup);
* ``report.py`` — table rendering and hillclimb picks on synthetic
  artifact records.
"""
import math

import pytest

from repro.configs import ARCHS, get_arch, get_shape
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
from repro.roofline.analysis import (Roofline, analytic_flops,
                                     build_roofline, estimate_hbm_bytes,
                                     model_flops)
from repro.roofline.hlo import parse_collectives, shape_bytes
from repro.roofline.report import (_fmt_bytes, dryrun_table,
                                   interesting_pairs, roofline_table)


# ------------------------------------------------------------- analysis

class TestModelFlops:
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_train_is_6nd(self, arch):
        cfg, shape = get_arch(arch), get_shape("train_4k")
        expect = 6.0 * cfg.n_active_params() * (shape.global_batch
                                                * shape.seq_len)
        assert model_flops(cfg, shape) == expect

    def test_prefill_forward_only(self):
        cfg = get_arch("gemma3-1b")
        train = model_flops(cfg, get_shape("train_4k"))
        prefill = model_flops(cfg, get_shape("prefill_32k"))
        # same 2ND forward term, train adds the 4ND backward; the shapes
        # share batch*seq? no — compare against the closed form directly
        shape = get_shape("prefill_32k")
        assert prefill == 2.0 * cfg.n_active_params() * (
            shape.global_batch * shape.seq_len)
        assert train > 0

    def test_decode_one_token_per_sequence(self):
        cfg = get_arch("gemma3-1b")
        shape = get_shape("decode_32k")
        assert model_flops(cfg, shape) == (2.0 * cfg.n_active_params()
                                           * shape.global_batch)

    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_analytic_flops_adds_attention(self, arch):
        cfg, shape = get_arch(arch), get_shape("train_4k")
        base, full = model_flops(cfg, shape), analytic_flops(cfg, shape)
        if cfg.attn is None:
            assert full == base
        else:
            assert full > base

    @pytest.mark.parametrize("arch", sorted(ARCHS))
    @pytest.mark.parametrize("shape", ["train_4k", "prefill_32k",
                                       "decode_32k"])
    def test_hbm_estimate_positive_and_finite(self, arch, shape):
        est = estimate_hbm_bytes(get_arch(arch), get_shape(shape), chips=8)
        assert math.isfinite(est) and est > 0


class TestRooflineFinalize:
    def _roof(self, **kw):
        base = dict(arch="a", shape="s", mesh="single", chips=4,
                    flops_per_device=0.0, bytes_per_device=0.0,
                    collective_bytes_per_device=0.0, model_flops=0.0)
        base.update(kw)
        return Roofline(**base).finalize()

    def test_terms_are_rate_quotients(self):
        r = self._roof(flops_per_device=PEAK_FLOPS_BF16 * 2.0,
                       bytes_per_device=HBM_BW * 0.5,
                       collective_bytes_per_device=ICI_BW_PER_LINK * 0.25)
        assert r.compute_s == pytest.approx(2.0)
        assert r.memory_s == pytest.approx(0.5)
        assert r.collective_s == pytest.approx(0.25)
        assert r.dominant == "compute"

    @pytest.mark.parametrize("term,expect", [
        ("flops_per_device", "compute"),
        ("bytes_per_device", "memory"),
        ("collective_bytes_per_device", "collective")])
    def test_dominant_picks_largest(self, term, expect):
        scale = {"flops_per_device": PEAK_FLOPS_BF16,
                 "bytes_per_device": HBM_BW,
                 "collective_bytes_per_device": ICI_BW_PER_LINK}
        kw = {k: v * 1e-3 for k, v in scale.items()}
        kw[term] = scale[term] * 1.0
        assert self._roof(**kw).dominant == expect

    def test_useful_ratio(self):
        r = self._roof(flops_per_device=10.0, model_flops=20.0, chips=4)
        assert r.useful_ratio == pytest.approx(20.0 / 40.0)
        assert self._roof(flops_per_device=0.0).useful_ratio == 0.0

    def test_build_roofline_cost_key_fallback(self):
        for key in ("bytes accessed", "bytes_accessed"):
            r = build_roofline("a", "s", "single", 4,
                               {"flops": 8.0, key: 16.0},
                               collective_bytes_total=32.0, mflops=1.0)
            assert r.flops_per_device == 8.0
            assert r.bytes_per_device == 16.0
            assert r.collective_bytes_per_device == 8.0  # / chips

    def test_build_roofline_with_arch_fills_analytics(self):
        cfg, shape = get_arch("gemma3-1b"), get_shape("train_4k")
        r = build_roofline("gemma3-1b", "train_4k", "single", 8,
                           {"flops": 1.0}, 0.0,
                           model_flops(cfg, shape), cfg=cfg, shape=shape)
        assert r.analytic_flops_total == analytic_flops(cfg, shape)
        assert r.hbm_est_bytes_per_device == estimate_hbm_bytes(
            cfg, shape, 8)
        assert r.dominant_est in ("compute", "memory", "collective")
        assert "dominant" in r.summary()


# ------------------------------------------------------------------ hlo

class TestShapeBytes:
    @pytest.mark.parametrize("s,expect", [
        ("f32[8]", 32),
        ("bf16[16,4096]", 16 * 4096 * 2),
        ("pred[]", 1),
        ("u8[3,3]", 9),
        ("(f32[4], bf16[2,2])", 16 + 8),       # tuple shapes sum
        ("token[]", 0),                        # unknown dtype skipped
    ])
    def test_golden(self, s, expect):
        assert shape_bytes(s) == expect


_HLO = """\
HloModule m
ENTRY %main {
  %ag = bf16[16,4096]{1,0} all-gather(%p0), replica_groups={{0,1}}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs = f32[256]{0} reduce-scatter(%y), dimensions={0}
  %start = bf16[8,8]{1,0} all-gather-start(%z)
  %done = bf16[8,8]{1,0} all-gather-done(%start)
  %cp = f32[64]{0} collective-permute(%w), source_target_pairs={{0,1}}
}
"""


class TestParseCollectives:
    def test_inventory_golden(self):
        stats = parse_collectives(_HLO)
        assert stats.count_by_kind == {"all-gather": 2, "all-reduce": 1,
                                       "reduce-scatter": 1,
                                       "collective-permute": 1}
        ag = 16 * 4096 * 2 + 8 * 8 * 2      # start counted, done deduped
        assert stats.bytes_by_kind["all-gather"] == ag
        assert stats.bytes_by_kind["all-reduce"] == 1024 * 4
        assert stats.bytes_by_kind["reduce-scatter"] == 256 * 4
        assert stats.bytes_by_kind["collective-permute"] == 64 * 4
        assert stats.total_bytes == sum(stats.bytes_by_kind.values())
        assert stats.as_dict()["total_bytes"] == stats.total_bytes

    def test_no_collectives(self):
        stats = parse_collectives("ENTRY %m { %r = f32[2] add(%a, %b) }")
        assert stats.total_bytes == 0
        assert stats.bytes_by_kind == {}


# --------------------------------------------------------------- report

def _rec(arch, shape, *, mesh="single", compute=2.0, hbm=1.0, coll=0.5):
    """A synthetic ok-record shaped like a dry-run artifact after
    ``report._refresh`` (roofline fields in seconds)."""
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "chips": 4,
        "status": "ok", "n_params": 1.5e9, "compile_s": 1.2,
        "memory_analysis": {"temp_size_in_bytes": 2 ** 30,
                            "argument_size_in_bytes": 2 ** 29},
        "collectives": {"bytes_by_kind": {"all-reduce": 4096},
                        "count_by_kind": {"all-reduce": 2},
                        "total_bytes": 4096},
        "roofline": {"compute_s": compute, "memory_s": hbm,
                     "collective_s": coll, "dominant": "compute",
                     "compute_analytic_s": compute, "hbm_est_s": hbm,
                     "dominant_est": "compute",
                     "model_flops": 1e15, "useful_ratio": 0.5},
    }


class TestReport:
    def test_fmt_bytes(self):
        assert _fmt_bytes(512) == "512.0B"
        assert _fmt_bytes(2048) == "2.0KiB"
        assert _fmt_bytes(3 * 2 ** 30) == "3.0GiB"

    def test_dryrun_table_rows(self):
        recs = [_rec("a1", "train_4k"),
                {"arch": "a2", "shape": "train_4k", "mesh": "single",
                 "status": "skipped", "reason": "x" * 60},
                {"arch": "a3", "shape": "train_4k", "mesh": "single",
                 "status": "error"}]
        table = dryrun_table(recs)
        lines = table.splitlines()
        assert len(lines) == 2 + 3                  # header + 3 rows
        assert "| a1 |" in table and "1.50B" in table
        assert "SKIP" in table and "ERROR" in table
        assert "all-reduce:4.0KiB" in table

    def test_roofline_table_filters_mesh_and_status(self):
        recs = [_rec("a1", "train_4k"),
                _rec("a2", "train_4k", mesh="multi"),
                {"arch": "a3", "shape": "train_4k", "mesh": "single",
                 "status": "error"}]
        single = roofline_table(recs, "single")
        assert "a1" in single and "a2" not in single and "a3" not in single
        assert "a2" in roofline_table(recs, "multi")
        assert "**compute**" in single

    def test_interesting_pairs_picks(self):
        recs = [
            # headroom case: tiny compute fraction
            _rec("lowfrac", "train_4k", compute=0.1, hbm=8.0, coll=0.1),
            # collective-bound case
            _rec("collbound", "prefill_32k", compute=1.0, hbm=1.0,
                 coll=50.0),
            _rec("balanced", "train_4k", compute=1.0, hbm=1.0, coll=0.1),
            # wrong shape/mesh records must be ignored
            _rec("othershape", "decode_32k", compute=1e-9),
            _rec("othermesh", "train_4k", mesh="multi", compute=1e-9),
        ]
        picks = interesting_pairs(recs)
        assert picks["worst_roofline_fraction"][0] == "lowfrac"
        assert picks["most_collective"] == ("collbound", "prefill_32k")
