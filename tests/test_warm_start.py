"""Warm-start threading through the solver stack (ISSUE 4 tentpole).

The contract, for every entry point (``solve_joint``,
``solve_joint_fused``, ``solve_joint_batch``, the scheduler wrappers):

* ``init`` never changes the answer — warm and cold solutions agree to
  solver epsilon (for Dinkelbach's globally-convergent lambda iteration
  they agree bitwise in practice; we assert a tight tolerance);
* on a time-correlated (``drifting_metro``) channel, warm-starting from
  the previous round's ``resume`` state collapses the inner Algorithm-1
  iteration count — the acceptance criterion;
* the drifting scenarios themselves have the advertised statistics
  (Exp(1) marginals, ``corr = coherence^2`` round-to-round).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    WarmStart,
    gauss_markov_fading,
    make_problem,
    sample_problem,
    slice_round,
    solve_joint,
    solve_joint_batch,
    solve_joint_fused,
    stack_problems,
)
from repro.core.schedulers import ProbabilisticScheduler


def assert_same_solution(warm, cold, tol=1e-6):
    np.testing.assert_allclose(np.asarray(warm.a), np.asarray(cold.a),
                               atol=tol, rtol=0)
    np.testing.assert_allclose(np.asarray(warm.power),
                               np.asarray(cold.power), atol=tol, rtol=tol)


class TestWarmStartSolveJoint:
    def test_solution_unchanged_same_problem(self):
        prob = sample_problem(0, 48)
        cold = solve_joint(prob)
        warm = solve_joint(prob, init=cold.resume)
        assert_same_solution(warm, cold, tol=0.0)   # bitwise
        assert int(warm.inner_iters) < int(cold.inner_iters)

    def test_resume_is_warm_start_state(self):
        prob = sample_problem(1, 16)
        sol = solve_joint(prob)
        state = sol.resume
        assert isinstance(state, WarmStart)
        assert state.a.shape == sol.a.shape
        assert state.power.shape == sol.power.shape

    def test_tuple_init_accepted(self):
        prob = sample_problem(2, 16)
        cold = solve_joint(prob)
        warm = solve_joint(prob, init=(cold.a, cold.power))
        assert_same_solution(warm, cold, tol=0.0)

    def test_analytic_mode_ignores_init(self):
        prob = sample_problem(3, 16)
        cold = solve_joint(prob, power_solver="analytic")
        warm = solve_joint(prob, power_solver="analytic", init=cold.resume)
        assert_same_solution(warm, cold, tol=0.0)
        assert int(cold.inner_iters) == int(warm.inner_iters) == 0

    def test_jit_with_init(self):
        prob = sample_problem(4, 24)
        cold = solve_joint(prob)
        warm = jax.jit(lambda p, s: solve_joint(p, init=s))(prob, cold.resume)
        assert_same_solution(warm, cold, tol=1e-7)


class TestWarmStartFused:
    def test_solution_unchanged(self):
        prob = sample_problem(5, 48)
        cold = solve_joint_fused(prob, power_solver="dinkelbach")
        warm = solve_joint_fused(prob, power_solver="dinkelbach",
                                 init=cold.resume)
        assert_same_solution(warm, cold, tol=0.0)
        assert int(warm.inner_iters) < int(cold.inner_iters)

    def test_chunked_warm_matches(self):
        prob = sample_problem(6, 40)
        cold = solve_joint_fused(prob, power_solver="dinkelbach")
        warm = solve_joint_fused(prob, power_solver="dinkelbach",
                                 chunk_elements=16, init=cold.resume)
        assert_same_solution(warm, cold, tol=1e-6)
        assert bool(warm.converged)

    def test_fading_shapes(self):
        prob = sample_problem(7, 12, with_fading=True, n_rounds=5)
        cold = solve_joint_fused(prob, power_solver="dinkelbach")
        warm = solve_joint_fused(prob, power_solver="dinkelbach",
                                 init=cold.resume)
        assert warm.a.shape == (12, 5)
        assert_same_solution(warm, cold, tol=0.0)

    def test_zero_init_rows_behave_cold(self):
        """All-zero init is the 'no previous state' encoding the service
        relies on for mixed warm/cold micro-batches."""
        prob = sample_problem(8, 24)
        cold = solve_joint_fused(prob, power_solver="dinkelbach")
        zeros = WarmStart(a=jnp.zeros_like(cold.a),
                          power=jnp.zeros_like(cold.power))
        pseudo = solve_joint_fused(prob, power_solver="dinkelbach",
                                   init=zeros)
        assert_same_solution(pseudo, cold, tol=0.0)
        assert int(pseudo.inner_iters) == int(cold.inner_iters)


class TestWarmStartBatch:
    def test_alternating_batch(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 24, 16])]
        batch = stack_problems(probs)
        cold = solve_joint_batch(batch)
        warm = solve_joint_batch(batch, init=cold.resume)
        assert_same_solution(warm, cold, tol=0.0)
        assert (np.asarray(warm.inner_iters) <
                np.asarray(cold.inner_iters)).all()

    def test_fused_batch(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 24, 16])]
        batch = stack_problems(probs)
        cold = solve_joint_batch(batch, method="fused",
                                 power_solver="dinkelbach")
        warm = solve_joint_batch(batch, method="fused",
                                 power_solver="dinkelbach", init=cold.resume)
        assert_same_solution(warm, cold, tol=0.0)
        assert int(np.asarray(warm.inner_iters)) < \
            int(np.asarray(cold.inner_iters))

    def test_direct_methods_reject_init(self):
        batch = stack_problems([sample_problem(0, 8)])
        sol = solve_joint_batch(batch)
        for method in ("optimal", "kernel", "fused_kernel"):
            with pytest.raises(ValueError, match="init"):
                solve_joint_batch(batch, method=method, init=sol.resume)

    def test_scheduler_threading(self):
        prob = sample_problem(9, 16)
        sch = ProbabilisticScheduler()
        cold = sch.solve(prob)
        warm_state = sch.precompute(prob, init=cold.resume)
        np.testing.assert_array_equal(np.asarray(warm_state.a),
                                      np.asarray(cold.a))
        with pytest.raises(ValueError, match="optimal"):
            ProbabilisticScheduler(solver="optimal").solve(
                prob, init=cold.resume)


class TestDriftingScenarios:
    def test_gauss_markov_statistics(self):
        g = gauss_markov_fading(0, 4000, 40, coherence=0.9)
        assert g.shape == (4000, 40)
        assert (g > 0).all()
        # Exp(1) marginals: mean 1, var 1 (loose CLT bounds)
        assert abs(g.mean() - 1.0) < 0.05
        assert abs(g.var() - 1.0) < 0.15
        # round-to-round power-gain correlation ~ coherence^2
        flat = g.reshape(-1, 40)
        c = np.corrcoef(flat[:, :-1].ravel(), flat[:, 1:].ravel())[0, 1]
        assert abs(c - 0.81) < 0.05

    def test_zero_coherence_is_iid(self):
        g = gauss_markov_fading(1, 2000, 20, coherence=0.0)
        flat = g.reshape(-1, 20)
        c = np.corrcoef(flat[:, :-1].ravel(), flat[:, 1:].ravel())[0, 1]
        assert abs(c) < 0.05

    def test_coherence_validated(self):
        with pytest.raises(ValueError, match="coherence"):
            gauss_markov_fading(0, 4, 4, coherence=1.0)

    def test_registry_entries(self):
        prob = make_problem("drifting_metro", seed=0, n_devices=16,
                            n_rounds=6)
        assert prob.fading.shape == (16, 6)
        big = make_problem("drifting_mega_fleet", seed=0, n_devices=64,
                           n_rounds=3)
        assert big.fading.shape == (64, 3)

    def test_slice_round(self):
        prob = make_problem("drifting_metro", seed=0, n_devices=8,
                            n_rounds=4)
        r2 = slice_round(prob, 2)
        assert r2.fading.shape == (8, 1)
        assert r2.n_rounds == 1
        np.testing.assert_array_equal(np.asarray(r2.fading[:, 0]),
                                      np.asarray(prob.fading[:, 2]))
        static = dataclasses.replace(prob, fading=None)
        with pytest.raises(ValueError, match="fading"):
            slice_round(static, 0)


class TestDriftingWarmStart:
    """The acceptance criterion: warm-started solves on the
    ``drifting_metro`` stream converge in measurably fewer (inner)
    iterations than cold starts, with unchanged solutions."""

    def test_iteration_drop_on_drift_stream(self):
        prob = make_problem("drifting_metro", seed=0, n_devices=48,
                            n_rounds=8)
        state = None
        warm_iters, cold_iters = [], []
        for k in range(8):
            pk = slice_round(prob, k)
            cold = solve_joint_fused(pk, power_solver="dinkelbach")
            cold_iters.append(int(cold.inner_iters))
            if state is not None:
                warm = solve_joint_fused(pk, power_solver="dinkelbach",
                                         init=state)
                warm_iters.append(int(warm.inner_iters))
                assert_same_solution(warm, cold, tol=1e-6)
            state = cold.resume
        # "measurably fewer": at most half the cold count, every round
        assert np.mean(warm_iters) <= 0.5 * np.mean(cold_iters[1:])
        assert max(warm_iters) < min(cold_iters)

    def test_solve_joint_drift_stream(self):
        prob = make_problem("drifting_metro", seed=1, n_devices=32,
                            n_rounds=4)
        state = None
        for k in range(4):
            pk = slice_round(prob, k)
            cold = solve_joint(pk)
            if state is not None:
                warm = solve_joint(pk, init=state)
                assert_same_solution(warm, cold, tol=1e-6)
                assert int(warm.inner_iters) < int(cold.inner_iters)
            state = cold.resume
