"""Regression suite for the [N] / [N, K] broadcasting contract.

Every ``WirelessFLProblem`` method (and the problem-level power/selection
shims) used to crash with ``Incompatible shapes for broadcasting:
[(N,), (N, K)]`` for 1-d inputs on a fading problem with K != N — the
``[N]`` numerator was mixed with the ``[N, K]`` path gain, which only
"worked" (silently wrongly) when K == N.  These tests pin the contract of
``problem.py``'s module docstring on a fading problem with K != N:

* every method accepts all four (a-rank x power-rank) combinations;
* a 1-d input equals its column-broadcast 2-d call **bit-for-bit**
  (regression cases + a hypothesis property over random problems);
* the 2-d result's column k equals the per-round ``slice_round`` call.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import slice_round
from repro.core.optimal import _feasible
from repro.core.power import analytic_power, dinkelbach_power, energy_bound_ok
from repro.core.scenarios import make_problem
from repro.core.selection import optimal_selection

N, K = 12, 5      # K != N everywhere: equal sizes would mask rank bugs


@pytest.fixture(scope="module")
def fading_problem():
    return make_problem("drifting_metro", seed=3, n_devices=N, n_rounds=K)


def _ranked(x_1d, ndim):
    """The 1-d vector, or its column-broadcast [N, K] copy."""
    x = jnp.asarray(x_1d, jnp.float32)
    return x if ndim == 1 else jnp.broadcast_to(x[:, None], (N, K))


# method name -> callable(problem, a, power); one entry per public
# surface that mixes decision variables with the [N, K] path gain
METHODS = {
    "rate": lambda pb, a, p: pb.rate(p),
    "tx_time": lambda pb, a, p: pb.tx_time(p),
    "upload_energy": lambda pb, a, p: pb.upload_energy(p),
    "round_energy": lambda pb, a, p: pb.round_energy(p),
    "p_min": lambda pb, a, p: pb.p_min(a),
    "constraints_satisfied": lambda pb, a, p: pb.constraints_satisfied(a, p),
    "analytic_power": lambda pb, a, p: analytic_power(pb, a).power,
    "analytic_lam": lambda pb, a, p: analytic_power(pb, a).lam,
    "dinkelbach_power": lambda pb, a, p: dinkelbach_power(pb, a).power,
    "optimal_selection": lambda pb, a, p: optimal_selection(pb, p),
    "energy_bound_ok": lambda pb, a, p: energy_bound_ok(
        pb, a, analytic_power(pb, a)),
    "optimal_feasible": lambda pb, a, p: _feasible(pb, a),
}

A_1D = np.linspace(0.02, 0.6, N).astype(np.float32)
P_1D = np.linspace(0.05, 0.9, N).astype(np.float32)


@pytest.mark.parametrize("method", sorted(METHODS))
@pytest.mark.parametrize("a_ndim,p_ndim", [(1, 1), (1, 2), (2, 1), (2, 2)])
def test_rank_combinations(fading_problem, method, a_ndim, p_ndim):
    """All four input-rank combinations work on fading K != N and agree
    bit-for-bit: 1-d means "same value at each round's channel"."""
    fn = METHODS[method]
    out = fn(fading_problem, _ranked(A_1D, a_ndim), _ranked(P_1D, p_ndim))
    ref = fn(fading_problem, _ranked(A_1D, 2), _ranked(P_1D, 2))
    assert out.shape == (N, K)
    assert ref.shape == (N, K)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("method", sorted(METHODS))
def test_columns_match_sliced_rounds(fading_problem, method):
    """Column k of the broadcast result equals the standalone 1-round
    problem for round k (``slice_round``)."""
    fn = METHODS[method]
    full = np.asarray(fn(fading_problem, jnp.asarray(A_1D),
                         jnp.asarray(P_1D)))
    for k in (0, K - 1):
        sub = slice_round(fading_problem, k)
        col = np.asarray(fn(sub, jnp.asarray(A_1D), jnp.asarray(P_1D)))
        assert col.shape == (N, 1)
        np.testing.assert_array_equal(full[:, k], col[:, 0])


def test_static_problem_ranks_unchanged():
    """On a static channel 1-d stays 1-d and 2-d inputs broadcast the
    per-device constants across rounds (no behaviour change)."""
    prob = make_problem("paper_static", seed=0, n_devices=N)
    a1, p1 = jnp.asarray(A_1D), jnp.asarray(P_1D)
    assert prob.p_min(a1).shape == (N,)
    assert prob.constraints_satisfied(a1, p1).shape == (N,)
    a2 = jnp.broadcast_to(a1[:, None], (N, K))
    p2 = jnp.broadcast_to(p1[:, None], (N, K))
    out = prob.constraints_satisfied(a2, p2)
    assert out.shape == (N, K)
    np.testing.assert_array_equal(
        np.asarray(out)[:, 0], np.asarray(prob.constraints_satisfied(a1, p1)))


def test_objective_reduces_not_broadcasts(fading_problem):
    """``objective`` is the one non-elementwise method: it *reduces*
    (7a)'s weighted sum, so a 2-d input sums over rounds too (the global
    Algorithm-2 stopping statistic) — K times the 1-d call for a
    round-constant a.  Documented here so the contract's scope is pinned."""
    a1 = jnp.asarray(A_1D)
    a2 = jnp.broadcast_to(a1[:, None], (N, K))
    o1 = float(fading_problem.objective(a1))
    o2 = float(fading_problem.objective(a2))
    assert o2 == pytest.approx(K * o1, rel=1e-6)


def test_issue_repro_snippets():
    """The literal crash repros from ISSUE 5."""
    prob = make_problem("drifting_metro", seed=0, n_devices=N, n_rounds=K)
    a = jnp.full((N,), 0.1)
    power = jnp.full((N,), 0.5)
    assert prob.p_min(a).shape == (N, K)
    assert prob.constraints_satisfied(a, power).shape == (N, K)


def test_per_round_false_rejected_on_fading(fading_problem):
    """A 1-d solve on a fading problem is ill-defined — assert-with-message
    instead of a silent K == N dependence."""
    from repro.core import solve_joint, solve_joint_optimal

    with pytest.raises(ValueError, match="per_round"):
        solve_joint(fading_problem, per_round=False)
    with pytest.raises(ValueError, match="per_round"):
        solve_joint_optimal(fading_problem, per_round=False)


# ------------------------------ interference operand (multi-cell, PR 7)
# the ``interference`` leaf follows the same [N] / [N, K] rank rules as
# every decision-variable operand — the exact bug class ISSUE 5 fixed —
# and its zero must be indistinguishable from "no interference"

I_1D = np.geomspace(1e-13, 5e-11, N).astype(np.float32)   # around sigma^2


@pytest.mark.parametrize("method", sorted(METHODS))
@pytest.mark.parametrize("i_ndim", [1, 2])
@pytest.mark.parametrize("a_ndim,p_ndim", [(1, 1), (1, 2), (2, 1), (2, 2)])
def test_interference_rank_combinations(fading_problem, method, i_ndim,
                                        a_ndim, p_ndim):
    """All (a, power) rank combinations also work with a 1-d or 2-d
    interference leaf, and a 1-d leaf equals its column-broadcast 2-d
    copy bit-for-bit (same round-constant-interference semantics as every
    other 1-d operand)."""
    fn = METHODS[method]
    prob = dataclasses.replace(fading_problem,
                               interference=_ranked(I_1D, i_ndim))
    ref_prob = dataclasses.replace(fading_problem,
                                   interference=_ranked(I_1D, 2))
    out = fn(prob, _ranked(A_1D, a_ndim), _ranked(P_1D, p_ndim))
    ref = fn(ref_prob, _ranked(A_1D, 2), _ranked(P_1D, 2))
    assert out.shape == (N, K)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("method", sorted(METHODS))
@pytest.mark.parametrize("i_ndim", [1, 2])
def test_zero_interference_is_bitwise_noop(fading_problem, method, i_ndim):
    """interference = 0 gives the current no-interference results
    bit-for-bit — multi-cell machinery cannot perturb single-cell
    answers (the solve_coupled identity guarantee builds on this)."""
    fn = METHODS[method]
    zero = dataclasses.replace(
        fading_problem, interference=_ranked(np.zeros(N, np.float32),
                                             i_ndim))
    out = fn(zero, _ranked(A_1D, 1), _ranked(P_1D, 1))
    ref = fn(fading_problem, _ranked(A_1D, 1), _ranked(P_1D, 1))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_zero_interference_rate_bitwise_static():
    """The acceptance pin on a static problem too: zero interference ==
    the current ``rate`` (and path gain) bit-for-bit, shapes unchanged."""
    prob = make_problem("paper_static", seed=0, n_devices=N)
    zero = dataclasses.replace(prob,
                               interference=jnp.zeros((N,), jnp.float32))
    p1 = jnp.asarray(P_1D)
    assert zero.path_gain().shape == (N,)
    np.testing.assert_array_equal(np.asarray(zero.path_gain()),
                                  np.asarray(prob.path_gain()))
    np.testing.assert_array_equal(np.asarray(zero.rate(p1)),
                                  np.asarray(prob.rate(p1)))
    np.testing.assert_array_equal(np.asarray(zero.p_min(jnp.asarray(A_1D))),
                                  np.asarray(prob.p_min(jnp.asarray(A_1D))))


def test_interference_raises_noise_floor():
    """Physics sanity: interference strictly lowers rate (and raises
    p_min) exactly like a higher sigma^2 would — the SINR denominator is
    d^2 (sigma^2 + I)."""
    prob = make_problem("paper_static", seed=0, n_devices=N)
    noisy = dataclasses.replace(prob, interference=jnp.asarray(I_1D))
    p1 = jnp.asarray(P_1D)
    assert np.all(np.asarray(noisy.rate(p1)) < np.asarray(prob.rate(p1)))
    assert np.all(np.asarray(noisy.p_min(jnp.asarray(A_1D)))
                  > np.asarray(prob.p_min(jnp.asarray(A_1D))))
    # equivalent single-cell problem with the noise folded in: for a
    # *uniform* interference level I, sigma^2 + I is just a new sigma^2
    level = 3e-12
    uniform = dataclasses.replace(
        prob, interference=jnp.full((N,), level, jnp.float32))
    folded = dataclasses.replace(prob, noise_power=prob.noise_power + level)
    np.testing.assert_allclose(np.asarray(uniform.rate(p1)),
                               np.asarray(folded.rate(p1)), rtol=1e-6)


# --------------------------------------------------- hypothesis property
# guarded import (not importorskip) so the regression tests above still
# run where hypothesis is unavailable; CI installs it via
# requirements-dev.txt and runs the properties

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised per environment
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def fading_case(draw):
        seed = draw(st.integers(0, 2 ** 31 - 1))
        # fixed (N, K), N != K: arbitrary sizes would recompile per example
        prob = make_problem("drifting_metro", seed=seed, n_devices=8,
                            n_rounds=3,
                            coherence=draw(st.sampled_from([0.0, 0.5, 0.9])))
        rng = np.random.default_rng(seed)
        a = rng.uniform(0.0, 1.0, 8).astype(np.float32)
        p = rng.uniform(1e-3, 1.0, 8).astype(np.float32)
        return prob, a, p

    @given(case=fading_case())
    @settings(max_examples=25, deadline=None)
    def test_1d_equals_column_broadcast_bitwise(case):
        """Property: for every method, the 1-d call equals the explicit
        column-broadcast 2-d call bit-for-bit on random fading problems."""
        prob, a, p = case
        n, k = prob.fading.shape
        a2 = jnp.broadcast_to(jnp.asarray(a)[:, None], (n, k))
        p2 = jnp.broadcast_to(jnp.asarray(p)[:, None], (n, k))
        for name, fn in METHODS.items():
            out = np.asarray(fn(prob, jnp.asarray(a), jnp.asarray(p)))
            ref = np.asarray(fn(prob, a2, p2))
            np.testing.assert_array_equal(out, ref, err_msg=name)

    @given(case=fading_case())
    @settings(max_examples=15, deadline=None)
    def test_constraints_consistent_with_energy_terms(case):
        """constraints_satisfied's energy term routes through
        upload_energy: a solution reported feasible satisfies eq. (7b)
        recomputed by hand."""
        prob, a, p = case
        ok = np.asarray(prob.constraints_satisfied(jnp.asarray(a),
                                                   jnp.asarray(p)))
        eu = np.asarray(prob.upload_energy(jnp.asarray(p)))
        ec = np.asarray(prob.compute_energy())[:, None]
        emax = np.broadcast_to(np.asarray(prob.energy_budget_j)[:, None],
                               eu.shape)
        lhs = a[:, None] * (eu + ec)
        # a reported-feasible element can never violate the hand-computed
        # (7b) bound (the other three constraints are AND-ed on top)
        violated_energy = lhs > emax * (1 + 1e-4) + 1e-9
        assert not (ok & violated_energy).any()


def test_broadcast_sliced_equals_fullwidth_constraints(fading_problem):
    """Mixed ranks: [N] a against [N, K] power and vice versa."""
    a1 = jnp.asarray(A_1D)
    p2 = jnp.broadcast_to(jnp.asarray(P_1D)[:, None], (N, K))
    assert fading_problem.constraints_satisfied(a1, p2).shape == (N, K)
    a2 = jnp.broadcast_to(a1[:, None], (N, K))
    p1 = jnp.asarray(P_1D)
    assert fading_problem.constraints_satisfied(a2, p1).shape == (N, K)


# -------------------------------------------------------------------------
# Defects surfaced by the rank-contract checker's first run
# (repro.analysis.rank): pinned here so they cannot regress.
# -------------------------------------------------------------------------

class TestRankCheckerRegressions:
    """The analysis sweep found two silent contract violations:

    * a rank-1 (round-invariant) ``fading`` draw built an [N, N]
      ``path_gain`` — the ``base[:, None]`` lift ran unconditionally and
      broadcast silently whenever K == N;
    * a rank-2 ``bits`` table raised (or mis-shaped) in ``tx_time`` /
      ``p_min`` when the decision variables stayed rank 1, although the
      contract says the result lifts to the highest rank present.
    """

    def test_rank1_fading_keeps_rank1_path_gain(self, fading_problem):
        pb = dataclasses.replace(fading_problem,
                                 fading=fading_problem.fading[:, 0])
        pg = pb.path_gain()
        assert pg.shape == (N,)
        # and bitwise equals column 0 of the full-width problem
        np.testing.assert_array_equal(
            np.asarray(pg), np.asarray(fading_problem.path_gain()[:, 0]))

    def test_rank1_fading_with_interference(self, fading_problem):
        i2 = jnp.broadcast_to(
            jnp.asarray(np.linspace(1e-13, 5e-13, N), jnp.float32)[:, None],
            (N, K))
        pb1 = dataclasses.replace(fading_problem,
                                  fading=fading_problem.fading[:, 0],
                                  interference=i2[:, 0])
        assert pb1.path_gain().shape == (N,)
        pb2 = dataclasses.replace(fading_problem,
                                  fading=fading_problem.fading[:, 0],
                                  interference=i2)
        assert pb2.path_gain().shape == (N, K)

    @pytest.fixture()
    def bits2_problem(self, fading_problem):
        bits = jnp.asarray(
            8.0 * (1.0 + np.arange(N * K, dtype=np.float32).reshape(N, K)
                   % 3))
        return dataclasses.replace(fading_problem, bits=bits)

    @pytest.mark.parametrize("method,arg", [
        ("tx_time", P_1D), ("upload_energy", P_1D),
        ("round_energy", P_1D), ("p_min", A_1D),
    ])
    def test_bits2_lifts_rank1_args(self, bits2_problem, method, arg):
        """Rank-2 bits + rank-1 decision variable: result is [N, K] and
        every column matches the rank-1 eval on the column-sliced bits."""
        out = getattr(bits2_problem, method)(jnp.asarray(arg))
        assert out.shape == (N, K)
        for col in range(K):
            sliced = dataclasses.replace(
                bits2_problem, bits=bits2_problem.bits[:, col],
                fading=bits2_problem.fading[:, col])
            ref = getattr(sliced, method)(jnp.asarray(arg))
            np.testing.assert_array_equal(np.asarray(ref),
                                          np.asarray(out[:, col]))

    def test_bits2_constraints_mixed_ranks(self, bits2_problem):
        out = bits2_problem.constraints_satisfied(jnp.asarray(A_1D),
                                                  jnp.asarray(P_1D))
        assert out.shape == (N, K)
