"""Static-analysis gate (``repro.analysis``): every pass proves it
*catches* a planted defect (positive fixtures) and stays quiet on
clean/production code (negative fixtures).

Tier-1 keeps the fixtures tiny; the production-scale sweeps (all hot
paths, all PRNG programs, the full rank sweep) run in the CI
``analysis`` job via ``tools/run_analysis.py --gate`` and in the slow
tier here."""
import textwrap

import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (
    HOT_PATHS,
    PRNG_PROGRAMS,
    CompileBudget,
    CompileBudgetExceeded,
    broadcastable_leaves,
    check_key_reuse,
    compile_event_count,
    load_budgets,
    measure,
    sweep_rank_contract,
    weak_scalar_findings,
)
from repro.analysis.hygiene import WAIVER, check_donation, scan_host_syncs
from repro.core.problem import WirelessFLProblem


# ------------------------------------------------------------ recompile

class TestCompileBudget:
    def test_counts_fresh_compile(self):
        """Positive: a jit signature never seen before must be counted.
        Inputs are built *outside* the scope (eager ``jnp.ones`` itself
        compiles tiny programs); an odd prime size keeps the signature
        unique to this test."""
        fn = jax.jit(lambda x: x * 2.0 + 1.0)
        x = jnp.ones((173,))
        with CompileBudget(budget=None, strict=False) as cb:
            fn(x).block_until_ready()
        assert cb.count == 1

    def test_budget_zero_raises_and_names_program(self):
        def distinctly_named_program(x):
            return x - 3.0

        fn = jax.jit(distinctly_named_program)
        x = jnp.ones((179,))
        with pytest.raises(CompileBudgetExceeded) as ei, \
                CompileBudget(budget=0, name="steady"):
            fn(x).block_until_ready()
        assert "steady" in str(ei.value)
        # program names are best-effort (parsed from jax debug logs)
        assert "distinctly_named_program" in str(ei.value)

    def test_cache_hit_is_zero(self):
        """Negative: re-running a compiled signature on fresh same-shaped
        inputs is free — the steady-state contract."""
        fn = jax.jit(lambda x: jnp.sum(x * x))
        # explicit dtype: jnp.full with a bare python fill value is
        # weak-typed, which would fork the signature vs jnp.ones — the
        # very hazard the hygiene pass audits
        a, b = jnp.ones((181,)), jnp.full((181,), 2.0, dtype=jnp.float32)
        fn(a).block_until_ready()
        with CompileBudget(budget=0, name="cache hit"):
            fn(b).block_until_ready()

    def test_does_not_swallow_body_exception(self):
        x = jnp.ones((191,))
        with pytest.raises(ValueError, match="from body"), \
                CompileBudget(budget=0):
            jax.jit(lambda x: x @ x)(x).block_until_ready()
            raise ValueError("from body")

    def test_global_log_is_monotonic(self):
        x = jnp.ones((193,))
        before = compile_event_count()
        jax.jit(lambda x: x + 5)(x).block_until_ready()
        assert compile_event_count() >= before + 1

    def test_budgets_file_covers_every_hot_path(self):
        budgets = load_budgets()
        assert set(budgets) == set(HOT_PATHS)
        assert all(v == 0 for v in budgets.values()), \
            "non-zero steady-state budgets need a justification comment"

    @pytest.mark.slow
    @pytest.mark.parametrize("name", sorted(HOT_PATHS))
    def test_hot_path_steady_state(self, name):
        """Every registered production hot path meets its committed
        budget (the same check the CI analysis job gates)."""
        result = measure(name)
        assert result["steady_compiles"] <= load_budgets()[name], result


# ------------------------------------------------------------------ prng

def _consume(key, shape=()):
    return jax.random.uniform(key, shape)


class TestKeyReuse:
    def test_flags_double_consumption(self):
        """Positive: the same key drawn twice."""
        def bad(key):
            return _consume(key) + _consume(key)

        findings = check_key_reuse(bad, jax.random.PRNGKey(0))
        assert len(findings) == 1
        assert findings[0].n_consumed == 2
        assert findings[0].kind == "reuse"

    def test_split_is_clean(self):
        def good(key):
            k1, k2 = jax.random.split(key)
            return _consume(k1) + _consume(k2)

        assert check_key_reuse(good, jax.random.PRNGKey(0)) == []

    def test_fold_in_collision_flagged_distinct_clean(self):
        def collide(key):
            return (_consume(jax.random.fold_in(key, 7))
                    + _consume(jax.random.fold_in(key, 7)))

        def distinct(key):
            return (_consume(jax.random.fold_in(key, 7))
                    + _consume(jax.random.fold_in(key, 8)))

        assert len(check_key_reuse(collide, jax.random.PRNGKey(0))) == 1
        assert check_key_reuse(distinct, jax.random.PRNGKey(0)) == []

    def test_scan_carry_reuse_flagged(self):
        """Positive: a scan body that consumes its key carry but threads
        it through unchanged reuses it every iteration."""
        def bad_scan(key):
            def body(k, _):
                return k, _consume(k)
            return jax.lax.scan(body, key, jnp.arange(4.0))

        findings = check_key_reuse(bad_scan, jax.random.PRNGKey(0))
        assert any(f.kind == "carry-reuse" for f in findings)

    def test_scan_split_carry_clean(self):
        def good_scan(key):
            def body(k, _):
                k, sub = jax.random.split(k)
                return k, _consume(sub)
            return jax.lax.scan(body, key, jnp.arange(4.0))

        assert check_key_reuse(good_scan, jax.random.PRNGKey(0)) == []

    def test_exclusive_branches_clean(self):
        """cond branches are exclusive: one key consumed in both arms is
        still consumed once per execution."""
        def branchy(key, flag):
            return jax.lax.cond(flag, _consume, lambda k: _consume(k) * 2.0,
                                key)

        assert check_key_reuse(branchy, jax.random.PRNGKey(0),
                               jnp.bool_(True)) == []

    def test_vmapped_split_children_distinct(self):
        """Regression: under vmap the split axis is not axis 0; children
        must still get distinct classes."""
        def vm(keys):
            def one(key):
                k1, k2 = jax.random.split(key)
                return _consume(k1) + _consume(k2)
            return jax.vmap(one)(keys)

        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        assert check_key_reuse(vm, keys) == []

    def test_mask_stream_program_clean(self):
        """Negative (production): the planner's mask preview."""
        assert PRNG_PROGRAMS["mask_stream"]() == []

    @pytest.mark.slow
    @pytest.mark.parametrize("name", sorted(PRNG_PROGRAMS))
    def test_production_programs_clean(self, name):
        assert PRNG_PROGRAMS[name]() == []


# ------------------------------------------------------------------ rank

class _OldPathGainBug(WirelessFLProblem):
    """The pre-fix ``path_gain``: base lifted to ``[:, None]`` whenever
    fading is present, so a rank-1 fading silently builds [N, N]."""

    def path_gain(self):
        if self.fading is None or self.interference is not None:
            return super().path_gain()
        base = 1.0 / (jnp.square(self.distance_m) * self.noise_power)
        return jnp.where(self.fading > 0, self.fading * base[:, None], 0.0)


class _DropsRoundAxisBug(WirelessFLProblem):
    """A method that collapses the round axis of a rank-2 result."""

    def rate(self, power):
        r = super().rate(power)
        return r[:, 0] if r.ndim == 2 else r


class _WrongColumnBug(WirelessFLProblem):
    """Right shape, wrong values: every round repeats column 0 — only
    the bitwise per-column check can see this."""

    def rate(self, power):
        r = super().rate(power)
        return jnp.broadcast_to(r[:, :1], r.shape) if r.ndim == 2 else r


class TestRankContract:
    def test_discovers_all_leaves(self):
        assert set(broadcastable_leaves()) >= {"fading", "interference",
                                               "bits"}

    def test_requires_n_neq_k(self):
        with pytest.raises(ValueError, match="n != k"):
            sweep_rank_contract(n=3, k=3)

    def test_flags_rank1_fading_shape_bug(self):
        """Positive: the exact defect this pass surfaced on its first
        run against the real ``problem.py`` (fixed in this PR)."""
        findings, _ = sweep_rank_contract(
            _OldPathGainBug, methods={"path_gain": ((), "elementwise")})
        assert any(f.kind == "shape" and "(3, 3)" in f.detail
                   for f in findings)

    def test_flags_collapsed_round_axis(self):
        findings, _ = sweep_rank_contract(
            _DropsRoundAxisBug, methods={"rate": (("power",), "elementwise")})
        assert any(f.kind == "shape" for f in findings)

    def test_flags_wrong_column_values(self):
        findings, _ = sweep_rank_contract(
            _WrongColumnBug, methods={"rate": (("power",), "elementwise")})
        assert any(f.kind == "columns" for f in findings)

    def test_clean_on_fixed_library_subset(self):
        """Negative (tier-1 sized): the methods the PR fixed."""
        findings, stats = sweep_rank_contract(methods={
            "path_gain": ((), "elementwise"),
            "tx_time": (("power",), "elementwise"),
            "p_min": (("a",), "elementwise"),
        })
        assert findings == []
        assert stats["n_combos"] > 100

    @pytest.mark.slow
    def test_full_sweep_clean(self):
        findings, stats = sweep_rank_contract()
        assert findings == [], "\n".join(str(f) for f in findings)
        assert stats["n_combos"] == 486


# --------------------------------------------------------------- hygiene

_BAD_MODULE = textwrap.dedent(f"""
    import jax
    import numpy as np

    @jax.jit
    def jitted(x):
        y = float(x)
        z = np.asarray(x)
        waived = x.sum().item()  # {WAIVER}
        return y + z + waived

    def scan_body(c, x):
        return c + x.item(), None

    def run(xs):
        return jax.lax.scan(scan_body, 0.0, xs)

    def untraced(x):
        return float(x)
""")


class TestHostSyncScan:
    @pytest.fixture()
    def bad_tree(self, tmp_path):
        (tmp_path / "mod.py").write_text(_BAD_MODULE)
        return tmp_path

    def test_flags_syncs_in_traced_contexts(self, bad_tree):
        findings, stats = scan_host_syncs(bad_tree)
        details = [f.detail for f in findings]
        assert stats["traced_functions"] == 2  # jitted + scan_body
        assert sum("float()" in d for d in details) == 1
        assert sum("np.asarray" in d for d in details) == 1
        assert sum(".item()" in d for d in details) == 1  # scan_body only

    def test_waiver_and_untraced_are_quiet(self, bad_tree):
        findings, _ = scan_host_syncs(bad_tree)
        src_lines = _BAD_MODULE.splitlines()
        flagged = [src_lines[int(f.site.rsplit(":", 1)[1]) - 1]
                   for f in findings]
        assert not any(WAIVER in line for line in flagged)
        assert not any("untraced" in line for line in flagged)

    def test_production_tree_clean(self):
        findings, stats = scan_host_syncs()
        assert findings == [], "\n".join(str(f) for f in findings)
        assert stats["traced_functions"] > 20


class TestWeakTypeAudit:
    def test_flags_strong_scalar_leaf(self):
        findings = weak_scalar_findings(
            {"lr": jnp.float32(0.1)}, program="fixture")
        assert len(findings) == 1
        assert findings[0].kind == "weak-type"

    def test_quiet_on_weak_and_nonscalar(self):
        clean = {"lr": 0.1, "n": 7, "arr": jnp.ones((3,)),
                 "key": jax.random.PRNGKey(0)}
        assert weak_scalar_findings(clean, program="fixture") == []


class TestDonationAudit:
    @pytest.mark.slow
    def test_sweep_donation_round_trips(self):
        findings, stats = check_donation()
        assert findings == [], "\n".join(str(f) for f in findings)
        assert stats["aliased_outputs"] == stats["params_leaves"] > 0
        assert stats["aliased_outputs_undonated"] == 0
