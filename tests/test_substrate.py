"""Substrate tests: optimizers, checkpointing, LM data, zoo utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.configs.base import INPUT_SHAPES
from repro.data.lm import SyntheticLMData
from repro.models.zoo import grad_size_bits, input_specs, param_count
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm, sgd


class TestOptimizers:
    def _quadratic(self, opt, steps=200):
        params = {"x": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        target = jnp.asarray([1.0, 1.0])
        for _ in range(steps):
            g = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        return float(jnp.max(jnp.abs(params["x"] - target)))

    def test_sgd_converges(self):
        assert self._quadratic(sgd(0.1)) < 1e-3

    def test_momentum_converges(self):
        assert self._quadratic(sgd(0.05, momentum=0.9)) < 1e-3

    def test_adamw_converges(self):
        assert self._quadratic(adamw(0.1), steps=400) < 1e-2

    def test_adamw_state_is_fp32(self):
        params = {"w": jnp.zeros((4,), jnp.bfloat16)}
        opt = adamw(1e-3)
        st = opt.init(params)
        assert st.mu["w"].dtype == jnp.float32

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        cn = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
        np.testing.assert_allclose(cn, 1.0, rtol=1e-5)
        assert float(norm) > 1.0


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.checkpoint import checkpoint as ckpt
        params = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                            "b": jnp.ones((3,))}}
        opt = adamw(1e-3)
        st = opt.init(params)
        ckpt.save(tmp_path, 7, params, st, extra={"note": "hi"})
        step, p2, s2, extra = ckpt.restore(tmp_path, params_template=params,
                                           opt_template=st)
        assert step == 7 and extra["note"] == "hi"
        np.testing.assert_array_equal(np.asarray(p2["layer"]["w"]),
                                      np.asarray(params["layer"]["w"]))
        assert int(s2.count) == 0

    def test_latest_step(self, tmp_path):
        from repro.checkpoint import checkpoint as ckpt
        assert ckpt.latest_step(tmp_path) is None
        p = {"w": jnp.zeros(2)}
        ckpt.save(tmp_path, 1, p)
        ckpt.save(tmp_path, 5, p)
        assert ckpt.latest_step(tmp_path) == 5

    def test_shape_mismatch_raises(self, tmp_path):
        from repro.checkpoint import checkpoint as ckpt
        ckpt.save(tmp_path, 0, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(tmp_path, params_template={"w": jnp.zeros((3, 3))})


class TestSyntheticLM:
    def test_structure_learnable(self):
        data = SyntheticLMData(4, vocab=97, seed=0, noise=0.0)
        b = data.batch(np.array([0, 0]), 64)
        toks, labels = b["tokens"], b["labels"]
        # noiseless recurrence: label fully determined by token
        nxt = (data.mult[0] * toks + data.add[0]) % 97
        np.testing.assert_array_equal(nxt, labels)

    def test_clients_differ(self):
        data = SyntheticLMData(8, vocab=101, seed=1)
        assert len(set(zip(data.mult.tolist(), data.add.tolist()))) > 1

    def test_batch_shapes(self):
        data = SyntheticLMData(4, vocab=50, seed=0)
        b = data.batch(np.array([1, 2, 3]), 32)
        assert b["tokens"].shape == (3, 32)
        assert b["labels"].shape == (3, 32)


class TestZooUtils:
    def test_grad_size_scales_with_params(self):
        small = get_arch("gemma3-1b").reduced()
        big = get_arch("gemma3-1b").reduced(n_layers=2, d_model=512)
        assert grad_size_bits(big) > grad_size_bits(small)
        assert grad_size_bits(small) == param_count(small) * 32

    @pytest.mark.parametrize("arch", sorted(ARCHS))
    @pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
    def test_input_specs_no_allocation(self, arch, shape):
        cfg = ARCHS[arch]
        specs = input_specs(cfg, INPUT_SHAPES[shape])
        for v in jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
            assert isinstance(v, jax.ShapeDtypeStruct)
        if INPUT_SHAPES[shape].mode != "decode":
            b, s = (INPUT_SHAPES[shape].global_batch,
                    INPUT_SHAPES[shape].seq_len)
            text = specs["tokens"].shape[1]
            prefix = (cfg.frontend.n_prefix
                      if cfg.frontend and cfg.frontend.kind == "vision" else 0)
            assert text + prefix == s

    def test_moe_active_less_than_total(self):
        for name in ("deepseek-v2-lite-16b", "llama4-scout-17b-a16e"):
            cfg = ARCHS[name]
            assert param_count(cfg, active_only=True) < param_count(cfg) / 2
