"""Property-based tests (hypothesis) for the solver's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    optimal_selection,
    sample_problem,
    solve_joint,
    solve_joint_optimal,
)


def _problem(seed, n, tau, pmax):
    return sample_problem(seed, n, tau_th=tau, p_max=pmax)


# n is drawn from a tiny set so jax's shape-keyed compilation cache is
# reused across hypothesis examples (arbitrary n => a recompile per example).
problem_strategy = st.builds(
    _problem,
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([8, 32]),
    tau=st.floats(0.01, 2.0),
    pmax=st.floats(0.05, 10.0),
)


@settings(max_examples=25, deadline=None)
@given(problem_strategy)
def test_alternating_always_feasible(problem):
    sol = solve_joint(problem)
    assert bool(problem.constraints_satisfied(sol.a, sol.power, rtol=1e-3).all())
    assert bool(jnp.all((sol.a >= 0) & (sol.a <= 1)))
    assert bool(jnp.all(jnp.isfinite(sol.power)))


@settings(max_examples=25, deadline=None)
@given(problem_strategy)
def test_optimal_dominates_and_feasible(problem):
    alt = solve_joint(problem)
    opt = solve_joint_optimal(problem)
    assert float(opt.objective) >= float(alt.objective) - 1e-6
    assert bool(problem.constraints_satisfied(opt.a, opt.power, rtol=1e-3).all())


@settings(max_examples=20, deadline=None)
@given(problem_strategy, st.floats(1e-3, 1.0), st.floats(1.1, 4.0))
def test_rate_monotone_in_power(problem, p_base, factor):
    p1 = jnp.full((problem.n_devices,), p_base)
    p2 = p1 * factor
    assert bool(jnp.all(problem.rate(p2) > problem.rate(p1)))
    assert bool(jnp.all(problem.tx_time(p2) < problem.tx_time(p1)))


@settings(max_examples=20, deadline=None)
@given(problem_strategy)
def test_selection_monotone_in_budget(problem):
    """Doubling every energy budget can only increase a* (global solver)."""
    import dataclasses
    opt1 = solve_joint_optimal(problem)
    bigger = dataclasses.replace(problem, energy_budget_j=problem.energy_budget_j * 2)
    opt2 = solve_joint_optimal(bigger)
    assert np.all(np.asarray(opt2.a) >= np.asarray(opt1.a) - 1e-5)


@settings(max_examples=20, deadline=None)
@given(problem_strategy)
def test_selection_monotone_in_tau(problem):
    """Relaxing the deadline can only increase a* (global solver)."""
    import dataclasses
    opt1 = solve_joint_optimal(problem)
    relaxed = dataclasses.replace(problem, tau_th=problem.tau_th * 2)
    opt2 = solve_joint_optimal(relaxed)
    assert np.all(np.asarray(opt2.a) >= np.asarray(opt1.a) - 1e-5)


@settings(max_examples=20, deadline=None)
@given(problem_strategy, st.floats(0.001, 1.0))
def test_eq13_output_is_feasible_probability(problem, pfrac):
    p = jnp.full((problem.n_devices,), pfrac * problem.p_max)
    a = optimal_selection(problem, p)
    assert bool(jnp.all((a >= 0) & (a <= 1)))
    assert bool(problem.constraints_satisfied(a, p, rtol=1e-3).all())
