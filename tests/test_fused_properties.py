"""Property-based tests (hypothesis): the fused single-level solver is
``solve_joint`` — same a*, P* and objective to <= 1e-5 — across random
feasible problems including fading, ragged stacked batches with padded
slots self-deselecting, and chunked == unchunked solves."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    sample_problem,
    solve_joint,
    solve_joint_batch,
    solve_joint_fused,
    stack_problems,
)

TOL = 1e-5


def assert_agrees(fused, ref, *, tol=TOL):
    np.testing.assert_allclose(np.asarray(fused.a), np.asarray(ref.a),
                               atol=tol, rtol=0)
    np.testing.assert_allclose(np.asarray(fused.power), np.asarray(ref.power),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(float(fused.objective), float(ref.objective),
                               atol=tol, rtol=0)


def _problem(seed, n, tau, pmax, fading):
    return sample_problem(seed, n, tau_th=tau, p_max=pmax,
                          with_fading=fading, n_rounds=3 if fading else 1)


# n is drawn from a tiny set so jax's shape-keyed compilation cache is
# reused across hypothesis examples (arbitrary n => a recompile per example).
problem_strategy = st.builds(
    _problem,
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([8, 32]),
    tau=st.floats(0.01, 2.0),
    pmax=st.floats(0.05, 10.0),
    fading=st.booleans(),
)


@settings(max_examples=25, deadline=None)
@given(problem_strategy)
def test_fused_matches_solve_joint(problem):
    assert_agrees(solve_joint_fused(problem), solve_joint(problem))


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2**31 - 1),
                          st.sampled_from([8, 16, 24])),
                min_size=2, max_size=5))
def test_fused_batch_ragged_property(specs):
    probs = [sample_problem(seed, n) for seed, n in specs]
    batch = stack_problems(probs)
    sol = solve_joint_batch(batch, method="fused")
    for b, prob in enumerate(probs):
        assert_agrees(sol.instance(b), solve_joint(prob))
    # padded slots self-deselect
    pad = ~np.asarray(batch.mask)
    assert np.all(np.asarray(sol.a)[pad] == 0.0)
    assert np.all(np.asarray(sol.power)[pad] == 0.0)


@settings(max_examples=15, deadline=None)
@given(problem_strategy, st.sampled_from([32, 100, 4096]))
def test_fused_chunked_matches_unchunked(problem, chunk):
    ref = solve_joint_fused(problem)
    sol = solve_joint_fused(problem, chunk_elements=chunk)
    np.testing.assert_allclose(np.asarray(sol.a), np.asarray(ref.a),
                               atol=1e-6, rtol=0)
    np.testing.assert_allclose(np.asarray(sol.power), np.asarray(ref.power),
                               atol=1e-6, rtol=1e-6)
