"""Fleet control-plane service (``repro.serve``): correctness of the
micro-batched, warm-started serving loop against direct solves, cache
behaviour, slot padding, compatibility grouping and accounting."""
import numpy as np
import pytest

from repro.core import make_problem, sample_problem, slice_round, solve_joint_fused
from repro.serve import (
    FleetControlService,
    ServiceConfig,
    SolveResponse,
    quantized_problem_key,
)


def drift_cells(n_cells, n_devices, n_rounds, seed0=0):
    return [make_problem("drifting_metro", seed=s, n_devices=n_devices,
                         n_rounds=n_rounds) for s in range(seed0, seed0 + n_cells)]


class TestServiceCorrectness:
    @pytest.mark.parametrize("power_solver", ["analytic", "dinkelbach"])
    def test_matches_direct_solves(self, power_solver):
        cells = drift_cells(3, 16, 4)
        svc = FleetControlService(ServiceConfig(max_batch=4,
                                                power_solver=power_solver))
        for k in range(4):
            responses = svc.run([(c, slice_round(p, k))
                                 for c, p in enumerate(cells)])
            assert len(responses) == 3
            for r in responses:
                ref = solve_joint_fused(slice_round(cells[r.cell_id], k),
                                        power_solver=power_solver)
                # 1e-5, the repo-wide solver agreement tolerance: the
                # batched warm program is a different XLA fusion than the
                # direct jit, so f32 noise at the p_max clip boundary is
                # expected
                np.testing.assert_allclose(np.asarray(r.solution.a),
                                           np.asarray(ref.a), atol=1e-5)
                np.testing.assert_allclose(np.asarray(r.solution.power),
                                           np.asarray(ref.power),
                                           atol=1e-5, rtol=1e-5)

    def test_ragged_requests_one_batch(self):
        probs = [sample_problem(i, n) for i, n in enumerate([5, 12, 9])]
        svc = FleetControlService(ServiceConfig(max_batch=4))
        responses = svc.run(list(enumerate(probs)))
        assert len(responses) == 3
        for r in responses:
            assert r.solution.a.shape == (probs[r.cell_id].n_devices,)
            ref = solve_joint_fused(probs[r.cell_id])
            np.testing.assert_allclose(np.asarray(r.solution.a),
                                       np.asarray(ref.a), atol=1e-6)

    def test_incompatible_statics_split_batches(self):
        a = sample_problem(0, 8, tau_th=0.08)
        b = sample_problem(1, 8, tau_th=0.5)   # different static tau
        svc = FleetControlService(ServiceConfig(max_batch=8))
        svc.submit("a", a)
        svc.submit("b", b)
        first = svc.step()
        assert [r.cell_id for r in first] == ["a"]
        assert svc.pending == 1
        second = svc.step()
        assert [r.cell_id for r in second] == ["b"]
        assert svc.stats.n_batches == 2

    def test_queue_overflow_multiple_steps(self):
        probs = [sample_problem(i, 8) for i in range(5)]
        svc = FleetControlService(ServiceConfig(max_batch=2))
        out = svc.run(list(enumerate(probs)))
        assert len(out) == 5
        assert svc.stats.n_batches == 3


class TestWarmCache:
    def test_identical_resubmit_hits_feature_cache(self):
        prob = sample_problem(0, 12)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        (r1,) = svc.run([("cell", prob)])
        assert not r1.warm_started
        (r2,) = svc.run([("cell", prob)])
        assert r2.warm_started and r2.cache_hit
        np.testing.assert_array_equal(np.asarray(r1.solution.a),
                                      np.asarray(r2.solution.a))

    def test_feature_cache_shared_across_cells(self):
        prob = sample_problem(0, 12)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("cell-a", prob)])
        (r,) = svc.run([("cell-b", prob)])   # same features, new cell
        assert r.warm_started and r.cache_hit

    def test_drifted_channel_falls_back_to_cell_cache(self):
        prob = make_problem("drifting_metro", seed=0, n_devices=12,
                            n_rounds=2, coherence=0.5)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("cell", slice_round(prob, 0))])
        (r,) = svc.run([("cell", slice_round(prob, 1))])
        assert r.warm_started and not r.cache_hit

    def test_warm_start_disabled(self):
        prob = sample_problem(0, 12)
        svc = FleetControlService(ServiceConfig(max_batch=2,
                                                warm_start=False))
        svc.run([("cell", prob)])
        (r,) = svc.run([("cell", prob)])
        assert not r.warm_started

    def test_lru_eviction(self):
        svc = FleetControlService(ServiceConfig(max_batch=2, cache_size=2))
        probs = [sample_problem(i, 8) for i in range(3)]
        for i, p in enumerate(probs):
            svc.run([(i, p)])
        (r0,) = svc.run([(0, probs[0])])     # evicted by 1 and 2
        assert not r0.warm_started
        (r2,) = svc.run([(2, probs[2])])     # still resident
        assert r2.warm_started

    def test_fleet_size_change_is_cold(self):
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("cell", sample_problem(0, 8))])
        (r,) = svc.run([("cell", sample_problem(0, 12))])
        assert not r.warm_started

    def test_warm_iteration_drop_dinkelbach(self):
        """The service-level acceptance check: warm inner iterations per
        micro-batch measurably below cold on the drifting stream."""
        cells = drift_cells(4, 24, 6)

        def run(warm):
            svc = FleetControlService(ServiceConfig(
                max_batch=4, power_solver="dinkelbach", warm_start=warm))
            for k in range(6):
                svc.run([(c, slice_round(p, k))
                         for c, p in enumerate(cells)])
                if k == 0:
                    svc.stats.reset()
            return svc.stats.mean_inner_iters

        warm_iters, cold_iters = run(True), run(False)
        assert warm_iters <= 0.5 * cold_iters


class TestQuantizedKey:
    def test_row_keys_match_per_problem_function(self):
        """The service's batch-level key computation must reproduce
        ``quantized_problem_key`` exactly, or cache hits would depend on
        which path computed the key."""
        probs = [sample_problem(i, n) for i, n in enumerate([6, 10, 8])]
        svc = FleetControlService(ServiceConfig(max_batch=4))
        responses = svc.run(list(enumerate(probs)))
        assert len(responses) == 3
        for i, p in enumerate(probs):
            key = quantized_problem_key(p)
            assert svc._feature_cache.get(key) is not None, i

    def test_key_stability_and_sensitivity(self):
        p = sample_problem(0, 16)
        assert quantized_problem_key(p) == quantized_problem_key(p)
        other = sample_problem(1, 16)
        assert quantized_problem_key(p) != quantized_problem_key(other)

    def test_key_quantisation_buckets_small_drift(self):
        import dataclasses
        import jax.numpy as jnp
        p = sample_problem(0, 16)
        nudged = dataclasses.replace(
            p, energy_budget_j=p.energy_budget_j * 1.0001)
        far = dataclasses.replace(
            p, energy_budget_j=jnp.asarray(p.energy_budget_j * 2.0))
        assert quantized_problem_key(p) == quantized_problem_key(nudged)
        assert quantized_problem_key(p) != quantized_problem_key(far)


class TestStats:
    def test_summary_fields(self):
        cells = drift_cells(2, 8, 3)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        for k in range(3):
            svc.run([(c, slice_round(p, k)) for c, p in enumerate(cells)])
        s = svc.stats.summary()
        assert s["requests"] == s["solved"] == 6
        assert s["batches"] == 3
        assert s["solves_per_sec"] > 0
        assert 0 < s["p50_latency_s"] <= s["p99_latency_s"]
        assert 0 < s["warm_fraction"] <= 1
        assert s["mean_outer_iters"] >= 1

    def test_reset(self):
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("c", sample_problem(0, 8))])
        svc.stats.reset()
        assert svc.stats.n_solved == 0
        assert svc.stats.summary()["solves_per_sec"] == 0.0
        # caches survive a stats reset
        (r,) = svc.run([("c", sample_problem(0, 8))])
        assert isinstance(r, SolveResponse) and r.warm_started
