"""Fleet control-plane service (``repro.serve``): correctness of the
micro-batched, warm-started serving loop against direct solves, cache
behaviour, slot padding, compatibility grouping and accounting — plus
the open-loop control plane: the batch-close policy, deadline stamping
and miss accounting, priority-lane preemption, `_next_pow2` /
`latency_percentile` edge semantics, and the AOT warmup guarantee (the
first post-warmup request pays no trace spike)."""
import math

import numpy as np
import pytest

from repro.core import make_problem, sample_problem, slice_round, solve_joint_fused
from repro.serve import (
    CLOSE_DEADLINE,
    CLOSE_FORCED,
    CLOSE_FULL,
    CLOSE_LINGER,
    FleetControlService,
    ServiceConfig,
    ServiceStats,
    SolveRequest,
    SolveResponse,
    batch_close_reason,
    quantized_problem_key,
)
from repro.serve.fleet_service import _next_pow2


def drift_cells(n_cells, n_devices, n_rounds, seed0=0):
    return [make_problem("drifting_metro", seed=s, n_devices=n_devices,
                         n_rounds=n_rounds) for s in range(seed0, seed0 + n_cells)]


class TestServiceCorrectness:
    @pytest.mark.parametrize("power_solver", ["analytic", "dinkelbach"])
    def test_matches_direct_solves(self, power_solver):
        cells = drift_cells(3, 16, 4)
        svc = FleetControlService(ServiceConfig(max_batch=4,
                                                power_solver=power_solver))
        for k in range(4):
            responses = svc.run([(c, slice_round(p, k))
                                 for c, p in enumerate(cells)])
            assert len(responses) == 3
            for r in responses:
                ref = solve_joint_fused(slice_round(cells[r.cell_id], k),
                                        power_solver=power_solver)
                # 1e-5, the repo-wide solver agreement tolerance: the
                # batched warm program is a different XLA fusion than the
                # direct jit, so f32 noise at the p_max clip boundary is
                # expected
                np.testing.assert_allclose(np.asarray(r.solution.a),
                                           np.asarray(ref.a), atol=1e-5)
                np.testing.assert_allclose(np.asarray(r.solution.power),
                                           np.asarray(ref.power),
                                           atol=1e-5, rtol=1e-5)

    def test_ragged_requests_one_batch(self):
        probs = [sample_problem(i, n) for i, n in enumerate([5, 12, 9])]
        svc = FleetControlService(ServiceConfig(max_batch=4))
        responses = svc.run(list(enumerate(probs)))
        assert len(responses) == 3
        for r in responses:
            assert r.solution.a.shape == (probs[r.cell_id].n_devices,)
            ref = solve_joint_fused(probs[r.cell_id])
            np.testing.assert_allclose(np.asarray(r.solution.a),
                                       np.asarray(ref.a), atol=1e-6)

    def test_incompatible_statics_split_batches(self):
        a = sample_problem(0, 8, tau_th=0.08)
        b = sample_problem(1, 8, tau_th=0.5)   # different static tau
        svc = FleetControlService(ServiceConfig(max_batch=8))
        svc.submit("a", a)
        svc.submit("b", b)
        first = svc.step()
        assert [r.cell_id for r in first] == ["a"]
        assert svc.pending == 1
        second = svc.step()
        assert [r.cell_id for r in second] == ["b"]
        assert svc.stats.n_batches == 2

    def test_queue_overflow_multiple_steps(self):
        probs = [sample_problem(i, 8) for i in range(5)]
        svc = FleetControlService(ServiceConfig(max_batch=2))
        out = svc.run(list(enumerate(probs)))
        assert len(out) == 5
        assert svc.stats.n_batches == 3


class TestWarmCache:
    def test_identical_resubmit_hits_feature_cache(self):
        prob = sample_problem(0, 12)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        (r1,) = svc.run([("cell", prob)])
        assert not r1.warm_started
        (r2,) = svc.run([("cell", prob)])
        assert r2.warm_started and r2.cache_hit
        np.testing.assert_array_equal(np.asarray(r1.solution.a),
                                      np.asarray(r2.solution.a))

    def test_feature_cache_shared_across_cells(self):
        prob = sample_problem(0, 12)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("cell-a", prob)])
        (r,) = svc.run([("cell-b", prob)])   # same features, new cell
        assert r.warm_started and r.cache_hit

    def test_drifted_channel_falls_back_to_cell_cache(self):
        prob = make_problem("drifting_metro", seed=0, n_devices=12,
                            n_rounds=2, coherence=0.5)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("cell", slice_round(prob, 0))])
        (r,) = svc.run([("cell", slice_round(prob, 1))])
        assert r.warm_started and not r.cache_hit

    def test_warm_start_disabled(self):
        prob = sample_problem(0, 12)
        svc = FleetControlService(ServiceConfig(max_batch=2,
                                                warm_start=False))
        svc.run([("cell", prob)])
        (r,) = svc.run([("cell", prob)])
        assert not r.warm_started

    def test_lru_eviction(self):
        svc = FleetControlService(ServiceConfig(max_batch=2, cache_size=2))
        probs = [sample_problem(i, 8) for i in range(3)]
        for i, p in enumerate(probs):
            svc.run([(i, p)])
        (r0,) = svc.run([(0, probs[0])])     # evicted by 1 and 2
        assert not r0.warm_started
        (r2,) = svc.run([(2, probs[2])])     # still resident
        assert r2.warm_started

    def test_fleet_size_change_is_cold(self):
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("cell", sample_problem(0, 8))])
        (r,) = svc.run([("cell", sample_problem(0, 12))])
        assert not r.warm_started

    def test_warm_iteration_drop_dinkelbach(self):
        """The service-level acceptance check: warm inner iterations per
        micro-batch measurably below cold on the drifting stream."""
        cells = drift_cells(4, 24, 6)

        def run(warm):
            svc = FleetControlService(ServiceConfig(
                max_batch=4, power_solver="dinkelbach", warm_start=warm))
            for k in range(6):
                svc.run([(c, slice_round(p, k))
                         for c, p in enumerate(cells)])
                if k == 0:
                    svc.stats.reset()
            return svc.stats.mean_inner_iters

        warm_iters, cold_iters = run(True), run(False)
        assert warm_iters <= 0.5 * cold_iters


class TestQuantizedKey:
    def test_row_keys_match_per_problem_function(self):
        """The service's batch-level key computation must reproduce
        ``quantized_problem_key`` exactly, or cache hits would depend on
        which path computed the key."""
        probs = [sample_problem(i, n) for i, n in enumerate([6, 10, 8])]
        svc = FleetControlService(ServiceConfig(max_batch=4))
        responses = svc.run(list(enumerate(probs)))
        assert len(responses) == 3
        for i, p in enumerate(probs):
            key = quantized_problem_key(p)
            assert svc._feature_cache.get(key) is not None, i

    def test_key_stability_and_sensitivity(self):
        p = sample_problem(0, 16)
        assert quantized_problem_key(p) == quantized_problem_key(p)
        other = sample_problem(1, 16)
        assert quantized_problem_key(p) != quantized_problem_key(other)

    def test_key_quantisation_buckets_small_drift(self):
        import dataclasses
        import jax.numpy as jnp
        p = sample_problem(0, 16)
        nudged = dataclasses.replace(
            p, energy_budget_j=p.energy_budget_j * 1.0001)
        far = dataclasses.replace(
            p, energy_budget_j=jnp.asarray(p.energy_budget_j * 2.0))
        assert quantized_problem_key(p) == quantized_problem_key(nudged)
        assert quantized_problem_key(p) != quantized_problem_key(far)


class TestStats:
    def test_summary_fields(self):
        cells = drift_cells(2, 8, 3)
        svc = FleetControlService(ServiceConfig(max_batch=2))
        for k in range(3):
            svc.run([(c, slice_round(p, k)) for c, p in enumerate(cells)])
        s = svc.stats.summary()
        assert s["requests"] == s["solved"] == 6
        assert s["batches"] == 3
        assert s["solves_per_sec"] > 0
        assert 0 < s["p50_latency_s"] <= s["p99_latency_s"]
        assert 0 < s["warm_fraction"] <= 1
        assert s["mean_outer_iters"] >= 1

    def test_reset(self):
        svc = FleetControlService(ServiceConfig(max_batch=2))
        svc.run([("c", sample_problem(0, 8))])
        svc.stats.reset()
        assert svc.stats.n_solved == 0
        assert svc.stats.summary()["solves_per_sec"] == 0.0
        # caches survive a stats reset
        (r,) = svc.run([("c", sample_problem(0, 8))])
        assert isinstance(r, SolveResponse) and r.warm_started


class TestNextPow2:
    """`_next_pow2` floor semantics (ISSUE satellite): every bucket the
    service registers must be a true power of two, including the floor."""

    @pytest.mark.parametrize("n,floor,expect", [
        (0, 1, 1), (1, 1, 1), (2, 1, 2), (3, 1, 4), (8, 1, 8),
        (9, 1, 16), (1000, 1, 1024),
        # the floor itself rounds UP to a power of two
        (1, 12, 16), (5, 12, 16), (20, 12, 32),
        (1, 8, 8), (64, 8, 64), (65, 8, 128),
        (0, 0, 1),
    ])
    def test_values(self, n, floor, expect):
        assert _next_pow2(n, floor) == expect

    def test_always_power_of_two_and_bounds(self):
        for n in range(0, 70):
            for floor in (1, 3, 8, 12):
                b = _next_pow2(n, floor)
                assert b & (b - 1) == 0 and b >= 1
                assert b >= n and b >= min(floor, b)  # covers n
                # minimal: halving would no longer cover max(n, floor, 1)
                assert b == 1 or b // 2 < max(n, floor, 1)


class TestLatencyPercentile:
    """Empty-window / single-sample / interpolation / window-edge
    semantics of ``ServiceStats.latency_percentile`` (ISSUE satellite)."""

    def test_empty_window_is_nan_not_zero(self):
        s = ServiceStats()
        for q in (0, 50, 99, 100):
            assert math.isnan(s.latency_percentile(q))
        assert math.isnan(s.summary()["p50_latency_s"])

    def test_single_sample_every_quantile(self):
        s = ServiceStats()
        s.latencies.append(0.25)
        for q in (0, 50, 99, 100):
            assert s.latency_percentile(q) == 0.25

    def test_linear_interpolation(self):
        s = ServiceStats()
        s.latencies.extend([0.0, 1.0])
        assert s.latency_percentile(50) == 0.5      # midpoint of 2 samples
        s.latencies.append(2.0)
        assert s.latency_percentile(50) == 1.0
        assert s.latency_percentile(25) == 0.5
        assert s.latency_percentile(100) == 2.0

    def test_window_edge_evicts_oldest(self):
        s = ServiceStats(latency_window=4)
        for v in [100.0, 100.0, 1.0, 2.0, 3.0, 4.0]:
            s.latencies.append(v)
        # the two 100.0 outliers fell off the edge
        assert s.latency_percentile(100) == 4.0
        assert s.latency_percentile(50) == 2.5

    def test_reset_returns_to_nan(self):
        s = ServiceStats()
        s.latencies.append(1.0)
        s.reset()
        assert math.isnan(s.latency_percentile(50))


def _req(seq, t_submit, deadline=math.inf, ckey=0, priority=False):
    """A synthetic queue entry for pure policy tests (no solve)."""
    return SolveRequest(cell_id=seq, problem=None, t_submit=t_submit,
                        t_deadline=deadline, priority=priority,
                        fkey=None, ckey=ckey, seq=seq)


class TestClosePolicy:
    """Deterministic unit tests of ``batch_close_reason`` — the
    hypothesis suite (tests/test_openloop_properties.py) generalises
    these to random batches."""

    CFG = ServiceConfig(max_batch=4, close_safety=1.5, max_linger_s=5e-3)

    def test_empty_batch_never_closes(self):
        assert batch_close_reason([], 0.0, 1.0, self.CFG) is None

    def test_full_wins(self):
        batch = [_req(i, 0.0) for i in range(4)]
        assert batch_close_reason(batch, 0.0, 1e-3, self.CFG) == CLOSE_FULL

    def test_deadline_close_at_safety_margin(self):
        batch = [_req(0, 0.0, deadline=1.0)]
        # budget 1.0 > 1.5 * cost 0.1 -> keep accumulating
        assert batch_close_reason(batch, 0.0, 0.1, self.CFG) is None
        # budget 0.15 == 1.5 * 0.1 -> close now
        assert batch_close_reason(batch, 0.85, 0.1, self.CFG) == CLOSE_DEADLINE
        # tightest deadline in the batch governs, not the oldest request
        batch = [_req(0, 0.0, deadline=10.0), _req(1, 0.1, deadline=1.0)]
        assert batch_close_reason(batch, 0.85, 0.1, self.CFG) == CLOSE_DEADLINE

    def test_linger_bounds_deadline_less_traffic(self):
        batch = [_req(0, 0.0)]
        assert batch_close_reason(batch, 4e-3, 1e-4, self.CFG) is None
        assert batch_close_reason(batch, 5e-3, 1e-4, self.CFG) == CLOSE_LINGER

    def test_none_means_every_rule_has_slack(self):
        batch = [_req(0, 0.0, deadline=1.0), _req(1, 1e-3, deadline=2.0)]
        reason = batch_close_reason(batch, 2e-3, 1e-3, self.CFG)
        assert reason is None
        assert len(batch) < self.CFG.max_batch
        assert min(r.t_deadline for r in batch) - 2e-3 \
            > self.CFG.close_safety * 1e-3
        assert 2e-3 - batch[0].t_submit < self.CFG.max_linger_s


class TestOpenLoop:
    """`submit`/`poll` on a virtual clock: deadline stamping, close
    accounting, miss detection, FIFO, priority preemption, drain."""

    def _svc(self, **kw):
        base = dict(max_batch=4, cost_smoothing=0.0, prior_solve_s=0.01,
                    close_safety=1.0, max_linger_s=10.0)
        base.update(kw)
        return FleetControlService(ServiceConfig(**base))

    def test_poll_waits_then_deadline_closes(self):
        svc = self._svc()
        svc.submit("a", sample_problem(0, 8), deadline_s=1.0, now=0.0)
        assert svc.poll(0.0) == []          # budget 1.0 > 1.0 * 0.01
        assert svc.poll(0.5) == []
        out = svc.poll(0.995)               # budget 0.005 <= est cost 0.01
        assert [r.cell_id for r in out] == ["a"]
        assert not out[0].deadline_missed
        assert out[0].latency_s == pytest.approx(0.995)
        assert svc.stats.closes == {CLOSE_DEADLINE: 1}

    def test_poll_linger_close(self):
        svc = self._svc(max_linger_s=5e-3)
        svc.submit("a", sample_problem(0, 8), now=0.0)   # no deadline
        assert svc.poll(0.004) == []
        out = svc.poll(0.006)
        assert len(out) == 1
        assert svc.stats.closes == {CLOSE_LINGER: 1}

    def test_poll_full_close_immediate(self):
        svc = self._svc(max_batch=2)
        p = sample_problem(0, 8)
        svc.submit("a", p, deadline_s=100.0, now=0.0)
        svc.submit("b", p, deadline_s=100.0, now=0.0)
        out = svc.poll(0.0)
        assert [r.cell_id for r in out] == ["a", "b"]
        assert svc.stats.closes == {CLOSE_FULL: 1}

    def test_deadline_miss_accounted(self):
        svc = self._svc()
        svc.submit("late", sample_problem(0, 8), deadline_s=0.01, now=0.0)
        out = svc.poll(5.0)                 # polled far past the deadline
        assert out[0].deadline_missed
        assert svc.stats.n_deadline_misses == 1
        assert svc.stats.deadline_miss_rate == 1.0
        assert svc.stats.summary()["deadline_miss_rate"] == 1.0

    def test_default_deadline_from_config(self):
        svc = self._svc(default_deadline_s=0.25)
        req = svc.submit("a", sample_problem(0, 8), now=1.0)
        assert req.t_deadline == pytest.approx(1.25)
        req2 = svc.submit("b", sample_problem(1, 8), now=1.0,
                          deadline_s=0.5)   # explicit budget overrides
        assert req2.t_deadline == pytest.approx(1.5)

    def test_unbounded_deadline_is_inf(self):
        svc = self._svc()
        req = svc.submit("a", sample_problem(0, 8), now=0.0)
        assert req.t_deadline == math.inf

    def test_fifo_order_within_lane(self):
        svc = self._svc(max_batch=2)
        probs = [sample_problem(i, 8) for i in range(5)]
        for i, p in enumerate(probs):
            svc.submit(i, p, now=0.0)
        out = svc.run()
        assert [r.cell_id for r in out] == [0, 1, 2, 3, 4]
        assert [r.seq for r in out] == sorted(r.seq for r in out)

    def test_drifted_cell_preempts_stale_traffic(self):
        prob = make_problem("drifting_metro", seed=0, n_devices=12,
                            n_rounds=2, coherence=0.5)
        r0, r1 = slice_round(prob, 0), slice_round(prob, 1)
        svc = self._svc(max_batch=1)
        svc.run([("stale", r0), ("drift", r0)])   # prime both cells
        # "stale" resubmits the identical round (fkey matches -> normal
        # lane); "drift" moved a round (fkey went stale -> priority lane)
        svc.submit("stale", r0, now=0.0)
        svc.submit("drift", r1, now=0.0)
        first = svc.step(now=0.0)
        assert [r.cell_id for r in first] == ["drift"]
        assert svc.stats.n_preemptions == 1
        assert first[0].warm_started and not first[0].cache_hit
        second = svc.step(now=0.0)
        assert [r.cell_id for r in second] == ["stale"]
        assert second[0].cache_hit
        assert svc.stats.n_priority == 1

    def test_explicit_priority_flag(self):
        svc = self._svc(max_batch=1)
        p = sample_problem(0, 8)
        svc.submit("normal", p, now=0.0)
        svc.submit("vip", sample_problem(1, 8), now=0.0, priority=True)
        out = svc.step(now=0.0)
        assert [r.cell_id for r in out] == ["vip"]
        assert svc.stats.n_preemptions == 1

    def test_fresh_cell_is_not_priority(self):
        svc = self._svc()
        req = svc.submit("new-cell", sample_problem(0, 8), now=0.0)
        assert not req.priority

    def test_drain_terminates_and_serves_exactly_once(self):
        svc = self._svc(max_batch=2)
        # incompatible statics interleaved with compatible ones
        probs = [sample_problem(0, 8), sample_problem(1, 8, tau_th=0.5),
                 sample_problem(2, 8), sample_problem(3, 8, tau_th=0.5),
                 sample_problem(4, 8)]
        for i, p in enumerate(probs):
            svc.submit(i, p, now=0.0)
        out = svc.run()
        assert sorted(r.cell_id for r in out) == [0, 1, 2, 3, 4]
        assert svc.pending == 0
        assert all(c == CLOSE_FORCED for c in svc.stats.closes)

    def test_forced_step_empty_queue(self):
        svc = self._svc()
        assert svc.step() == []
        assert svc.poll(0.0) == []


class TestWarmup:
    def test_warmup_registers_pow2_buckets(self):
        svc = FleetControlService(ServiceConfig(max_batch=2,
                                                min_device_bucket=8))
        timings = svc.warmup(sample_problem(0, 20), max_devices=20)
        assert set(timings) == {8, 16, 32} == svc.warmed_buckets
        assert all(t > 0 for t in timings.values())
        # live traffic then only uses warmed buckets
        svc.run([(0, sample_problem(1, 20)), (1, sample_problem(2, 6))])
        assert svc.buckets_used <= svc.warmed_buckets
        # and warmup touched neither stats nor caches
        assert svc.stats.n_requests == 2

    def test_first_request_after_warmup_no_trace_spike(self):
        """ISSUE acceptance: the first post-warmup request's latency is
        within 3x the steady-state p50 — no compile/trace spike.  A
        unique ``max_iters`` forces fresh jit signatures, so warmup (not
        an earlier test) is what pre-compiled them."""
        cells = drift_cells(4, 24, 4, seed0=50)
        svc = FleetControlService(ServiceConfig(max_batch=4, max_iters=41))
        svc.warmup(slice_round(cells[0], 0))
        (first,) = svc.run([(0, slice_round(cells[0], 0))])
        svc.stats.reset()
        for k in range(4):
            svc.run([(c, slice_round(p, k)) for c, p in enumerate(cells)])
        p50 = svc.stats.latency_percentile(50)
        # floor p50 at 1ms: a trace spike is O(100ms), scheduler jitter
        # on a sub-ms p50 is not
        assert first.latency_s <= 3.0 * max(p50, 1e-3), \
            f"first={first.latency_s:.4f}s p50={p50:.4f}s"

    @pytest.mark.slow
    def test_zero_compiles_after_warmup(self):
        """The recompile sentinel makes the warmup contract exact: after
        ``warmup()``, two full rounds of live submit/step traffic (the
        second exercising the warm-start signature) build ZERO new XLA
        programs — not merely "no visible latency spike"."""
        from repro.analysis import CompileBudget

        svc = FleetControlService(ServiceConfig(max_batch=4, max_iters=43,
                                                cost_smoothing=0.0))
        svc.warmup(sample_problem(0, 24), max_devices=24)
        rounds = [[sample_problem(1000 * r + c, 24) for c in range(3)]
                  for r in range(2)]
        with CompileBudget(budget=0, name="fleet post-warmup"):
            now = 0.0
            for round_problems in rounds:
                for c, prob in enumerate(round_problems):
                    now += 1e-4
                    svc.submit(f"cell-{c}", prob, now=now)
                svc.step(now=now)

    def test_unwarmed_first_request_eats_trace(self):
        """The contrast run: same stream shape, fresh jit signature, no
        warmup — the first request visibly pays the compile."""
        cells = drift_cells(4, 24, 4, seed0=60)
        svc = FleetControlService(ServiceConfig(max_batch=4, max_iters=42))
        (first,) = svc.run([(0, slice_round(cells[0], 0))])
        svc.stats.reset()
        for k in range(4):
            svc.run([(c, slice_round(p, k)) for c, p in enumerate(cells)])
        p50 = svc.stats.latency_percentile(50)
        assert first.latency_s > 10.0 * max(p50, 1e-3), \
            f"first={first.latency_s:.4f}s p50={p50:.4f}s"
