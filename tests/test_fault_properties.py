"""Property-based chaos tests (requires ``hypothesis``; skipped if absent).

Under *any* seeded ``FaultPlan`` the control plane must uphold three
invariants:

1. every response carries finite selection probabilities and powers —
   corruption is absorbed at the ``submit()`` boundary, never echoed;
2. every arrival gets exactly one response (degrade, never hang);
3. a fault-free request sharing the service with faulted cohabitants is
   answered as if they were not there — bitwise when the cohabitant is
   fully corrupted (it sanitises to neutral padding rows), and to
   solver tolerance otherwise (see ``docs/robustness.md``).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.scenarios import make_problem, slice_round  # noqa: E402
from repro.serve import (  # noqa: E402
    CHANNEL_KINDS,
    FaultPlan,
    FleetControlService,
    ServiceConfig,
    chaos_drive,
    corrupt_problem,
    make_cells,
    poisson_trace,
)

N = 8

fault_plans = st.builds(
    FaultPlan,
    kinds=st.sets(st.sampled_from(CHANNEL_KINDS), min_size=1).map(tuple),
    seed=st.integers(0, 2**16),
    fault_rate=st.floats(0.05, 1.0),
    device_rate=st.floats(0.05, 1.0),
    deep_fade_db=st.floats(20.0, 120.0),
)


@settings(max_examples=10, deadline=None)
@given(plan=fault_plans, trace_seed=st.integers(0, 2**16))
def test_chaos_drive_finite_and_complete(plan, trace_seed):
    cells = make_cells(2, n_devices=N, n_rounds=2, seed=0)
    trace = poisson_trace(cells, rate_hz=500.0, n_requests=8,
                          seed=trace_seed)
    svc = FleetControlService(ServiceConfig())
    rep = chaos_drive(svc, trace, plan)
    assert len(rep.report.responses) == len(trace)
    assert rep.nan_escapes == 0
    for r in rep.report.responses:
        assert np.isfinite(np.asarray(r.solution.a)).all()
        assert np.isfinite(np.asarray(r.solution.power)).all()


@settings(max_examples=10, deadline=None)
@given(kind=st.sampled_from(CHANNEL_KINDS), seed=st.integers(0, 2**16))
def test_fully_faulted_cohabitant_is_bitwise_invisible(kind, seed):
    prob = slice_round(make_problem("drifting_metro", seed=0,
                                    n_devices=N, n_rounds=2), 0)
    bad = corrupt_problem(prob, kind, rng=np.random.default_rng(seed),
                          device_rate=1.0)
    solo, = FleetControlService(ServiceConfig()).run([("clean", prob)])
    both = FleetControlService(ServiceConfig()).run(
        [("clean", prob), ("bad", bad)])
    co = next(r for r in both if r.cell_id == "clean")
    if kind == "deep_fade":
        # deep fades keep devices *healthy* (finite gains), so the
        # cohabitant genuinely participates: tolerance, not bitwise
        assert np.allclose(solo.solution.a, co.solution.a, atol=1e-5)
    else:
        assert np.array_equal(np.asarray(solo.solution.a),
                              np.asarray(co.solution.a))
        assert np.array_equal(np.asarray(solo.solution.power),
                              np.asarray(co.solution.power))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16),
       device_rate=st.floats(0.1, 0.9))
def test_partially_faulted_cohabitant_within_tolerance(seed, device_rate):
    prob = slice_round(make_problem("drifting_metro", seed=0,
                                    n_devices=N, n_rounds=2), 0)
    rng = np.random.default_rng(seed)
    bad = corrupt_problem(prob, "nan_channel", rng=rng,
                          device_rate=device_rate)
    solo, = FleetControlService(ServiceConfig()).run([("clean", prob)])
    both = FleetControlService(ServiceConfig()).run(
        [("clean", prob), ("bad", bad)])
    co = next(r for r in both if r.cell_id == "clean")
    assert np.isfinite(np.asarray(co.solution.a)).all()
    assert np.allclose(solo.solution.a, co.solution.a, atol=1e-5)
    assert np.allclose(solo.solution.power, co.solution.power,
                       rtol=1e-4, atol=1e-6)
