"""Hypothesis property suite for the open-loop batch-close policy.

Pins the control-plane invariants the ISSUE names so later refactors of
``FleetControlService`` cannot silently bend them:

* every bucket the service can register is a true power of two;
* FIFO order holds within a priority class (and compat group), every
  request is served exactly once, and draining terminates;
* the close policy is internally consistent (``None`` means every rule
  has slack), and under fine-grained polling no *feasible* request —
  one whose budget covered the safety-scaled solve cost at submission,
  with queueing slack — is ever closed after its deadline;
* closing decisions are pure functions of ``(batch, now, cost, config)``.

The suite drives :func:`batch_close_reason` and the service's lane
machinery (``_eligible`` / ``_take_micro_batch``) directly with
synthetic requests — no jit, no solves — so hundreds of generated cases
run in milliseconds.  Deterministic mirrors of the key cases live in
``tests/test_fleet_service.py`` and run even without hypothesis.
"""
import collections
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve import (  # noqa: E402
    CLOSE_DEADLINE,
    CLOSE_FULL,
    CLOSE_LINGER,
    FleetControlService,
    ServiceConfig,
    SolveRequest,
    batch_close_reason,
)
from repro.serve.fleet_service import _next_pow2  # noqa: E402


def _req(seq, t_submit, deadline=math.inf, ckey=0, priority=False):
    return SolveRequest(cell_id=seq, problem=None, t_submit=t_submit,
                        t_deadline=deadline, priority=priority,
                        fkey=None, ckey=ckey, seq=seq)


# --------------------------------------------------------------- buckets
@given(n=st.integers(0, 1 << 20), floor=st.integers(0, 4096))
def test_buckets_are_always_powers_of_two(n, floor):
    b = _next_pow2(n, floor)
    assert b >= 1 and b & (b - 1) == 0
    assert b >= n
    # minimal power of two covering max(n, floor, 1) — in particular the
    # floor itself is rounded up, never returned verbatim
    target = max(n, floor, 1)
    assert b >= target
    assert b == 1 or b // 2 < target


# ---------------------------------------------------------- close policy
@st.composite
def _batches(draw):
    """A FIFO-ordered candidate batch plus a clock/cost/config tuple."""
    n = draw(st.integers(1, 10))
    gaps = draw(st.lists(st.floats(0.0, 1.0), min_size=n, max_size=n))
    t = 0.0
    reqs = []
    for i, g in enumerate(gaps):
        t += g
        budget = draw(st.one_of(st.none(), st.floats(1e-6, 100.0)))
        reqs.append(_req(i, t, math.inf if budget is None else t + budget))
    now = t + draw(st.floats(0.0, 10.0))
    cost = draw(st.floats(1e-6, 1.0))
    cfg = ServiceConfig(
        max_batch=draw(st.integers(1, 8)),
        close_safety=draw(st.floats(1.0, 3.0)),
        max_linger_s=draw(st.floats(1e-4, 1.0)))
    return reqs, now, cost, cfg


@given(_batches())
def test_close_reason_consistency(case):
    """Each reported reason implies its rule actually fired, and ``None``
    implies every rule has slack — no request can be stranded past a
    bound the policy claims to enforce."""
    reqs, now, cost, cfg = case
    reason = batch_close_reason(reqs, now, cost, cfg)
    budget = min(r.t_deadline for r in reqs) - now
    wait = now - reqs[0].t_submit
    if reason is None:
        assert len(reqs) < cfg.max_batch
        assert budget > cfg.close_safety * cost
        assert wait < cfg.max_linger_s
    elif reason == CLOSE_FULL:
        assert len(reqs) >= cfg.max_batch
    elif reason == CLOSE_DEADLINE:
        assert budget <= cfg.close_safety * cost
    elif reason == CLOSE_LINGER:
        assert wait >= cfg.max_linger_s
    else:  # pragma: no cover - policy returns only the four constants
        pytest.fail(f"unknown close reason {reason!r}")
    # purity: same inputs, same answer
    assert batch_close_reason(reqs, now, cost, cfg) == reason


@given(_batches())
def test_empty_batch_never_closes(case):
    _, now, cost, cfg = case
    assert batch_close_reason([], now, cost, cfg) is None


# ------------------------------------------- feasible-never-late (sim)
@st.composite
def _arrival_streams(draw):
    n = draw(st.integers(1, 12))
    # gaps >= 2*cost keep the single server under ~0.5 load, so queueing
    # delay is bounded by one in-flight solve
    gaps = draw(st.lists(st.floats(2.0, 6.0), min_size=n, max_size=n))
    max_batch = draw(st.integers(1, 4))
    linger = draw(st.floats(1.0, 10.0))
    return gaps, max_batch, linger


@settings(deadline=None)
@given(_arrival_streams())
def test_feasible_requests_never_served_after_deadline(case):
    """Single-server simulation mirroring ``FleetControlService.poll``
    on a virtual clock with blocking solves of fixed cost ``c=1``:
    every request whose deadline budget covers the safety margin, the
    linger bound and one in-flight solve is completed on time."""
    gaps, max_batch, linger = case
    c = 1.0
    tick = c / 8.0
    cfg = ServiceConfig(max_batch=max_batch, close_safety=3.0,
                        max_linger_s=linger)
    # feasible budget: safety margin + worst-case wait behind the linger
    # rule + one blocking solve + polling granularity
    slack = cfg.close_safety * c + linger + 2.0 * c + tick
    t_sub, reqs = 0.0, []
    for i, g in enumerate(gaps):
        t_sub += g
        reqs.append(_req(i, t_sub, deadline=t_sub + slack))

    t, i, queue, completions = 0.0, 0, collections.deque(), []
    while i < len(reqs) or queue:
        while i < len(reqs) and reqs[i].t_submit <= t:
            queue.append(reqs[i])
            i += 1
        batch = list(queue)[:max_batch]
        reason = batch_close_reason(batch, t, c, cfg)
        if reason is not None:
            for _ in batch:
                queue.popleft()
            t += c                       # the solve blocks the server
            completions.extend((r, t) for r in batch)
        elif not queue and i < len(reqs):
            t = max(t + tick, reqs[i].t_submit)
        else:
            t += tick

    assert len(completions) == len(reqs)
    for r, t_done in completions:
        assert t_done <= r.t_deadline, \
            f"req {r.seq}: done {t_done:.3f} > deadline {r.t_deadline:.3f}"


# ------------------------------------------------------- FIFO / draining
@st.composite
def _lanes(draw):
    n = draw(st.integers(0, 30))
    ckeys = draw(st.lists(st.integers(0, 2), min_size=n, max_size=n))
    max_batch = draw(st.integers(1, 5))
    return ckeys, max_batch


@given(_lanes())
def test_fifo_within_class_and_drain_terminates(case):
    """``_take_micro_batch`` over an arbitrary lane: batches are
    head-compatible, size-bounded, FIFO within each compat group, every
    request is served exactly once, and draining terminates."""
    ckeys, max_batch = case
    svc = FleetControlService(ServiceConfig(max_batch=max_batch))
    lane = collections.deque(_req(i, float(i), ckey=k)
                             for i, k in enumerate(ckeys))
    batches, rounds = [], 0
    while lane:
        taken = svc._take_micro_batch(lane)
        assert taken, "drain made no progress"
        batches.append(taken)
        rounds += 1
        assert rounds <= max(len(ckeys), 1)      # termination bound
    served = [r for b in batches for r in b]
    assert sorted(r.seq for r in served) == list(range(len(ckeys)))
    for b in batches:
        assert len(b) <= max_batch
        assert len({r.ckey for r in b}) <= 1     # head-compatible
    # FIFO within each compat group across the whole drain
    by_key = collections.defaultdict(list)
    for r in served:
        by_key[r.ckey].append(r.seq)
    for seqs in by_key.values():
        assert seqs == sorted(seqs)


@given(st.lists(st.integers(0, 2), min_size=0, max_size=20),
       st.integers(1, 4))
def test_priority_class_order_preserved_across_lanes(ckeys, max_batch):
    """`step` drains the priority lane before the normal lane, and each
    lane drains FIFO: enqueue the same requests into both lanes and
    check the pop order class-by-class (no solves — requests are taken
    via the lane machinery directly)."""
    svc = FleetControlService(ServiceConfig(max_batch=max_batch))
    for i, k in enumerate(ckeys):
        lane = svc._prio if k == 0 else svc._queue
        lane.append(_req(i, float(i), ckey=k, priority=(k == 0)))
    order = []
    while svc.pending:
        lane = svc._prio if svc._prio else svc._queue
        order.extend(r.seq for r in svc._take_micro_batch(lane))
    prio_seqs = [i for i, k in enumerate(ckeys) if k == 0]
    norm_seqs = [i for i, k in enumerate(ckeys) if k != 0]
    assert order[:len(prio_seqs)] == prio_seqs   # priority class first...
    # ...and FIFO within the normal class per compat group
    by_key = collections.defaultdict(list)
    for s in order[len(prio_seqs):]:
        by_key[ckeys[s]].append(s)
    for seqs in by_key.values():
        assert seqs == sorted(seqs)
    assert sorted(order) == list(range(len(ckeys)))
    assert sorted(order[len(prio_seqs):]) == norm_seqs
