"""Dataset + Dirichlet partitioner tests."""
import numpy as np

from repro.data.partition import dirichlet_partition, heterogeneity_index, label_distribution
from repro.data.synthetic import make_dataset, make_mnist_like


class TestSyntheticDigits:
    def test_shapes_and_ranges(self):
        ds = make_dataset(256, seed=0)
        assert ds.images.shape == (256, 28, 28, 1)
        assert ds.images.dtype == np.float32
        assert ds.images.min() >= 0.0 and ds.images.max() <= 1.0
        assert set(np.unique(ds.labels)) <= set(range(10))

    def test_deterministic(self):
        a = make_dataset(64, seed=7)
        b = make_dataset(64, seed=7)
        np.testing.assert_array_equal(a.images, b.images)
        np.testing.assert_array_equal(a.labels, b.labels)

    def test_classes_distinguishable(self):
        """Nearest-centroid in raw pixel space beats chance (the random
        shift/scale jitter is deliberately strong — a linear pixel model
        only gets ~2.4x chance while the paper's CNN reaches >90%, see
        test_fl_engine.test_learning_happens)."""
        train, test = make_mnist_like(2000, 400, seed=1)
        cents = np.stack([train.images[train.labels == c].mean(0)
                          for c in range(10)])
        d = ((test.images[:, None] - cents[None]) ** 2).sum((2, 3, 4))
        acc = (d.argmin(1) == test.labels).mean()
        assert acc > 0.18


class TestDirichletPartition:
    def test_partition_is_exact_cover(self):
        ds = make_dataset(3000, seed=0)
        parts = dirichlet_partition(ds, 20, beta=0.5, seed=0)
        allidx = np.concatenate(parts)
        assert len(allidx) == 3000
        assert len(np.unique(allidx)) == 3000

    def test_beta_ordering(self):
        """Smaller beta => more heterogeneity (paper scenarios 1 vs 2)."""
        ds = make_dataset(6000, seed=0)
        h = {}
        for beta in (0.1, 0.3, 10.0):
            parts = dirichlet_partition(ds, 50, beta=beta, seed=3)
            h[beta] = heterogeneity_index(label_distribution(ds, parts))
        assert h[0.1] > h[0.3] > h[10.0]

    def test_min_size(self):
        ds = make_dataset(2000, seed=0)
        parts = dirichlet_partition(ds, 30, beta=0.1, seed=0, min_size=2)
        assert min(len(p) for p in parts) >= 2
