"""FL round-engine tests: aggregation-path equivalence, accounting
invariants, and end-to-end learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProbabilisticScheduler, make_scheduler, sample_problem
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_mnist_like
from repro.fl.engine import FLConfig, run_fl


@pytest.fixture(scope="module")
def setup():
    train, test = make_mnist_like(1500, 300, seed=0)
    parts = dirichlet_partition(train, 20, beta=0.3, seed=1)
    sizes = np.array([len(p) for p in parts])
    prob = sample_problem(0, 20, tau_th=0.5, dirichlet_sizes=sizes)
    return prob, train, parts, test


def test_fused_and_stacked_aggregation_agree(setup):
    """The two eq.-(4) implementations produce identical parameters."""
    prob, train, parts, test = setup
    res = {}
    for mode in ("fused", "stacked"):
        cfg = FLConfig(n_rounds=5, eval_every=5, batch_per_client=4,
                       aggregate=mode, seed=11)
        res[mode] = run_fl(prob, ProbabilisticScheduler(), train, parts,
                           test, cfg)
    pa = jax.tree_util.tree_leaves(res["fused"].params)
    pb = jax.tree_util.tree_leaves(res["stacked"].params)
    for a, b in zip(pa, pb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


def test_accounting_invariants(setup):
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=30, eval_every=10, batch_per_client=4, seed=2)
    res = run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)
    h = res.history
    assert np.all(np.diff(h.sim_time) >= 0)      # cumulative
    assert np.all(np.diff(h.energy) >= 0)
    assert h.participants.min() >= 0
    assert h.participants.max() <= prob.n_devices
    # no-participant rounds must add no time/energy
    zero = h.participants == 0
    if zero.any():
        idx = np.where(zero)[0]
        idx = idx[idx > 0]
        assert np.allclose(h.sim_time[idx], h.sim_time[idx - 1])


def test_expected_participation_matches_probabilities(setup):
    prob, train, parts, test = setup
    sch = ProbabilisticScheduler()
    state = sch.precompute(prob)
    cfg = FLConfig(n_rounds=150, eval_every=150, batch_per_client=2, seed=4)
    res = run_fl(prob, sch, train, parts, test, cfg)
    expected = float(state.a.sum())
    observed = res.history.participants.mean()
    # Bernoulli CLT bound (~4 sigma)
    sigma = float(jnp.sqrt(jnp.sum(state.a * (1 - state.a))) / np.sqrt(150))
    assert abs(observed - expected) < 4 * sigma + 0.3


def test_learning_happens(setup):
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=120, eval_every=40, batch_per_client=8,
                   lr=0.1, seed=5)
    res = run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)
    assert res.history.eval_acc[-1] > 0.3        # well above 10% chance


def test_deterministic_selects_fixed_subset(setup):
    prob, train, parts, test = setup
    sch = make_scheduler("deterministic")
    state = sch.precompute(prob)
    a = np.asarray(state.a)
    assert set(np.unique(a)) <= {0.0, 1.0}
    psch = ProbabilisticScheduler()
    pstate = psch.precompute(prob)
    assert abs(a.sum() - round(float(pstate.a.sum()))) <= 1


def test_history_time_to_accuracy(setup):
    prob, train, parts, test = setup
    cfg = FLConfig(n_rounds=40, eval_every=10, batch_per_client=4, seed=6)
    res = run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)
    t = res.history.time_to_accuracy(0.0)        # trivially achieved
    assert np.isfinite(t)
    assert np.isnan(res.history.time_to_accuracy(1.01))
