"""Joint bit/power/selection optimisation (docs/compression.md).

Covers the four layers the bits variable threads through:

* problem contract — the ``bits`` leaf scales the payload in tx_time /
  P^min / upload_energy, ``bits=None`` keeps the payload a static python
  float, and an all-32 leaf solves bitwise identically to ``None``;
* solver — the menu step (one converged candidate per menu width inside
  the single fused while_loop + ``select_best_bits`` argmax) strictly
  buys participation where the time constraint binds, with a golden N=3
  oracle for the tie-break rules;
* training — the quantized masked-aggregate kernel matches its jnp
  oracle and the unfused engine path, and the scan engine's bits-table
  plans reproduce ``run_fl``'s quantized stream;
* serving — the bits leaf enters the cache/compat keys and warmup
  resize.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GRAD_SIZE_BITS_FP32,
    ProbabilisticScheduler,
    make_problem,
    sample_problem,
    select_best_bits,
    slice_round,
    solve_joint,
    solve_joint_batch,
    solve_joint_fused,
    stack_problems,
)

MENU = (8, 16, 32)


def _starved(seed=1, n=32, **kw):
    return make_problem("bandwidth_starved", seed=seed, n_devices=n, **kw)


def _with_bits(problem, bits):
    return dataclasses.replace(
        problem, bits=jnp.asarray(np.broadcast_to(
            np.float32(bits), (problem.n_devices,))))


# ------------------------------------------------------- problem contract

class TestProblemContract:
    def test_bits_none_payload_is_static_float(self):
        prob = sample_problem(0, 4)
        assert isinstance(prob.payload_bits(1), float)
        assert prob.payload_bits(1) == prob.grad_size_bits
        assert prob.grad_size_bits == GRAD_SIZE_BITS_FP32

    def test_bits_scale_tx_time_and_pmin(self):
        prob = sample_problem(0, 8)
        prob8 = _with_bits(prob, 8.0)
        p = jnp.full(8, 0.05)
        np.testing.assert_allclose(np.asarray(prob8.tx_time(p)),
                                   np.asarray(prob.tx_time(p)) / 4.0,
                                   rtol=1e-6)
        a = jnp.full(8, 0.5)
        # P^min is exp-linear in the payload: quartering S quarters the
        # exponent
        full = np.log1p(np.asarray(prob.p_min(a))
                        * np.asarray(prob.path_gain()))
        quarter = np.log1p(np.asarray(prob8.p_min(a))
                           * np.asarray(prob8.path_gain()))
        np.testing.assert_allclose(quarter, full / 4.0, rtol=1e-5)

    def test_bits32_leaf_bitwise_identical_solves(self):
        """b/32 = 1.0 exactly, so the all-32 leaf must not perturb a
        single ulp across the solver entry points."""
        prob = _starved(n=16)
        prob32 = _with_bits(prob, 32.0)
        for solver in (solve_joint, solve_joint_fused):
            s0, s1 = solver(prob), solver(prob32)
            assert np.array_equal(np.asarray(s0.a), np.asarray(s1.a))
            assert np.array_equal(np.asarray(s0.power),
                                  np.asarray(s1.power))
        batch = stack_problems([prob, _starved(seed=2, n=16)])
        batch32 = stack_problems([prob32,
                                  _with_bits(_starved(seed=2, n=16), 32.0)])
        b0 = solve_joint_batch(batch, method="fused")
        b1 = solve_joint_batch(batch32, method="fused")
        assert np.array_equal(np.asarray(b0.a), np.asarray(b1.a))

    def test_sanitize_fills_bad_bits(self):
        prob = _with_bits(sample_problem(0, 4), 8.0)
        bad = dataclasses.replace(
            prob, bits=prob.bits.at[1].set(jnp.nan).at[2].set(0.0))
        clean, mask = bad.sanitize()
        assert not bool(mask[1]) and not bool(mask[2])
        assert np.asarray(clean.bits)[1] == 32.0
        assert np.isfinite(np.asarray(clean.tx_time(jnp.full(4, 0.05)))).all()

    def test_kernel_batch_method_rejects_bits(self):
        batch = stack_problems([_with_bits(_starved(n=16), 8.0)])
        with pytest.raises(ValueError, match="static payload"):
            solve_joint_batch(batch, method="kernel")

    def test_slice_round_slices_rank2_bits(self):
        prob = make_problem("drifting_metro", seed=0, n_devices=8,
                            n_rounds=5)
        bits = jnp.asarray(
            np.random.default_rng(0).choice([8.0, 16.0, 32.0], (8, 5)),
            jnp.float32)
        prob = dataclasses.replace(prob, bits=bits)
        sl = slice_round(prob, 3)
        assert sl.bits.shape == (8, 1)
        np.testing.assert_array_equal(np.asarray(sl.bits)[:, 0],
                                      np.asarray(bits)[:, 3])


# ----------------------------------------------------------- solver layer

class TestBitAllocationStep:
    def test_golden_n3_select_best_bits(self):
        """Hand-built candidate stacks (menu order 32, 16, 8) pin the
        argmax + tie-break semantics:

        * device 0: narrower is strictly better -> picks 8;
        * device 1: exact three-way tie (a = 1 capped) -> widest wins;
        * device 2: float-noise 'gain' within atol -> stays at 32.
        """
        s = 1000.0
        a_m = jnp.asarray([[0.3, 1.0, 0.4],
                           [0.5, 1.0, 0.4 + 1e-8],
                           [0.9, 1.0, 0.4]])
        p_m = jnp.asarray([[1.0, 2.0, 3.0],
                           [4.0, 5.0, 6.0],
                           [7.0, 8.0, 9.0]])
        sbits_m = jnp.asarray([jnp.full(3, s),
                               jnp.full(3, s / 2),
                               jnp.full(3, s / 4)])
        a, p, bits = select_best_bits(a_m, p_m, sbits_m, s_bits=s)
        np.testing.assert_allclose(np.asarray(bits), [8.0, 32.0, 32.0])
        np.testing.assert_allclose(np.asarray(a), [0.9, 1.0, 0.4])
        np.testing.assert_allclose(np.asarray(p), [7.0, 2.0, 3.0])

    def test_menu_buys_participation_when_bandwidth_starved(self):
        """Acceptance: on the bandwidth-starved scenario the joint solve
        strictly increases expected participants vs fixed fp32 (>= 1.5x;
        in the time-binding regime the gain approaches 32/min(menu))."""
        prob = _starved()
        e32 = float(jnp.sum(solve_joint_fused(prob).a))
        solm = solve_joint_fused(prob, bit_menu=MENU)
        em = float(jnp.sum(solm.a))
        assert em > 1.5 * e32
        assert solm.bits is not None and solm.bits.shape == (32,)
        assert set(np.unique(np.asarray(solm.bits))) <= set(
            float(b) for b in MENU)

    def test_menu_never_loses_to_any_fixed_width(self):
        """The per-element argmax dominates every uniform-width solve,
        including full precision (32 is on the menu)."""
        prob = _starved(seed=3)
        em = float(jnp.sum(solve_joint_fused(prob, bit_menu=MENU).a))
        for b in MENU:
            eb = float(jnp.sum(solve_joint_fused(_with_bits(prob, b)).a))
            assert em >= eb - 1e-5

    def test_menu_solution_is_fixed_point_of_chosen_widths(self):
        """Each element's (a, P) must equal the plain solve at its chosen
        width — candidates converge at their own fixed points, not at a
        shared iterate."""
        prob = _starved(seed=4, n=16)
        solm = solve_joint_fused(prob, bit_menu=MENU)
        bits = np.asarray(solm.bits)
        for b in np.unique(bits):
            ref = solve_joint_fused(_with_bits(prob, float(b)))
            sel = bits == b
            np.testing.assert_allclose(np.asarray(solm.a)[sel],
                                       np.asarray(ref.a)[sel],
                                       rtol=1e-5, atol=1e-6)

    def test_batch_fused_menu_matches_instances(self):
        probs = [_starved(seed=s, n=16) for s in (1, 2)]
        batch = stack_problems(probs)
        bsol = solve_joint_batch(batch, method="fused", bit_menu=MENU)
        assert bsol.bits is not None
        for i, p in enumerate(probs):
            ref = solve_joint_fused(p, bit_menu=MENU)
            inst = bsol.instance(i)
            np.testing.assert_allclose(np.asarray(inst.a),
                                       np.asarray(ref.a),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_array_equal(np.asarray(inst.bits),
                                          np.asarray(ref.bits))

    def test_batch_non_fused_method_rejects_menu(self):
        batch = stack_problems([_starved(n=16)])
        with pytest.raises(ValueError, match="fused"):
            solve_joint_batch(batch, method="alternating", bit_menu=MENU)

    def test_scheduler_threads_menu(self):
        prob = _starved(n=16)
        sch = ProbabilisticScheduler(solver="fused", bit_menu=MENU)
        state = sch.precompute(prob)
        plain = ProbabilisticScheduler(solver="fused").precompute(prob)
        assert float(np.sum(state.a)) > 1.5 * float(np.sum(plain.a))
        with pytest.raises(ValueError, match="fused"):
            ProbabilisticScheduler(solver="alternating",
                                   bit_menu=MENU).precompute(prob)


# --------------------------------------------------------- training layer

class TestQuantizedAggregate:
    def _operands(self, n=20, d=1000, seed=0):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        coef = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
        noise = jnp.asarray(rng.uniform(0, 1, (n, d)), jnp.float32)
        bits = jnp.asarray(rng.choice([1.0, 4.0, 8.0, 16.0, 32.0], n),
                           jnp.float32)
        return g, coef, noise, bits

    def test_kernel_matches_ref(self):
        from repro.kernels.masked_aggregate.ops import (
            quantized_masked_aggregate)
        from repro.kernels.masked_aggregate.ref import (
            quantized_masked_aggregate_ref)
        g, coef, noise, bits = self._operands()
        out = quantized_masked_aggregate(g, coef, noise, bits,
                                         interpret=True)
        ref = quantized_masked_aggregate_ref(g, coef, noise, bits)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_pytree_front_end_matches_engine_stream(self):
        """quantized_aggregate_pytree must reproduce _quantize_tree +
        weighted sum exactly (same key split order, same math)."""
        from repro.fl.engine import _quantize_tree
        from repro.kernels.masked_aggregate.ops import (
            quantized_aggregate_pytree)
        rng = np.random.default_rng(1)
        n = 12
        tree = {"w": jnp.asarray(rng.normal(size=(n, 25, 40)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(n, 7)), jnp.float32)}
        coef = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
        bits = jnp.asarray(rng.choice([4.0, 8.0], n), jnp.float32)
        key = jax.random.PRNGKey(5)
        ref = jax.tree_util.tree_map(
            lambda q: jnp.tensordot(coef, q, axes=((0,), (0,))),
            _quantize_tree(tree, key, bits))
        out = quantized_aggregate_pytree(tree, coef, key, bits,
                                         interpret=True)
        for k in tree:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-5, atol=1e-5)


class TestScanEngine:
    @pytest.fixture(scope="class")
    def fl_setup(self):
        from repro.data.partition import dirichlet_partition
        from repro.data.synthetic import make_mnist_like
        n = 8
        train, test = make_mnist_like(400, 100, seed=0)
        parts = dirichlet_partition(train, n, beta=0.3, seed=1)
        prob = sample_problem(0, n, tau_th=0.5)
        return prob, train, parts, test

    def test_uniform_bits_matches_run_fl(self, fl_setup):
        from repro.fl.engine import FLConfig, run_fl
        from repro.fl.scan_engine import run_fl_scan
        prob, train, parts, test = fl_setup
        cfg = FLConfig(n_rounds=5, eval_every=5, batch_per_client=4,
                       seed=3, aggregate="stacked", uplink_bits=8)
        ref = run_fl(prob, ProbabilisticScheduler(), train, parts, test,
                     cfg)
        for kw in ({}, {"use_kernel": True}):
            scan = run_fl_scan(prob, ProbabilisticScheduler(), train,
                               parts, test, cfg, **kw)
            for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                            jax.tree_util.tree_leaves(scan.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-4)
            np.testing.assert_array_equal(ref.history.participants,
                                          scan.history.participants)

    def test_bits_none_plan_and_program_unchanged(self, fl_setup):
        """PR-8 ``drops=None`` idiom: a quantisation-free config builds a
        plan with no bits leaf and an unquantized compiled program."""
        from repro.fl.engine import FLConfig
        from repro.fl.scan_engine import _Static, plan_trajectory
        prob, train, parts, test = fl_setup
        cfg = FLConfig(n_rounds=3, batch_per_client=4, seed=0,
                       aggregate="stacked")
        plan = plan_trajectory(prob, ProbabilisticScheduler(), parts, cfg)
        assert plan.bits is None
        assert "quantized" in _Static._fields

    def test_per_device_bits_table_runs(self, fl_setup):
        from repro.fl.engine import FLConfig
        from repro.fl.scan_engine import (init_sweep_params,
                                          plan_trajectory, run_fl_sweep,
                                          stack_plans)
        prob, train, parts, test = fl_setup
        cfg = FLConfig(n_rounds=4, eval_every=2, batch_per_client=4,
                       seed=1, aggregate="stacked")
        bits = np.random.default_rng(0).choice([8.0, 32.0], 8)
        plan = plan_trajectory(prob, ProbabilisticScheduler(), parts, cfg,
                               bits=bits)
        assert plan.bits.shape == (4, 8)
        sweep = run_fl_sweep(stack_plans([plan]), train, test, cfg,
                             init_sweep_params([cfg]), shard=False)
        for leaf in jax.tree_util.tree_leaves(sweep.params):
            assert bool(jnp.all(jnp.isfinite(leaf)))

    def test_plan_rejects_bits_with_fused_aggregate(self, fl_setup):
        from repro.fl.engine import FLConfig
        from repro.fl.scan_engine import plan_trajectory
        prob, train, parts, test = fl_setup
        cfg = FLConfig(n_rounds=2, batch_per_client=4, uplink_bits=8)
        with pytest.raises(ValueError, match="stacked"):
            plan_trajectory(prob, ProbabilisticScheduler(), parts, cfg)

    def test_stack_plans_rejects_mixed_bits(self, fl_setup):
        from repro.fl.engine import FLConfig
        from repro.fl.scan_engine import plan_trajectory, stack_plans
        prob, train, parts, test = fl_setup
        cfg = FLConfig(n_rounds=2, batch_per_client=4,
                       aggregate="stacked")
        p0 = plan_trajectory(prob, ProbabilisticScheduler(), parts, cfg)
        p1 = plan_trajectory(prob, ProbabilisticScheduler(), parts, cfg,
                             bits=np.full(8, 8.0))
        with pytest.raises(ValueError, match="bit-width"):
            stack_plans([p0, p1])


# ---------------------------------------------------------- serving layer

class TestServiceKeys:
    def test_bits_leaf_changes_cache_and_compat_keys(self):
        from repro.serve.fleet_service import (_compat_key,
                                               quantized_problem_key)
        prob = sample_problem(0, 8)
        prob8 = _with_bits(prob, 8.0)
        prob32 = _with_bits(prob, 32.0)
        assert quantized_problem_key(prob) != quantized_problem_key(prob8)
        # an all-32 leaf solves identically but compiles differently, so
        # it must not share a bucket with the bits=None program
        assert quantized_problem_key(prob) != quantized_problem_key(prob32)
        assert _compat_key(prob) != _compat_key(prob8)
        assert _compat_key(prob8) == _compat_key(prob32)

    def test_resize_preserves_bits_leaf(self):
        from repro.serve.fleet_service import _resize_problem
        prob = _with_bits(sample_problem(0, 8), 8.0)
        big = _resize_problem(prob, 16)
        assert big.bits.shape == (16,)
        assert np.asarray(big.bits).min() == 8.0

    def test_service_solves_bits_problem(self):
        from repro.serve import FleetControlService, ServiceConfig
        svc = FleetControlService(ServiceConfig())
        prob = _with_bits(_starved(n=16), 8.0)
        resp, = svc.run([("cell-q", prob)])
        a = np.asarray(resp.solution.a)
        assert np.isfinite(a).all() and a.max() <= 1.0


# ------------------------------------------------------------ closed loop

@pytest.mark.slow
def test_closed_loop_joint_bits_row():
    from repro.fl.closed_loop import (ClosedLoopConfig,
                                      format_closed_loop_table,
                                      run_closed_loop_grid)
    cfg = ClosedLoopConfig(n_devices=8, n_rounds=4, n_train=256, n_test=64,
                           eval_every=2)
    out = run_closed_loop_grid(cfg, strategies=("probabilistic",
                                                "joint_bits"))
    row = out["strategies"]["joint_bits"]
    assert row["mean_bits"] < 32.0
    assert np.isfinite(row["final_acc"])
    table = format_closed_loop_table(out)
    assert "joint_bits" in table and "bits" in table
