"""Property-based tests (hypothesis) for the client-selection schedulers.

Three families of invariants across random problems:

* sampling correctness — the empirical participation frequency of
  ``ProbabilisticScheduler.sample`` / ``sample_batch`` converges to the
  solved probabilities ``a*`` (CLT-bounded check over many draws);
* ``_round_preserving_count`` — binary output, expected-count
  preservation, and top-k structure (every selected device has a >= every
  unselected one);
* state shapes and simplex constraints for the Deterministic / Uniform /
  EquallyWeighted benchmark schedulers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import make_scheduler, sample_problem, stack_problems
from repro.core.schedulers import (
    DeterministicScheduler,
    EquallyWeightedScheduler,
    ProbabilisticScheduler,
    UniformScheduler,
    _round_preserving_count,
)


def _problem(seed, n, tau, pmax):
    return sample_problem(seed, n, tau_th=tau, p_max=pmax)


# n from a tiny set so jax's shape-keyed compilation cache is reused
# across hypothesis examples (arbitrary n => a recompile per example)
problem_strategy = st.builds(
    _problem,
    seed=st.integers(0, 2 ** 31 - 1),
    n=st.sampled_from([8, 32]),
    tau=st.floats(0.01, 2.0),
    pmax=st.floats(0.05, 10.0),
)

N_DRAWS = 4096
# 5-sigma CLT bound on a Bernoulli mean over N_DRAWS, worst case a = 0.5,
# plus f32 slack: 5 * 0.5 / sqrt(4096) ~ 0.039
FREQ_TOL = 0.045


@settings(max_examples=15, deadline=None)
@given(problem_strategy, st.integers(0, 2 ** 31 - 1))
def test_sample_frequency_converges_to_a_star(problem, key_seed):
    sch = ProbabilisticScheduler(solver="fused")
    state = sch.precompute(problem)
    keys = jax.random.split(jax.random.PRNGKey(key_seed), N_DRAWS)
    masks = jax.vmap(lambda k: sch.sample(state, k).mask)(keys)
    freq = np.asarray(jnp.mean(masks.astype(jnp.float32), axis=0))
    a = np.asarray(state.a if state.a.ndim == 1 else state.a[:, 0])
    np.testing.assert_allclose(freq, a, atol=FREQ_TOL)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 2 ** 31 - 1))
def test_sample_batch_frequency_converges(seed, key_seed):
    probs = [sample_problem(seed + i, 16) for i in range(3)]
    sch = ProbabilisticScheduler(solver="fused")
    state = sch.precompute_batch(stack_problems(probs))
    keys = jax.random.split(jax.random.PRNGKey(key_seed), N_DRAWS)
    masks = jax.vmap(lambda k: sch.sample_batch(state, k).mask)(keys)
    freq = np.asarray(jnp.mean(masks.astype(jnp.float32), axis=0))
    np.testing.assert_allclose(freq, np.asarray(state.a), atol=FREQ_TOL)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=64))
def test_round_preserving_count_invariants(a_list):
    a = jnp.asarray(np.asarray(a_list, np.float32))
    sel = np.asarray(_round_preserving_count(a))
    # binary
    assert set(np.unique(sel)).issubset({0.0, 1.0})
    # expected-count preserving: |{selected}| = clip(round(sum a), 1, N)
    k_expect = int(np.clip(np.round(np.asarray(a).sum()), 1, a.shape[0]))
    assert int(sel.sum()) == k_expect
    # top-k structure: min selected prob >= max unselected prob
    probs = np.asarray(a)
    if 0 < k_expect < a.shape[0]:
        assert probs[sel == 1].min() >= probs[sel == 0].max() - 1e-7


@settings(max_examples=10, deadline=None)
@given(problem_strategy)
def test_probabilistic_state_invariants(problem):
    state = ProbabilisticScheduler(solver="fused").precompute(problem)
    n = problem.n_devices
    a = np.asarray(state.a)
    assert state.a.shape[0] == n and state.power.shape == state.a.shape
    assert ((a >= 0) & (a <= 1)).all()
    p = np.asarray(state.power)
    assert ((p >= 0) & (p <= problem.p_max * (1 + 1e-6))).all()
    # aggregation weights are the data simplex
    w = np.asarray(state.agg_weights)
    assert w.shape == (n,) and (w >= 0).all()
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(problem_strategy)
def test_deterministic_state_invariants(problem):
    inner = ProbabilisticScheduler(solver="fused")
    state = DeterministicScheduler(inner=inner).precompute(problem)
    a = np.asarray(state.a)
    assert set(np.unique(a)).issubset({0.0, 1.0})
    draw = DeterministicScheduler(inner=inner).sample(
        state, jax.random.PRNGKey(0))
    # deterministic: the mask IS the binarised a, independent of the key
    np.testing.assert_array_equal(np.asarray(draw.mask), a > 0)
    w = np.asarray(state.agg_weights)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(problem_strategy, st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_uniform_state_invariants(problem, m, key_seed):
    m = min(m, problem.n_devices)
    sch = UniformScheduler(m=m)
    state = sch.precompute(problem)
    # a is the uniform M/N simplex scaled to expected count M
    np.testing.assert_allclose(np.asarray(state.a),
                               m / problem.n_devices, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(state.power), problem.p_max)
    draw = sch.sample(state, jax.random.PRNGKey(key_seed))
    assert int(np.asarray(draw.mask).sum()) == m   # exactly M participants
    np.testing.assert_allclose(np.asarray(state.agg_weights).sum(), 1.0,
                               rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(problem_strategy)
def test_equally_weighted_state_invariants(problem):
    inner = ProbabilisticScheduler(solver="fused")
    state = EquallyWeightedScheduler(inner=inner).precompute(problem)
    a = np.asarray(state.a)
    assert set(np.unique(a)).issubset({0.0, 1.0})
    # equal weights over the *selected* set: alpha restricted to the
    # selected devices sums to 1, and every entry is identical
    alpha = np.asarray(state.agg_weights)
    sel = a if a.ndim == 1 else a[:, 0]
    assert len(np.unique(alpha)) == 1
    np.testing.assert_allclose((alpha * (sel > 0)).sum(), 1.0, rtol=1e-5)


def test_make_scheduler_registry():
    for name in ("probabilistic", "deterministic", "uniform",
                 "equally_weighted"):
        sch = make_scheduler(name)
        assert hasattr(sch, "precompute") and hasattr(sch, "sample")
