"""Golden-value regression tests for the paper's closed forms.

The eq.-10 power update (``analytic_power_elements`` — the closed-form
optimum Algorithm 1 converges to), the eq.-13 selection update
(``selection_update_elements``), and the helpers they share are pinned
against *hand-computed* oracle numbers for a tiny N=3 element set, so a
future refactor cannot silently drift the formulas.  Every expected value
below is derived in the comment next to it from the paper equations with
calculator-friendly constants — none is a recorded output of the code
under test.

Constants used throughout: S = 100 bits, tau = 1 s, P^max = 1 W.
"""
import numpy as np
import pytest

from repro.core.power import (
    analytic_power_elements,
    dinkelbach_power_elements,
    element_p_min,
    element_tx_time,
    element_warm_lambda,
    energy_gate_elements,
)
from repro.core.selection import selection_update_elements

S_BITS, TAU, P_MAX = 100.0, 1.0, 1.0

# three regimes of the power subproblem (9):
#   el0 interior:  a=0.5, pg=3, B=100
#       exponent  = a S / (B tau) = 0.5
#       P^min     = (2^0.5 - 1) / 3          = 0.13807118745769837
#       P*        = P^min  (< P^max, feasible)
#       rate(P*)  = B log2(1 + P* pg) = 100 * 0.5 = 50 bit/s
#       T(P*)     = S / rate = 2 s  (= tau / a, by construction of P^min)
#       lam       = a P* T = 0.5 * 0.13807... * 2 = 0.13807118745769837 J
#   el1 clipped:   a=1, pg=1, B=10
#       exponent  = 10,  P^min = 2^10 - 1 = 1023  > P^max  -> infeasible
#       P*        = P^max = 1
#       T(P*)     = 100 / (10 * log2 2) = 10 s
#       lam       = 1 * 1 * 10 = 10 J
#   el2 deselected: a=0 -> P^min = 0, P* = 0, lam = 0 (rate(0) = 0)
A = np.array([0.5, 1.0, 0.0], np.float32)
PG = np.array([3.0, 1.0, 2.0], np.float32)
BW = np.array([100.0, 10.0, 50.0], np.float32)

P_MIN_GOLD = [0.13807118745769837, 1023.0, 0.0]
P_GOLD = [0.13807118745769837, 1.0, 0.0]
LAM_GOLD = [0.13807118745769837, 10.0, 0.0]
FEAS_GOLD = [True, False, True]


class TestPowerClosedForm:
    def test_element_p_min(self):
        got = element_p_min(A, PG, BW, s_bits=S_BITS, tau=TAU)
        np.testing.assert_allclose(np.asarray(got), P_MIN_GOLD, rtol=1e-5)

    def test_p_min_exponent_clamp(self):
        # a S / (B tau) = 200 clamps to 120: finite, astronomically
        # infeasible rather than NaN/inf
        got = np.asarray(element_p_min(
            np.float32(2.0), np.float32(1.0), np.float32(1.0),
            s_bits=S_BITS, tau=TAU))
        assert np.isfinite(got)
        np.testing.assert_allclose(got, 2.0 ** 120, rtol=1e-5)

    def test_element_tx_time(self):
        # P=3, pg=1: rate = 25 * log2(4) = 50 bit/s, T = 100/50 = 2 s
        got = element_tx_time(np.float32(3.0), np.float32(1.0),
                              np.float32(25.0), s_bits=S_BITS)
        np.testing.assert_allclose(np.asarray(got), 2.0, rtol=1e-6)

    def test_analytic_power_elements(self):
        p, lam, feas = analytic_power_elements(
            A, PG, BW, s_bits=S_BITS, tau=TAU, p_max=P_MAX)
        np.testing.assert_allclose(np.asarray(p), P_GOLD, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(lam), LAM_GOLD, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(feas), FEAS_GOLD)

    def test_dinkelbach_converges_to_golden(self):
        """Algorithm 1 must land on the same closed-form numbers."""
        p, lam, iters, feas = dinkelbach_power_elements(
            A, PG, BW, s_bits=S_BITS, tau=TAU, p_max=P_MAX)
        np.testing.assert_allclose(np.asarray(p), P_GOLD, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(lam), LAM_GOLD, rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(feas), FEAS_GOLD)
        assert 0 < int(iters) <= 64

    def test_warm_lambda_seed(self):
        # seed = a P T at the given state; invalid states fall back to
        # the cold constant 1e-3
        lam0 = element_warm_lambda(A, np.asarray(P_GOLD, np.float32),
                                   PG, BW, s_bits=S_BITS)
        np.testing.assert_allclose(np.asarray(lam0)[:2], LAM_GOLD[:2],
                                   rtol=1e-5)
        assert float(np.asarray(lam0)[2]) == pytest.approx(1e-3)


class TestSelectionClosedForm:
    # a* = min(1, tau / T, E^max / (P T + E^c)) per eq. (13), corrected
    #   time-binding:   P=0.5, T=4,   E^max=10,  E^c=1
    #                   -> min(1, 0.25, 10/3)         = 0.25
    #   energy-binding: P=1,   T=0.5, E^max=0.3, E^c=0.1
    #                   -> min(1, 2, 0.3/0.6)         = 0.5
    #   capped:         P=0.1, T=0.1, E^max=100, E^c=1
    #                   -> min(1, 10, 100/1.01)       = 1.0
    #   zero power:     P=0 transmits nothing         -> 0.0
    P = np.array([0.5, 1.0, 0.1, 0.0], np.float32)
    T = np.array([4.0, 0.5, 0.1, 1.0], np.float32)
    EMAX = np.array([10.0, 0.3, 100.0, 1.0], np.float32)
    EC = np.array([1.0, 0.1, 1.0, 0.1], np.float32)
    A_GOLD = [0.25, 0.5, 1.0, 0.0]

    def test_selection_update_elements(self):
        got = selection_update_elements(self.P, self.T, self.EMAX, self.EC,
                                        tau=TAU, s_bits=S_BITS)
        np.testing.assert_allclose(np.asarray(got), self.A_GOLD, rtol=1e-6)

    def test_faithful_typo_divides_time_term_by_s(self):
        # the verbatim paper formula prints tau / (S T): the time-binding
        # element drops to 0.25/100 = 0.0025; the energy-bound and capped
        # elements re-bind accordingly: min(1, 2/100, 0.5) = 0.02,
        # min(1, 10/100, 99.0099) = 0.1
        got = selection_update_elements(self.P, self.T, self.EMAX, self.EC,
                                        tau=TAU, s_bits=S_BITS,
                                        faithful_eq13_typo=True)
        np.testing.assert_allclose(np.asarray(got),
                                   [0.0025, 0.02, 0.1, 0.0], rtol=1e-6)


class TestEnergyGate:
    def test_eq10_gate(self):
        # H = E^max - a E^c; gate is lam <= H (+1e-9 tolerance):
        #   (a=0.5, E^max=1, E^c=1) -> H = 0.5
        a = np.full(3, 0.5, np.float32)
        emax = np.ones(3, np.float32)
        ec = np.ones(3, np.float32)
        lam = np.array([0.2, 0.6, 0.5], np.float32)
        np.testing.assert_array_equal(
            np.asarray(energy_gate_elements(a, lam, emax, ec)),
            [True, False, True])
