"""Batched multi-scenario engine: solve_joint_batch must agree with a
python loop of per-instance solves, through ragged padding, fading, the
kernel fast path, and the scenario registry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SCENARIOS,
    ProbabilisticScheduler,
    make_batch,
    make_mixed_batch,
    make_problem,
    sample_problem,
    solve_joint,
    solve_joint_batch,
    solve_joint_optimal,
    stack_problems,
)

OBJ_TOL = 1e-5


def _assert_matches_loop(batch, problems, *, method="alternating"):
    sol = solve_joint_batch(batch, method=method)
    ref_solver = solve_joint_optimal if method != "alternating" else solve_joint
    for b, prob in enumerate(problems):
        ref = ref_solver(prob)
        assert abs(float(sol.objective[b]) - float(ref.objective)) <= OBJ_TOL, \
            f"instance {b}: batched {float(sol.objective[b])} " \
            f"vs loop {float(ref.objective)}"
        inst = sol.instance(b)
        assert inst.a.shape == ref.a.shape
        assert bool(prob.constraints_satisfied(inst.a, inst.power,
                                               rtol=1e-3).all()), \
            f"instance {b}: batched solution infeasible"
    return sol


class TestStacking:
    def test_ragged_roundtrip(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 16, 12])]
        batch = stack_problems(probs)
        assert batch.batch_size == 3 and batch.n_max == 16
        assert np.array_equal(np.asarray(batch.fleet_sizes), [8, 16, 12])
        assert int(batch.mask.sum()) == 8 + 16 + 12
        for orig, back in zip(probs, batch.unstack()):
            assert back.n_devices == orig.n_devices
            for f in ("distance_m", "bandwidth_hz", "energy_budget_j",
                      "weights"):
                np.testing.assert_allclose(np.asarray(getattr(back, f)),
                                           np.asarray(getattr(orig, f)))

    def test_static_mismatch_rejected(self):
        a = sample_problem(0, 8)
        b = dataclasses.replace(a, tau_th=0.5)
        with pytest.raises(ValueError, match="tau_th"):
            stack_problems([a, b])

    def test_mixed_fading_rejected(self):
        # a non-fading instance solves one [N] round, a fading one [N, K];
        # mixing would silently K-multiply the former's objective
        a = sample_problem(0, 8, with_fading=True, n_rounds=3)
        b = sample_problem(1, 8, n_rounds=3)
        with pytest.raises(ValueError, match="all-or-none"):
            stack_problems([a, b])
        # explicit unit fading opts a static-channel instance in
        c = dataclasses.replace(b, fading=jnp.ones((8, 3), jnp.float32))
        batch = stack_problems([a, c])
        assert batch.problem.fading.shape == (2, 8, 3)
        np.testing.assert_allclose(np.asarray(batch.problem.fading[1]), 1.0)


class TestBatchAgreement:
    def test_ragged_alternating(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 24, 16, 24])]
        _assert_matches_loop(stack_problems(probs), probs)

    def test_ragged_optimal(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 24, 16])]
        _assert_matches_loop(stack_problems(probs), probs, method="optimal")

    def test_kernel_fast_path(self):
        probs = [sample_problem(i, n) for i, n in enumerate([8, 24, 16])]
        _assert_matches_loop(stack_problems(probs), probs, method="kernel")

    def test_64_instances(self):
        # the acceptance-scale check: >= 64 stacked scenarios, |dobj| <= 1e-5
        probs = [sample_problem(i, 16) for i in range(64)]
        sol = _assert_matches_loop(stack_problems(probs), probs)
        assert sol.a.shape == (64, 16)
        assert bool(sol.converged.all())

    def test_fading_batch(self):
        probs = [sample_problem(i, 10, with_fading=True, n_rounds=4)
                 for i in range(4)]
        sol = _assert_matches_loop(stack_problems(probs), probs)
        assert sol.a.shape == (4, 10, 4)

    def test_padding_inert(self):
        # padded slots must come back a = power = 0 and never participate
        probs = [sample_problem(i, n) for i, n in enumerate([4, 32])]
        batch = stack_problems(probs)
        sol = solve_joint_batch(batch)
        pad = ~np.asarray(batch.mask)
        assert np.all(np.asarray(sol.a)[pad] == 0.0)
        assert np.all(np.asarray(sol.power)[pad] == 0.0)


class TestScenarios:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_registry_builds_and_solves(self, name):
        from repro.core.multicell import MultiCellProblem, solve_coupled

        # small fleets keep CI fast; every scenario accepts n_devices
        prob = make_problem(name, seed=0, n_devices=16)
        if isinstance(prob, MultiCellProblem):
            # multi-cell entries solve through the coupled loop
            # (tests/test_multicell.py has the full contract)
            sol = solve_coupled(make_problem(name, seed=0, n_cells=2,
                                             n_devices=16))
            assert sol.converged
            assert float(jnp.sum(sol.batch.objective)) >= 0.0
            return
        sol = solve_joint(prob)
        assert bool(prob.constraints_satisfied(sol.a, sol.power,
                                               rtol=1e-3).all())
        assert float(sol.objective) >= 0.0

    def test_make_batch(self):
        batch = make_batch("sparse_energy_starved", 6, seed=0, n_devices=12)
        assert batch.batch_size == 6 and batch.n_max == 12
        sol = solve_joint_batch(batch)
        assert sol.objective.shape == (6,)

    def test_mixed_batch_ragged(self):
        batch = make_mixed_batch(
            ["paper_static", "sparse_energy_starved"], seed=0)
        assert batch.n_max == 100
        sol = solve_joint_batch(batch)
        assert bool(jnp.all(sol.objective > 0))

    def test_unknown_scenario(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            make_problem("nope")


class TestSchedulerBatch:
    def test_precompute_and_sample_batch(self):
        batch = make_batch("paper_static", 4, seed=0, n_devices=16)
        sched = ProbabilisticScheduler()
        state = sched.precompute_batch(batch)
        assert state.a.shape == (4, 16)
        np.testing.assert_allclose(np.asarray(state.agg_weights.sum(1)),
                                   1.0, rtol=1e-5)
        draw = sched.sample_batch(state, jax.random.PRNGKey(0))
        assert draw.mask.shape == (4, 16)
        assert draw.mask.dtype == jnp.bool_
        # each instance matches the per-problem precompute
        for b, prob in enumerate(batch.unstack()):
            ref = sched.precompute(prob)
            np.testing.assert_allclose(np.asarray(state.a[b]),
                                       np.asarray(ref.a), atol=1e-5)
