"""Unit tests for the paper's core algorithm (Algorithms 1-2, eq. 13)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    analytic_power,
    dinkelbach_power,
    optimal_selection,
    sample_problem,
    solve_joint,
    solve_joint_optimal,
    solve_joint_trace,
)

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def problem():
    return sample_problem(42, 64, tau_th=0.08)


def _grid_min_energy(problem, a, i, n_grid=200_000):
    """Brute-force oracle for the fractional program (9) of device i."""
    p_min = float(np.clip(problem.p_min(a)[i], 0, None))
    if p_min > problem.p_max:
        return None
    grid = np.linspace(max(p_min, 1e-9), problem.p_max, n_grid)
    t = problem.grad_size_bits / (np.asarray(problem.bandwidth_hz)[i]
                                  * np.log2(1 + grid * np.asarray(problem.path_gain())[i]))
    obj = float(a[i]) * grid * t
    return grid[np.argmin(obj)], obj.min()


class TestDinkelbach:
    def test_matches_grid_search(self, problem):
        a = jnp.full((problem.n_devices,), 0.02)
        sol = dinkelbach_power(problem, a)
        for i in [0, 7, 23, 55]:
            oracle = _grid_min_energy(problem, a, i)
            if oracle is None:
                assert not bool(sol.feasible[i])
                continue
            p_star, e_star = oracle
            np.testing.assert_allclose(float(sol.power[i]), p_star, rtol=2e-3)
            np.testing.assert_allclose(float(sol.lam[i]), e_star, rtol=2e-3)

    def test_agrees_with_analytic_closed_form(self, problem):
        for a_val in [1e-3, 0.01, 0.05, 0.5]:
            a = jnp.full((problem.n_devices,), a_val)
            d = dinkelbach_power(problem, a)
            an = analytic_power(problem, a)
            np.testing.assert_allclose(np.asarray(d.power), np.asarray(an.power),
                                       rtol=1e-4, atol=1e-9)

    def test_lambda_is_energy_at_solution(self, problem):
        a = jnp.full((problem.n_devices,), 0.02)
        sol = dinkelbach_power(problem, a)
        energy = np.asarray(a * sol.power * problem.tx_time(sol.power))
        np.testing.assert_allclose(np.asarray(sol.lam), energy, rtol=1e-4)

    def test_power_in_box(self, problem):
        a = jnp.full((problem.n_devices,), 0.02)
        sol = dinkelbach_power(problem, a)
        assert bool(jnp.all(sol.power >= -1e-9))
        assert bool(jnp.all(sol.power <= problem.p_max + 1e-9))

    def test_zero_probability_row(self, problem):
        a = jnp.zeros((problem.n_devices,))
        sol = dinkelbach_power(problem, a)
        assert bool(jnp.all(jnp.isfinite(sol.power)))
        np.testing.assert_allclose(np.asarray(sol.lam), 0.0, atol=1e-12)


class TestSelectionClosedForm:
    def test_saturates_tightest_constraint(self, problem):
        p = jnp.full((problem.n_devices,), problem.p_max)
        a = optimal_selection(problem, p)
        t = np.asarray(problem.tx_time(p))
        ec = np.asarray(problem.compute_energy())
        emax = np.asarray(problem.energy_budget_j)
        expected = np.minimum(1.0, np.minimum(problem.tau_th / t,
                                              emax / (np.asarray(p) * t + ec)))
        np.testing.assert_allclose(np.asarray(a), expected, rtol=1e-6)

    def test_feasible_by_construction(self, problem):
        for pval in [0.01, 0.1, problem.p_max]:
            p = jnp.full((problem.n_devices,), pval)
            a = optimal_selection(problem, p)
            assert bool(problem.constraints_satisfied(a, p).all())

    def test_typo_variant_much_smaller(self, problem):
        p = jnp.full((problem.n_devices,), problem.p_max)
        a_fixed = optimal_selection(problem, p)
        a_typo = optimal_selection(problem, p, faithful_eq13_typo=True)
        # verbatim eq. 13 divides the time term by S ~ 6.4e6: collapses a.
        assert float(a_typo.sum()) < float(a_fixed.sum()) * 1e-2


class TestAlternating:
    def test_objective_monotone_after_first_step(self, problem):
        _, trace = solve_joint_trace(problem)
        diffs = np.diff(np.asarray(trace))
        assert np.all(diffs >= -1e-7), trace

    def test_converges(self, problem):
        sol = solve_joint(problem)
        assert bool(sol.converged)
        assert int(sol.n_iters) < 20

    def test_solution_feasible(self, problem):
        sol = solve_joint(problem)
        assert bool(problem.constraints_satisfied(sol.a, sol.power).all())

    def test_jit_and_eager_agree(self, problem):
        eager = solve_joint(problem)
        jitted = jax.jit(solve_joint)(problem)
        np.testing.assert_allclose(np.asarray(eager.a), np.asarray(jitted.a),
                                   rtol=1e-6)

    def test_analytic_power_solver_equivalent(self, problem):
        a1 = solve_joint(problem, power_solver="dinkelbach")
        a2 = solve_joint(problem, power_solver="analytic")
        np.testing.assert_allclose(np.asarray(a1.a), np.asarray(a2.a),
                                   rtol=1e-3, atol=1e-6)


class TestGlobalOptimal:
    def test_dominates_alternating(self, problem):
        alt = solve_joint(problem)
        opt = solve_joint_optimal(problem)
        assert float(opt.objective) >= float(alt.objective) - 1e-7

    def test_feasible(self, problem):
        opt = solve_joint_optimal(problem)
        assert bool(problem.constraints_satisfied(opt.a, opt.power).all())

    def test_tightness(self, problem):
        """a* + epsilon must be infeasible for devices not at a=1 (global opt)."""
        opt = solve_joint_optimal(problem)
        from repro.core.optimal import _feasible
        bumped = jnp.clip(opt.a + 1e-3, 0.0, 1.0)
        interior = np.asarray(opt.a) < 1.0 - 1e-6
        infeas = ~np.asarray(_feasible(problem, bumped))
        assert np.all(infeas[interior])


class TestFading:
    def test_per_round_solutions_differ(self):
        prob = sample_problem(3, 32, n_rounds=8, with_fading=True)
        sol = solve_joint(prob)
        assert sol.a.shape == (32, 8)
        # fading varies per round => probabilities vary per round
        assert float(jnp.std(sol.a, axis=1).max()) > 1e-4
        assert bool(prob.constraints_satisfied(sol.a, sol.power).all())
