"""Coupled multi-cell solver suite (``core.multicell``).

Pins the ISSUE 7 acceptance criteria:

* **identity** — zero coupling + no shared budget: ``solve_coupled``
  returns the uncoupled union fused solve bitwise (the interference
  estimate is elided, so it is literally the same compiled program),
  and agrees with a python loop of per-cell ``solve_joint_fused`` calls
  to solver tolerance;
* **convergence** — the dual residual converges below tolerance on the
  ``metro_coupled`` / ``interference_grid`` registry scenarios;
* **complementary slackness** — exact (knapsack dual) on the shared
  backhaul budget: ``mu > 0`` iff the budget binds, and then the load
  equals the budget;
* **warm duals** — ``init=prev.resume`` collapses the outer loop
  tick-to-tick without changing converged solutions;
* **serving** — ``FleetControlService.solve_coupled`` buckets, caches
  duals per metro, and accounts ticks.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.alternating import solve_joint_fused
from repro.core.batch import solve_joint_batch
from repro.core.multicell import (
    MultiCellProblem,
    _knapsack_round,
    cell_interference,
    grid_coupling,
    make_multicell,
    pad_metro,
    solve_coupled,
    solve_coupled_loop,
)
from repro.core.problem import sample_problem
from repro.core.scenarios import SCENARIOS, make_batch, make_problem

C, N = 4, 16


def _cells(seed=0, n_cells=C, n_devices=N, **kw):
    return [sample_problem(seed + 7_001 * c, n_devices, **kw)
            for c in range(n_cells)]


@pytest.fixture(scope="module")
def uncoupled_mc():
    return make_multicell(_cells(), np.zeros((C, C)))


@pytest.fixture(scope="module")
def grid_mc():
    return make_problem("interference_grid", seed=0, n_cells=4,
                        n_devices=12)


@pytest.fixture(scope="module")
def metro_mc():
    return make_problem("metro_coupled", seed=0, n_cells=4, n_devices=24,
                        backhaul_bits=None)


# ------------------------------------------------------------- identity

def test_zero_coupling_bitwise_identity(uncoupled_mc):
    """Zero coupling, no budget: one outer iteration, bitwise equal to
    the uncoupled union fused solve (same compiled program)."""
    sol = solve_coupled(uncoupled_mc)
    ref = solve_joint_batch(uncoupled_mc.cells, method="fused")
    assert sol.outer_iters == 1
    assert sol.converged
    assert sol.residual == 0.0
    np.testing.assert_array_equal(np.asarray(sol.batch.a),
                                  np.asarray(ref.a))
    np.testing.assert_array_equal(np.asarray(sol.batch.power),
                                  np.asarray(ref.power))
    np.testing.assert_array_equal(np.asarray(sol.batch.objective),
                                  np.asarray(ref.objective))
    assert not sol.interference.any()
    assert float(np.max(np.abs(sol.mu))) == 0.0


def test_zero_coupling_matches_per_cell_fused(uncoupled_mc):
    """Per-cell agreement: the union solve matches a loop of standalone
    ``solve_joint_fused`` calls to solver tolerance (XLA compiles
    different programs for the two shapes, so bitwise is pinned against
    the same-shape union solve above)."""
    sol = solve_coupled(uncoupled_mc)
    for c, prob in enumerate(uncoupled_mc.cells.unstack()):
        ref = solve_joint_fused(prob)
        inst = sol.batch.instance(c)
        np.testing.assert_allclose(np.asarray(inst.a), np.asarray(ref.a),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(inst.power),
                                   np.asarray(ref.power), atol=1e-5)


# ----------------------------------------------------------- validation

def test_make_multicell_validation():
    cells = _cells(n_cells=2)
    with pytest.raises(ValueError, match=r"\[2, 2\]"):
        make_multicell(cells, np.zeros((3, 3)))
    with pytest.raises(ValueError, match="non-negative"):
        make_multicell(cells, np.array([[0.0, -1.0], [0.0, 0.0]]))
    with pytest.raises(ValueError, match="zero diagonal"):
        make_multicell(cells, np.eye(2))
    with pytest.raises(ValueError, match="backhaul_bits"):
        make_multicell(cells, np.zeros((2, 2)), backhaul_bits=0.0)
    mc = make_multicell(cells, np.zeros((2, 2)))
    with pytest.raises(ValueError, match="damping"):
        solve_coupled(mc, damping=0.0)
    with pytest.raises(ValueError, match="outer_iters"):
        solve_coupled(mc, outer_iters=0)


def test_scenarios_registered():
    for name in ("metro_coupled", "interference_grid"):
        assert name in SCENARIOS
        mc = make_problem(name, seed=1, n_cells=2, n_devices=8)
        assert isinstance(mc, MultiCellProblem)
        assert mc.n_cells == 2
        with pytest.raises(ValueError, match="MultiCellProblem"):
            make_batch(name, n_instances=2, n_cells=2, n_devices=8)
    assert SCENARIOS["metro_coupled"](0, n_cells=2, n_devices=8
                                      ).backhaul_bits is not None
    assert SCENARIOS["interference_grid"](0, n_cells=2, n_devices=8
                                          ).backhaul_bits is None


def test_grid_coupling_geometry():
    g = grid_coupling(4, gain=1e-12)
    assert g.shape == (4, 4)
    assert np.all(np.diag(g) == 0)
    assert np.all(g >= 0)
    # 2x2 grid: nearest neighbours at the full gain, the diagonal pair
    # attenuated by dist^alpha = 2
    np.testing.assert_allclose(g[0, 1], 1e-12)
    np.testing.assert_allclose(g[0, 3], 0.5e-12)
    np.testing.assert_allclose(g, g.T)


# ---------------------------------------------------------- convergence

def test_interference_grid_converges(grid_mc):
    sol = solve_coupled(grid_mc)
    assert sol.converged
    assert sol.residual <= 1e-3
    assert np.all(sol.interference > 0)
    # interference can only shrink participation vs the uncoupled solve
    ref = solve_joint_batch(grid_mc.cells, method="fused")
    assert float(jnp.sum(sol.batch.a)) < float(jnp.sum(ref.a))
    # the returned solution is feasible for the interference it reports
    cells = sol.batch
    for c, prob in enumerate(grid_mc.cells.unstack()):
        noisy = dataclasses.replace(
            prob, interference=jnp.full((prob.n_devices,),
                                        float(sol.interference[c]),
                                        jnp.float32))
        inst = cells.instance(c)
        ok = noisy.constraints_satisfied(inst.a, inst.power, rtol=1e-3)
        assert bool(np.all(np.asarray(ok)))


def test_interference_fixed_point_consistent(grid_mc):
    """The reported interference is the fixed point of the reported
    solution (the KKT primal-consistency condition)."""
    sol = solve_coupled(grid_mc, outer_tol=1e-4)
    i_implied = cell_interference(np.asarray(grid_mc.coupling),
                                  np.asarray(sol.batch.a),
                                  np.asarray(sol.batch.power))
    np.testing.assert_allclose(i_implied, sol.interference, rtol=2e-3)


def test_metro_coupled_slackness(metro_mc):
    """The shared budget binds on metro_coupled: mu > 0, load == budget
    (exact complementary slackness from the knapsack dual)."""
    sol = solve_coupled(metro_mc)
    budget = metro_mc.backhaul_bits
    assert sol.converged
    load = float(sol.backhaul_load)
    assert float(sol.mu) > 0.0
    np.testing.assert_allclose(load, budget, rtol=1e-9)
    assert load <= budget * (1 + 1e-9)
    # uncoupled demand genuinely exceeds the budget (the constraint is
    # active, not vacuous)
    ref = solve_joint_batch(metro_mc.cells, method="fused")
    s_bits = metro_mc.cells.problem.grad_size_bits
    assert float(jnp.sum(ref.a)) * s_bits > budget


def test_slack_budget_gives_zero_price(uncoupled_mc):
    """A budget that never binds: mu == 0 and the caps pass through
    untouched (slackness from the other side)."""
    mc = MultiCellProblem(cells=uncoupled_mc.cells,
                          coupling=uncoupled_mc.coupling,
                          backhaul_bits=1e18)
    sol = solve_coupled(mc)
    ref = solve_joint_batch(uncoupled_mc.cells, method="fused")
    assert float(np.max(np.abs(sol.mu))) == 0.0
    assert float(sol.backhaul_load) < 1e18
    np.testing.assert_array_equal(np.asarray(sol.batch.a),
                                  np.asarray(ref.a))


def test_knapsack_round_optimality():
    """Unit-level dual certificate: kept weights >= mu >= cut weights,
    load == budget exactly, caps respected."""
    rng = np.random.default_rng(0)
    caps = rng.uniform(0.0, 1.0, 64)
    w = rng.uniform(0.0, 1.0, 64)
    s_bits, budget = 10.0, 0.4 * caps.sum() * 10.0
    a, mu, load = _knapsack_round(caps, w, s_bits, budget)
    assert mu > 0.0
    np.testing.assert_allclose(load, budget)
    np.testing.assert_allclose(a.sum() * s_bits, budget)
    assert np.all(a <= caps + 1e-12) and np.all(a >= 0)
    full = a >= caps - 1e-12
    cut = a <= 1e-12
    assert np.all(w[full & (caps > 0)] >= mu - 1e-12)
    assert np.all(w[cut & (caps > 0)] <= mu + 1e-12)
    # slack budget: untouched caps, zero price
    a2, mu2, load2 = _knapsack_round(caps, w, s_bits, 1e9)
    assert mu2 == 0.0
    np.testing.assert_array_equal(a2, caps)
    np.testing.assert_allclose(load2, caps.sum() * s_bits)


# --------------------------------------------------- reference agreement

def test_loop_reference_agrees(metro_mc):
    """One union fused solve per outer step == a python loop of per-cell
    fused solves (to solver tolerance), duals included."""
    sol = solve_coupled(metro_mc)
    ref = solve_coupled_loop(metro_mc)
    assert ref.converged
    np.testing.assert_allclose(np.asarray(sol.batch.a),
                               np.asarray(ref.batch.a), atol=1e-5)
    np.testing.assert_allclose(sol.interference, ref.interference,
                               rtol=1e-3)
    np.testing.assert_allclose(np.atleast_1d(sol.mu),
                               np.atleast_1d(ref.mu), atol=1e-6)


# ------------------------------------------------------------ warm duals

def test_warm_duals_collapse_outer_loop(metro_mc):
    cold = solve_coupled(metro_mc)
    warm = solve_coupled(metro_mc, init=cold.resume)
    assert warm.converged
    assert warm.outer_iters == 1
    np.testing.assert_allclose(np.asarray(warm.batch.a),
                               np.asarray(cold.batch.a), atol=1e-3)
    np.testing.assert_allclose(warm.interference, cold.interference,
                               rtol=1e-2)


def test_warm_duals_on_drifted_tick(grid_mc):
    """Warm duals from tick t seed tick t+1 after a small channel drift:
    fewer outer iterations, same converged answer as a cold solve."""
    cold_t0 = solve_coupled(grid_mc)
    drifted = MultiCellProblem(
        cells=dataclasses.replace(
            grid_mc.cells,
            problem=dataclasses.replace(
                grid_mc.cells.problem,
                distance_m=grid_mc.cells.problem.distance_m * 1.01)),
        coupling=grid_mc.coupling, backhaul_bits=grid_mc.backhaul_bits)
    cold = solve_coupled(drifted)
    warm = solve_coupled(drifted, init=cold_t0.resume)
    assert warm.converged and cold.converged
    assert warm.outer_iters <= cold.outer_iters
    np.testing.assert_allclose(np.asarray(warm.batch.a),
                               np.asarray(cold.batch.a), atol=1e-3)


def test_mismatched_warm_state_runs_cold(metro_mc):
    """Shape-mismatched duals (metro resized) are ignored, not crashed on."""
    other = make_problem("metro_coupled", seed=3, n_cells=2, n_devices=8)
    seed = solve_coupled(other).resume
    sol = solve_coupled(metro_mc, init=seed)
    assert sol.converged


# ------------------------------------------------------------ fading / K

def test_fading_metro_per_round_duals():
    probs = _cells(seed=5, n_cells=3, n_devices=8, with_fading=True,
                   n_rounds=4)
    g = grid_coupling(3, gain=1e-12)
    s_bits = probs[0].grad_size_bits
    mc = make_multicell(probs, g, backhaul_bits=1.0 * s_bits)
    sol = solve_coupled(mc)
    assert sol.converged
    assert sol.interference.shape == (3, 4)     # [C, K]
    assert np.shape(sol.mu) == (4,)             # per-round prices
    assert np.shape(sol.backhaul_load) == (4,)
    # complementary slackness per round
    for k in range(4):
        slack = mc.backhaul_bits - float(sol.backhaul_load[k])
        assert float(sol.mu[k]) * slack <= 1e-6 * mc.backhaul_bits
        assert float(sol.backhaul_load[k]) <= mc.backhaul_bits * (1 + 1e-9)


# ------------------------------------------------------------ pad_metro

def test_pad_metro_is_transparent(grid_mc):
    padded = pad_metro(grid_mc, n_cells=8, n_max=16)
    assert padded.n_cells == 8
    assert padded.cells.n_max == 16
    assert padded.backhaul_bits == grid_mc.backhaul_bits
    g = np.asarray(padded.coupling)
    np.testing.assert_array_equal(g[:4, :4], np.asarray(grid_mc.coupling))
    assert not g[4:, :].any() and not g[:, 4:].any()
    sol = solve_coupled(padded)
    ref = solve_coupled(grid_mc)
    assert sol.converged
    # padded cells select nothing and radiate nothing
    assert not np.asarray(sol.batch.a)[4:].any()
    np.testing.assert_allclose(sol.interference[:4], ref.interference,
                               rtol=1e-3)
    for c in range(4):
        np.testing.assert_allclose(np.asarray(sol.batch.a)[c, :12],
                                   np.asarray(ref.batch.a)[c], atol=1e-5)


# --------------------------------------------------------------- serving

def test_service_solve_coupled_warm_ticks(metro_mc):
    from repro.serve import FleetControlService, ServiceConfig

    svc = FleetControlService(ServiceConfig())
    r1 = svc.solve_coupled("m0", metro_mc)
    r2 = svc.solve_coupled("m0", metro_mc)
    assert not r1.warm_started and r2.warm_started
    assert r1.solution.converged and r2.solution.converged
    assert r2.solution.outer_iters <= r1.solution.outer_iters
    assert r1.n_cells == metro_mc.n_cells
    # bucketed: 4 cells -> 4 slots, 24 devices -> 32
    assert r1.solution.batch.a.shape[0] == 4
    assert r1.solution.batch.a.shape[1] == 32
    counters = svc.stats.counter_summary()
    assert counters["metro_ticks"] == 2
    assert counters["metro_warm"] == 1
    assert counters["metro_outer_iters"] >= 2
    # a different metro id runs cold
    assert not svc.solve_coupled("m1", metro_mc).warm_started
    # a resized metro under the same id drops the stale duals
    small = make_problem("metro_coupled", seed=2, n_cells=2, n_devices=8)
    assert not svc.solve_coupled("m0", small).warm_started


def test_quantized_key_sees_interference():
    from repro.serve import quantized_problem_key

    prob = sample_problem(0, 8)
    k0 = quantized_problem_key(prob)
    with_zero = dataclasses.replace(prob,
                                    interference=jnp.zeros(8, jnp.float32))
    strong = dataclasses.replace(
        prob, interference=jnp.full(8, 1e-10, jnp.float32))
    assert quantized_problem_key(with_zero) != k0
    assert quantized_problem_key(strong) != quantized_problem_key(with_zero)
