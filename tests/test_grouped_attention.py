"""Perf-iteration-4 parity: grouped GQA attention == repeat-KV attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


@pytest.mark.parametrize("h,hkv,window,softcap", [
    (8, 2, None, None),
    (8, 8, None, 50.0),
    (4, 1, 16, None),
    (12, 4, 32, 30.0),
])
def test_grouped_matches_repeat(h, hkv, window, softcap):
    rng = np.random.default_rng(h * 7 + hkv)
    b, sq, skv, dh = 2, 24, 48, 32
    spec = L.AttnLayerSpec(n_heads=h, n_kv_heads=hkv, d_head=dh, theta=1e4,
                           window=window, softcap=softcap, qk_norm=False,
                           use_rope=True)
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, hkv, dh)), jnp.float32)
    qp = jnp.arange(24, 24 + sq, dtype=jnp.int32)
    kp = jnp.arange(skv, dtype=jnp.int32)
    ref = L._attend_block(q, L._repeat_kv(k, h), L._repeat_kv(v, h), qp, kp, spec)
    got = L._attend_block_grouped(q, k, v, qp, kp, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flag_switches_model_forward():
    """Full model forward identical under both attention paths."""
    from repro.configs import ARCHS
    from repro.models import transformer as T
    from repro.models.zoo import make_batch
    from repro.configs.base import InputShape
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, InputShape("s", 64, 2, "train"),
                       np.random.default_rng(0), with_weights=False)
    try:
        L.set_gqa_grouped(False)
        base, _ = T.forward(cfg, params, batch, q_chunk=32)
        L.set_gqa_grouped(True)
        grouped, _ = T.forward(cfg, params, batch, q_chunk=32)
    finally:
        L.set_gqa_grouped(False)
    np.testing.assert_allclose(np.asarray(grouped), np.asarray(base),
                               rtol=3e-4, atol=3e-4)
