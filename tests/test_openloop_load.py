"""Seeded open-loop load tests (slow tier, ``--runslow``).

The end-to-end serving claims of the ISSUE, measured rather than
assumed:

* **Poisson at 0.8x measured capacity** over ``drifting_metro`` cells
  sustains **zero deadline misses**, with **warm-fraction >= 0.5** after
  the first coherence interval (every cell has cached state by then);
* a **bursty trace** exercises the priority lane: drifted cells jump
  ahead of stale-tolerant traffic (completion-order inversions against
  submission order), preemptions are counted, and the run is fully
  deterministic under the virtual clock.

Wall-clock assertions are deliberately loose (deadline budgets are
expressed in units of the *measured* batch cost, so they transfer
across machines); the sharp assertions are the counter-based ones.
"""
import pytest

from repro.core import slice_round
from repro.serve import (
    FleetControlService,
    ServiceConfig,
    bursty_trace,
    drive,
    make_cells,
    measure_capacity,
    poisson_trace,
)

pytestmark = pytest.mark.slow


class TestPoissonLoad:
    def test_08x_capacity_sustains_zero_misses_and_warm_cache(self):
        n_cells, n_req = 4, 160
        cells = make_cells(n_cells, n_devices=32, n_rounds=10, seed=2)
        svc = FleetControlService(ServiceConfig(max_batch=8))
        probe = [slice_round(c, 0) for c in cells]
        svc.warmup(probe[0], max_devices=32)
        cap = measure_capacity(svc, probe)
        svc.stats.reset()
        assert cap > 0

        # budget = 24 full-batch solve costs at measured capacity: tight
        # enough to mean something, loose enough to absorb queueing at
        # 0.8x load plus scheduler hiccups on a shared CI runner
        deadline = 24.0 * svc.config.max_batch / cap
        trace = poisson_trace(cells, rate_hz=0.8 * cap, n_requests=n_req,
                              seed=5, deadline_s=deadline)
        # the first coherence interval: every cell seen (and cached) once
        # over ~2 rounds of arrivals; stats reset there -> steady state
        rep = drive(svc, trace, reset_stats_after=2 * n_cells)

        assert len(rep.responses) == n_req
        assert not any(r.deadline_missed for r in rep.responses)
        assert svc.stats.n_deadline_misses == 0
        # steady state: the drifting stream warm-starts from cached state
        assert svc.stats.warm_fraction >= 0.5
        # offered 0.8x capacity must be sustainable (generous margin for
        # shared CI runners)
        assert rep.sustained_rate_hz >= 0.4 * rep.offered_rate_hz

    def test_overload_sheds_into_full_batches(self):
        """Past capacity the close policy must degrade the right way:
        the backlog fills buckets (full closes dominate), instead of
        thrashing tiny linger batches."""
        cells = make_cells(3, n_devices=32, n_rounds=8, seed=7)
        svc = FleetControlService(ServiceConfig(max_batch=8))
        probe = [slice_round(c, 0) for c in cells]
        svc.warmup(probe[0], max_devices=32)
        cap = measure_capacity(svc, probe)
        svc.stats.reset()

        trace = poisson_trace(cells, rate_hz=3.0 * cap, n_requests=96,
                              seed=6)
        rep = drive(svc, trace)
        assert len(rep.responses) == 96
        closes = svc.stats.closes
        assert closes.get("full", 0) > closes.get("linger", 0)
        # saturation: mean batch near the full bucket
        assert svc.stats.n_solved / svc.stats.n_batches >= \
            0.5 * svc.config.max_batch


class TestBurstyPriorityLane:
    def _run(self):
        # stale-tolerant traffic: 1-round cells resubmit an identical
        # problem forever (feature key never moves -> normal lane);
        # drifting cells move every burst (key drifts -> priority lane)
        static = make_cells(2, n_devices=12, n_rounds=1, seed=40)
        drifting = make_cells(2, n_devices=12, n_rounds=6, seed=44,
                              coherence=0.5)
        trace = bursty_trace(static + drifting, burst_rate_hz=2000.0,
                             burst_len=10, n_bursts=3, idle_s=0.05,
                             seed=9)
        svc = FleetControlService(ServiceConfig(max_batch=4,
                                                cost_smoothing=0.0,
                                                record_batches=True))
        rep = drive(svc, trace, clock="virtual")
        return svc, trace, rep

    def test_drifted_cells_preempt_stale_tolerant_traffic(self):
        svc, trace, rep = self._run()
        assert len(rep.responses) == len(trace)
        # the lane machinery actually fired
        assert svc.stats.n_priority > 0
        assert svc.stats.n_preemptions >= 1
        assert any(rec.priority for rec in svc.batch_log)
        # drifted cells (ids 2,3) jump the queue: some response for a
        # drifted cell completes before a stale-tolerant request that
        # was submitted earlier
        order = [(r.seq, r.cell_id) for r in rep.responses]
        inverted = any(
            d_pos < s_pos
            for d_pos, (d_seq, d_cell) in enumerate(order) if d_cell >= 2
            for s_pos, (s_seq, s_cell) in enumerate(order)
            if s_cell < 2 and s_seq < d_seq)
        assert inverted
        # and after their cold first round, drifted requests ride the
        # warm per-cell cache despite the key drift — warmth is only
        # possible once the cell completed in an *earlier* batch (two
        # requests of one cell inside the same micro-batch cannot seed
        # each other), so gate the assertion on the batch log
        batch_of = {s: bi for bi, rec in enumerate(svc.batch_log)
                    for s in rec.seqs}
        first_done = {}
        for bi, rec in enumerate(svc.batch_log):
            for c in rec.cell_ids:
                first_done.setdefault(c, bi)
        checked = 0
        for r in rep.responses:
            if r.cell_id >= 2 and batch_of[r.seq] > first_done[r.cell_id]:
                assert r.warm_started, r
                checked += 1
        assert checked > 0   # the gated assertion actually saw requests

    def test_bursty_run_is_deterministic(self):
        svc1, _, _ = self._run()
        svc2, _, _ = self._run()
        assert svc1.stats.counter_summary() == svc2.stats.counter_summary()
        assert svc1.batch_log == svc2.batch_log
