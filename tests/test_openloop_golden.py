"""Golden/determinism suite for the open-loop control plane.

Under a virtual clock (``drive(..., clock="virtual")``) with
``cost_smoothing=0`` the whole open-loop run — batch composition, close
reasons, warm/priority/deadline counters, and the solutions themselves —
is a deterministic function of the seeded arrival trace.  This suite
pins that:

* every response is **bit-identical** to a direct cold
  ``solve_joint_batch`` on the same padded micro-batch (warm starts only
  seed the inner solver; they never change the answer — the PR-4
  invariant, now held through the open-loop path);
* a repeated run with the same seed reproduces the identical
  ``ServiceStats.counter_summary()`` and ``batch_log`` (latency fields
  are wall-clock and explicitly excluded);
* a slow-marked cross-process variant sha256-hashes counters + solution
  bytes in fresh interpreters and compares digests.
"""
import hashlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import solve_joint_batch, stack_problems
from repro.core.batch import pad_batch
from repro.serve import (
    FleetControlService,
    ServiceConfig,
    drive,
    make_cells,
    poisson_trace,
)

# cost_smoothing=0 freezes the cost model prior, so close decisions (and
# therefore batch composition) depend only on the trace timestamps
CFG = dict(max_batch=4, cost_smoothing=0.0, record_batches=True)


def _run_trace(seed=3):
    cells = make_cells(3, n_devices=12, n_rounds=4, seed=11)
    trace = poisson_trace(cells, rate_hz=400.0, n_requests=36, seed=seed,
                          deadline_s=0.05)
    svc = FleetControlService(ServiceConfig(**CFG))
    rep = drive(svc, trace, clock="virtual")
    return svc, trace, rep


def _solution_digest(responses):
    h = hashlib.sha256()
    for r in sorted(responses, key=lambda r: r.seq):
        h.update(np.ascontiguousarray(np.asarray(r.solution.a)).tobytes())
        h.update(np.ascontiguousarray(np.asarray(r.solution.power)).tobytes())
    return h.hexdigest()


class TestGoldenAgainstDirectSolve:
    def test_responses_bit_identical_to_solve_joint_batch(self):
        """Rebuild every served micro-batch from the ``batch_log`` and
        solve it cold and directly: the open-loop responses (queueing,
        warm seeds, priority lanes and all) must match bitwise."""
        svc, trace, rep = _run_trace()
        by_seq = {r.seq: r for r in rep.responses}
        assert len(by_seq) == len(trace)          # all served exactly once
        assert len(svc.batch_log) == svc.stats.n_batches
        for rec in svc.batch_log:
            probs = [trace[s - 1].problem for s in rec.seqs]
            batch = pad_batch(stack_problems(probs),
                              batch_size=CFG["max_batch"],
                              n_max=rec.n_bucket)
            ref = solve_joint_batch(batch, method="fused")
            ref_a, ref_p = np.asarray(ref.a), np.asarray(ref.power)
            for i, s in enumerate(rec.seqs):
                got = by_seq[s].solution
                n = probs[i].n_devices
                np.testing.assert_array_equal(np.asarray(got.a),
                                              ref_a[i, :n])
                np.testing.assert_array_equal(np.asarray(got.power),
                                              ref_p[i, :n])

    def test_trace_is_actually_batched(self):
        """Guard the guard: the golden comparison is vacuous if every
        batch has one request, so check real multi-request batches (and
        warm-started responses) occurred."""
        svc, _, rep = _run_trace()
        assert any(len(rec.seqs) > 1 for rec in svc.batch_log)
        assert any(r.warm_started for r in rep.responses)


class TestSeededDeterminism:
    def test_same_seed_identical_counters_and_batches(self):
        svc1, _, rep1 = _run_trace(seed=3)
        svc2, _, rep2 = _run_trace(seed=3)
        # latency fields excluded by construction: counter_summary holds
        # only trace-determined integers
        assert svc1.stats.counter_summary() == svc2.stats.counter_summary()
        assert svc1.batch_log == svc2.batch_log
        assert _solution_digest(rep1.responses) == \
            _solution_digest(rep2.responses)

    def test_different_seed_differs(self):
        svc1, _, _ = _run_trace(seed=3)
        svc2, _, _ = _run_trace(seed=4)
        assert svc1.batch_log != svc2.batch_log


_CROSS_PROCESS_SCRIPT = """
import hashlib, json
import numpy as np
from repro.serve import (FleetControlService, ServiceConfig, drive,
                         make_cells, poisson_trace)

cells = make_cells(3, n_devices=12, n_rounds=4, seed=11)
trace = poisson_trace(cells, rate_hz=400.0, n_requests=36, seed=3,
                      deadline_s=0.05)
svc = FleetControlService(ServiceConfig(max_batch=4, cost_smoothing=0.0,
                                        record_batches=True))
rep = drive(svc, trace, clock="virtual")
h = hashlib.sha256()
h.update(json.dumps(svc.stats.counter_summary(), sort_keys=True).encode())
h.update(repr(svc.batch_log).encode())
for r in sorted(rep.responses, key=lambda r: r.seq):
    h.update(np.ascontiguousarray(np.asarray(r.solution.a)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(r.solution.power)).tobytes())
print("DIGEST", h.hexdigest())
"""


@pytest.mark.slow
class TestCrossProcess:
    def test_cross_process_sha256(self):
        """Two fresh interpreters replay the same seeded trace to the
        same sha256 over counters + batch log + solution bytes — no
        hidden dependence on process state, hash seeds, or jit cache
        history."""
        def digest():
            out = subprocess.run(
                [sys.executable, "-c", _CROSS_PROCESS_SCRIPT],
                capture_output=True, text=True, timeout=600, check=True)
            lines = [ln for ln in out.stdout.splitlines()
                     if ln.startswith("DIGEST ")]
            assert lines, out.stdout + out.stderr
            return lines[-1].split()[1]

        assert digest() == digest()
