"""Batched serving launcher test."""
import pytest

from repro.launch.serve import main as serve_main


@pytest.mark.slow
def test_batched_server_serves_all_requests():
    stats = serve_main(["--arch", "gemma3-1b", "--requests", "5",
                        "--batch", "2", "--gen", "6"])
    assert stats["requests"] == 5
    assert all(len(c) == 6 for c in stats["completions"].values())
    assert stats["tokens"] == 30
