"""Fault-tolerance suite: boundary hardening, chaos harness, degraded modes.

Pins the ISSUE 8 acceptance criteria:

* **NaN regression** — the division guards in ``core.problem`` /
  ``core.power``: a zero/NaN/Inf channel gain yields the
  infeasible-device gate (``P^min = inf``), never a NaN that escapes
  through ``solve_joint_fused``;
* **health boundary** — ``health_mask`` / ``sanitize`` / ``validate``
  map corrupted devices to self-deselecting no-ops (``a = 0``, zero
  power) and are bitwise identities on healthy problems;
* **graceful degradation** — unconverged batches retry once through the
  reference path, repeatedly-failing buckets trip a per-bucket circuit
  breaker that sheds (cached-or-zero) instead of hanging, and
  ``solve_coupled`` returns best-feasible-so-far at its iteration cap;
* **chaos harness** — seeded ``FaultPlan`` corruption replays
  identically, composes with the open-loop driver, and never leaks a
  non-finite solution;
* **degraded training** — dropped uploads leave the eq.-4 aggregation
  (survivors only) while their energy stays charged, with an all-False
  drop table bitwise identical to the fault-free program;
* **crash safety** — ``solve_rounds`` checkpoint/resume reproduces the
  uninterrupted control table bitwise on a fresh service.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.alternating import solve_joint_fused
from repro.core.batch import solve_joint_batch, stack_problems
from repro.core.multicell import make_multicell, solve_coupled
from repro.core.power import element_p_min
from repro.core.problem import sample_problem
from repro.core.scenarios import make_problem, slice_round
from repro.fl.closed_loop import ClosedLoopConfig, run_closed_loop_grid, solve_rounds
from repro.fl.engine import FLConfig
from repro.fl.scan_engine import (
    init_sweep_params,
    plan_trajectory,
    run_fl_sweep,
    stack_plans,
)
from repro.serve import (
    CHANNEL_KINDS,
    FaultPlan,
    FleetControlService,
    ServiceConfig,
    chaos_drive,
    corrupt_problem,
    corrupt_trace,
    count_nonfinite,
    dropout_mask,
    make_cells,
    poisson_trace,
)

N = 16


def _drifting(n_devices=N, n_rounds=4, seed=0):
    return make_problem("drifting_metro", seed=seed, n_devices=n_devices,
                        n_rounds=n_rounds)


def _corrupt_fading(problem, entries):
    fad = np.array(problem.fading, np.float32)
    for (i, k), v in entries.items():
        fad[i, k] = v
    return dataclasses.replace(problem, fading=jnp.asarray(fad))


def _finite(sol):
    return (np.isfinite(np.asarray(sol.a)).all()
            and np.isfinite(np.asarray(sol.power)).all())


# ------------------------------------------------------- division guards

def test_p_min_zero_gain_is_infeasible_gate_not_nan():
    # the regression this PR fixes: a = 0 with pg = 0 used to emit
    # expm1(0)/0 = NaN; now zero/negative gain reads as P^min = inf
    a = jnp.array([0.0, 0.5, 0.5, 0.5])
    pg = jnp.array([0.0, 0.0, jnp.nan, 1e-3])
    out = element_p_min(a, pg, jnp.float32(1e6), s_bits=1e4, tau=0.5)
    assert bool(jnp.isinf(out[0])) and bool(jnp.isinf(out[1]))
    assert bool(jnp.isinf(out[2]))          # NaN gain fails pg > 0 too
    assert bool(jnp.isfinite(out[3]))


@pytest.mark.parametrize("bad", [0.0, np.nan, np.inf])
def test_fused_solver_finite_under_corrupted_gain(bad):
    # pre-guard, a single corrupted fading entry NaN-poisoned the whole
    # fused while-loop; post-guard every output element stays finite
    prob = _corrupt_fading(_drifting(), {(1, 0): bad, (5, 2): bad})
    sol = solve_joint_fused(prob, sanitize=True)
    assert _finite(sol)
    assert bool(sol.converged)


def test_path_gain_zero_fading_times_inf_distance():
    # 0 * inf in path_gain: zero fading on an (unphysical) zero-distance
    # row must not manufacture NaN
    prob = _drifting()
    d = np.array(prob.distance_m, np.float64)
    d[0] = 0.0
    fad = np.array(prob.fading, np.float32)
    fad[0, :] = 0.0
    prob = dataclasses.replace(prob, distance_m=jnp.asarray(d),
                               fading=jnp.asarray(fad))
    assert np.isfinite(np.asarray(prob.path_gain())[0]).all()


# --------------------------------------------------- health mask boundary

def test_health_mask_flags_each_corruption():
    prob = _drifting()
    fad = np.array(prob.fading, np.float32)
    fad[1, 0] = np.nan
    fad[3, 2] = np.inf
    fad[5, 1] = 0.0
    prob = dataclasses.replace(prob, fading=jnp.asarray(fad))
    health = prob.health_mask(xp=np)
    assert health.shape == (N,)
    # device granularity: one bad round marks the whole device
    assert not health[1] and not health[3] and not health[5]
    assert health.sum() == N - 3


def test_health_mask_non_channel_leaves():
    prob = _drifting()
    eb = np.array(prob.energy_budget_j, np.float32)
    eb[2] = -1.0
    bw = np.array(prob.bandwidth_hz, np.float32)
    bw[4] = 0.0
    prob = dataclasses.replace(prob, energy_budget_j=jnp.asarray(eb),
                               bandwidth_hz=jnp.asarray(bw))
    health = prob.health_mask(xp=np)
    assert not health[2] and not health[4] and health.sum() == N - 2


def test_sanitize_is_bitwise_identity_on_healthy_problem():
    prob = _drifting()
    clean, health = prob.sanitize()
    assert bool(np.asarray(health).all())
    for f in ("distance_m", "bandwidth_hz", "energy_budget_j",
              "dataset_size", "cycles_per_sample", "cpu_hz", "weights",
              "fading"):
        a = np.asarray(getattr(prob, f))
        b = np.asarray(getattr(clean, f))
        assert np.array_equal(a, b), f


def test_sanitized_devices_self_deselect_in_solve():
    prob = _corrupt_fading(_drifting(), {(2, 0): np.nan, (7, 1): np.inf})
    sol = solve_joint_fused(prob, sanitize=True)
    a = np.asarray(sol.a)
    p = np.asarray(sol.power)
    assert np.all(a[[2, 7]] == 0.0) and np.all(p[[2, 7]] == 0.0)
    # healthy rows solve exactly as if the corrupted devices were
    # replaced by padding (the NEUTRAL_FILLS idiom)
    assert _finite(sol)


def test_validate_names_unhealthy_devices():
    prob = _corrupt_fading(_drifting(), {(3, 1): np.nan})
    with pytest.raises(ValueError, match=r"\b3\b"):
        prob.validate()
    _drifting().validate()                  # healthy: no raise


# -------------------------------------------------------- chaos harness

@pytest.mark.parametrize("kind", CHANNEL_KINDS)
def test_corrupt_problem_kinds_stay_finite_through_service(kind):
    prob = slice_round(_drifting(), 0)
    bad = corrupt_problem(prob, kind, rng=np.random.default_rng(0),
                          device_rate=0.25)
    svc = FleetControlService(ServiceConfig())
    resp, = svc.run([("cell", bad)])
    assert _finite(resp.solution)
    if kind != "deep_fade":                 # deep fades stay *healthy*
        assert resp.n_unhealthy > 0


def test_corrupt_trace_is_seeded_and_composable():
    cells = make_cells(2, n_devices=N, n_rounds=3, seed=0)
    trace = poisson_trace(cells, rate_hz=100.0, n_requests=12, seed=1)
    plan = FaultPlan(seed=5, fault_rate=0.5)
    t1, n1 = corrupt_trace(trace, plan)
    t2, n2 = corrupt_trace(trace, plan)
    assert n1 == n2 > 0
    for a, b in zip(t1, t2):
        assert np.array_equal(np.asarray(a.problem.fading),
                              np.asarray(b.problem.fading),
                              equal_nan=True)
    # a different seed lands on different corruption
    t3, _ = corrupt_trace(trace, dataclasses.replace(plan, seed=6))
    assert any(not np.array_equal(np.asarray(a.problem.fading),
                                  np.asarray(b.problem.fading),
                                  equal_nan=True)
               for a, b in zip(t1, t3))


def test_chaos_drive_no_nan_escape_and_complete():
    cells = make_cells(2, n_devices=N, n_rounds=3, seed=0)
    trace = poisson_trace(cells, rate_hz=200.0, n_requests=16, seed=2)
    svc = FleetControlService(ServiceConfig(cost_smoothing=0.0))
    plan = FaultPlan(kinds=CHANNEL_KINDS + ("cost_spike",), seed=7,
                     fault_rate=0.4)
    rep = chaos_drive(svc, trace, plan)
    assert len(rep.report.responses) == len(trace)   # no hang, no loss
    assert rep.nan_escapes == 0
    assert rep.n_faulted > 0
    assert rep.n_unhealthy_devices > 0
    assert rep.counters["unhealthy_devices"] == rep.n_unhealthy_devices


def test_fault_free_cohabitant_bitwise_unaffected():
    # a fully-faulted problem sanitises to all-neutral rows (the padding
    # idiom), so sharing a micro-batch with it cannot perturb the fused
    # while-loop's trip count: the clean response is bitwise identical
    prob = slice_round(_drifting(), 0)
    dead = corrupt_problem(prob, "device_dropout",
                           rng=np.random.default_rng(0), device_rate=1.0)
    solo, = FleetControlService(ServiceConfig()).run([("clean", prob)])
    both = FleetControlService(ServiceConfig()).run(
        [("clean", prob), ("dead", dead)])
    co = next(r for r in both if r.cell_id == "clean")
    assert np.array_equal(np.asarray(solo.solution.a),
                          np.asarray(co.solution.a))
    assert np.array_equal(np.asarray(solo.solution.power),
                          np.asarray(co.solution.power))


# ------------------------------------------------- degraded-mode service

def _force_unconverged(svc):
    """Monkeypatch the fast path to report non-convergence (the retry
    path calls ``solve_joint_batch`` directly, so it stays real)."""
    orig = svc._solve
    def broken(batch, init):
        sol = orig(batch, init)
        return sol._replace(converged=jnp.zeros_like(sol.converged))
    svc._solve = broken


def test_unconverged_batch_retries_through_reference_path():
    svc = FleetControlService(ServiceConfig())
    _force_unconverged(svc)
    resp, = svc.run([("c", slice_round(_drifting(), 0))])
    assert resp.retried and resp.converged
    assert svc.stats.n_retries == 1 and svc.stats.n_unconverged == 0
    assert _finite(resp.solution)


def test_circuit_breaker_opens_sheds_and_recovers():
    cfg = ServiceConfig(retry_unconverged=False, breaker_threshold=2,
                        breaker_cooldown=2)
    svc = FleetControlService(cfg)
    _force_unconverged(svc)
    prob = slice_round(_drifting(), 0)
    svc.run([("c0", prob)])                 # streak 1
    svc.run([("c0", prob)])                 # streak 2 -> breaker opens
    assert svc.stats.breaker_opens == 1
    assert svc.stats.retry_backoff_s > 0.0
    shed, = svc.run([("c0", prob)])         # cooldown tick 1: shed
    assert shed.shed and not shed.converged
    # shed-from-cache: c0 solved before, so the cached table comes back
    assert shed.warm_started
    assert _finite(shed.solution)
    svc.run([("c0", prob)])                 # cooldown tick 2: shed
    assert svc.stats.n_shed == 2
    # half-open probe: restore the real solver and watch it recover
    svc._solve = FleetControlService.__dict__["_solve"].__get__(svc)
    ok, = svc.run([("c0", prob)])
    assert not ok.shed and ok.converged
    assert svc._fail_streak[16] == 0


def test_shed_without_cache_returns_zero_solution():
    cfg = ServiceConfig(retry_unconverged=False)
    svc = FleetControlService(cfg)
    svc._breaker_open[16] = 1               # force the breaker open
    resp, = svc.run([("never-seen", slice_round(_drifting(), 0))])
    assert resp.shed and not resp.warm_started
    assert np.all(np.asarray(resp.solution.a) == 0.0)
    assert np.all(np.asarray(resp.solution.power) == 0.0)


def test_counter_summary_carries_fault_counters():
    svc = FleetControlService(ServiceConfig())
    c = svc.stats.counter_summary()
    for key in ("unconverged", "retries", "shed", "unhealthy_devices",
                "breaker_opens", "metro_caps"):
        assert c[key] == 0
    s = svc.stats.summary()
    assert s["retry_backoff_s"] == 0.0


def test_response_surfaces_convergence_and_iters():
    svc = FleetControlService(ServiceConfig())
    resp, = svc.run([("c", slice_round(_drifting(), 0))])
    assert resp.converged is True
    assert resp.n_iters >= 1
    assert resp.n_iters == int(np.asarray(resp.solution.n_iters))


# ------------------------------------------------------ coupled degraded

def test_make_multicell_rejects_nonfinite_coupling():
    cells = [sample_problem(7_001 * c, 8) for c in range(2)]
    g = np.zeros((2, 2))
    g[0, 1] = np.inf
    with pytest.raises(ValueError, match="finite"):
        make_multicell(cells, g)


def test_solve_coupled_cap_returns_best_feasible_so_far():
    mc = make_problem("interference_grid", seed=0, n_cells=4, n_devices=12)
    capped = solve_coupled(mc, outer_iters=1)
    full = solve_coupled(mc, outer_iters=40)
    assert bool(full.converged) and not full.hit_iter_cap
    if not bool(capped.converged):
        assert capped.hit_iter_cap
    assert np.isfinite(np.asarray(capped.batch.a)).all()
    assert np.isfinite(np.asarray(capped.batch.power)).all()


def test_solve_coupled_sanitize_degrades_corrupted_cell():
    cells = [sample_problem(7_001 * c, 8) for c in range(2)]
    d = np.array(cells[0].distance_m, np.float64)
    d[3] = np.nan
    cells[0] = dataclasses.replace(cells[0], distance_m=jnp.asarray(d))
    mc = make_multicell(cells, np.zeros((2, 2)))
    sol = solve_coupled(mc, sanitize=True)
    a = np.asarray(sol.batch.a)
    assert np.isfinite(a).all()
    assert np.all(a[0, 3] == 0.0)


# -------------------------------------------------- degraded aggregation

@pytest.fixture(scope="module")
def fl_setup():
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_mnist_like
    prob = make_problem("paper_static", seed=0, n_devices=8)
    train, test = make_mnist_like(256, 64, seed=0)
    parts = dirichlet_partition(train, 8, 0.3, seed=1)
    cfg = FLConfig(n_rounds=6, eval_every=3, seed=0)
    return prob, train, test, parts, cfg


def _run_one(plan, train, test, cfg):
    plans = jax.tree_util.tree_map(lambda x: x[None], plan)
    return run_fl_sweep(plans, train, test, cfg, init_sweep_params([cfg]),
                        shard=False)


def test_all_false_drop_table_bitwise_identical(fl_setup):
    from repro.core.schedulers import ProbabilisticScheduler
    prob, train, test, parts, cfg = fl_setup
    sch = ProbabilisticScheduler()
    clean = _run_one(plan_trajectory(prob, sch, parts, cfg),
                     train, test, cfg)
    zeros = _run_one(plan_trajectory(prob, sch, parts, cfg,
                                     drops=np.zeros((6, 8), bool)),
                     train, test, cfg)
    h0, hz = clean.histories[0], zeros.histories[0]
    assert np.array_equal(h0.eval_acc, hz.eval_acc)
    assert np.array_equal(h0.participants, hz.participants)
    for a, b in zip(jax.tree_util.tree_leaves(clean.params),
                    jax.tree_util.tree_leaves(zeros.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_drops_cut_survivors_but_energy_stays_charged(fl_setup):
    from repro.core.schedulers import ProbabilisticScheduler
    prob, train, test, parts, cfg = fl_setup
    sch = ProbabilisticScheduler()
    clean = _run_one(plan_trajectory(prob, sch, parts, cfg),
                     train, test, cfg)
    heavy = _run_one(plan_trajectory(prob, sch, parts, cfg,
                                     drops=dropout_mask(3, 6, 8, 0.6)),
                     train, test, cfg)
    h0, hd = clean.histories[0], heavy.histories[0]
    # same attempted participation stream -> identical accounting, but
    # only surviving uploads count as participants / enter eq. 4
    assert hd.participants.sum() < h0.participants.sum()
    assert np.array_equal(hd.energy, h0.energy)
    assert np.array_equal(hd.sim_time, h0.sim_time)


def test_stack_plans_rejects_mixed_drop_tables(fl_setup):
    from repro.core.schedulers import ProbabilisticScheduler
    prob, train, test, parts, cfg = fl_setup
    sch = ProbabilisticScheduler()
    p1 = plan_trajectory(prob, sch, parts, cfg)
    p2 = plan_trajectory(prob, sch, parts, cfg,
                         drops=np.zeros((6, 8), bool))
    with pytest.raises(ValueError, match="drop"):
        stack_plans([p1, p2])


# ----------------------------------------------------- crash-safe resume

def test_solve_rounds_checkpoint_resume_bitwise(tmp_path):
    prob = _drifting(n_rounds=6)
    ref = solve_rounds(prob, FleetControlService(ServiceConfig()))

    # crash after 3 rounds
    svc = FleetControlService(ServiceConfig())
    orig_run, calls = svc.run, [0]
    def crashy(reqs=None):
        if calls[0] >= 3:
            raise RuntimeError("simulated crash")
        calls[0] += 1
        return orig_run(reqs)
    svc.run = crashy
    with pytest.raises(RuntimeError, match="simulated crash"):
        solve_rounds(prob, svc, checkpoint_dir=tmp_path)

    # resume on a FRESH service: bitwise-identical control table,
    # identical warm accounting — as if never killed
    res = solve_rounds(prob, FleetControlService(ServiceConfig()),
                       checkpoint_dir=tmp_path)
    assert np.array_equal(ref.a, res.a)
    assert np.array_equal(ref.power, res.power)
    assert ref.warm_rounds == res.warm_rounds
    assert ref.inner_iters == res.inner_iters
    assert ref.outer_iters == res.outer_iters


def test_resume_with_completed_checkpoint_skips_all_solves(tmp_path):
    prob = _drifting(n_rounds=4)
    first = solve_rounds(prob, FleetControlService(ServiceConfig()),
                         checkpoint_dir=tmp_path)
    svc = FleetControlService(ServiceConfig())
    again = solve_rounds(prob, svc, checkpoint_dir=tmp_path)
    assert np.array_equal(first.a, again.a)
    assert svc.stats.n_solved == 0          # everything restored


@pytest.mark.slow
def test_faulted_closed_loop_grid_finite_and_degraded(tmp_path):
    plan = FaultPlan(seed=3, device_rate=0.25, drop_rate=0.3)
    cfg = ClosedLoopConfig(n_devices=8, n_rounds=6, n_train=256, n_test=64,
                           eval_every=3, fault_plan=plan,
                           checkpoint_dir=str(tmp_path))
    out = run_closed_loop_grid(cfg, strategies=("probabilistic", "uniform"),
                               shard=False)
    assert out["faults"]["n_unhealthy_devices"] > 0
    for name, row in out["strategies"].items():
        assert all(np.isfinite(v) for v in row.values()), (name, row)
    # the service sanitised every corrupted submission
    assert out["control"]["service"]["unhealthy_devices"] > 0
