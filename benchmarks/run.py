"""Benchmark harness — one entry per paper table/figure plus framework
benchmarks.  Prints ``name,us_per_call,derived`` CSV rows (plus human
summaries as comment lines prefixed with '#').

    PYTHONPATH=src python -m benchmarks.run                 # fast set
    PYTHONPATH=src python -m benchmarks.run --full          # + FL tables
    PYTHONPATH=src python -m benchmarks.run --only solver_scaling
    PYTHONPATH=src python -m benchmarks.run \
        --only fl_sweep_scaling,batch_solver_scaling --json BENCH_pr.json

``--json`` records the rows (plus environment metadata) for the CI
benchmark-regression gate — see ``benchmarks/compare.py``.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import os
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def _timeit(fn, *args, n=20, warmup=3) -> float:
    """Best-of-n wall time per call in us.  Every call — warmup and timed —
    is ``block_until_ready``'d so jax's async dispatch can't understate
    the cost (returning an unrealised array is near-free).  The minimum,
    as in stdlib ``timeit``, is the noise-robust statistic: anything above
    it measures scheduler interference, not the program."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ----------------------------------------------------------- paper tables

def bench_paper_tables(full: bool):
    """Tables I-IV: time/energy-to-accuracy for the four strategies in both
    scenarios (fig 1-2 curves saved to experiments/)."""
    from repro.fl.experiments import HIGH_BIAS, MILD_BIAS, format_tables, run_scenario
    specs = [HIGH_BIAS, MILD_BIAS]
    if not full:
        # reduced rounds can't reach the paper-scale targets; scale them
        # down so the time/energy-to-accuracy columns stay meaningful
        specs = [dataclasses.replace(s, n_rounds=100, n_runs=1, n_train=3000,
                                     n_test=600, n_devices=50,
                                     targets=(0.25, 0.45))
                 for s in specs]
    out_dir = Path("experiments/bench_tables")
    out_dir.mkdir(parents=True, exist_ok=True)
    for spec in specs:
        t0 = time.perf_counter()
        res = run_scenario(spec, verbose=False)
        dt = time.perf_counter() - t0
        (out_dir / f"{spec.name}.json").write_text(json.dumps(res, indent=1))
        print("#" + format_tables(res, spec).replace("\n", "\n#"))
        for strat, r in res["strategies"].items():
            t = r["table"]
            emit(f"table_{spec.name}_{strat}_time_to_low",
                 (t["time_to_low"] or float("nan")) * 1e6,
                 f"sim_seconds_to_{spec.targets[0]:.0%}")
            emit(f"table_{spec.name}_{strat}_energy_to_low",
                 (t["energy_to_low"] or float("nan")),
                 f"joules_to_{spec.targets[0]:.0%}")
        emit(f"table_{spec.name}_wall", dt * 1e6, "bench wall time")


# --------------------------------------------------------- solver scaling

def bench_solver_scaling(full: bool):
    """Fleet-solve latency vs N (the paper solves 100 devices; the
    framework's vectorised/bisection paths scale to millions)."""
    from repro.core import sample_problem, solve_joint, solve_joint_optimal
    sizes = [100, 10_000, 1_000_000] if full else [100, 10_000, 200_000]
    for n in sizes:
        prob = sample_problem(0, n)
        alt = jax.jit(solve_joint)
        opt = jax.jit(solve_joint_optimal)
        us_alt = _timeit(lambda: alt(prob), n=5)
        us_opt = _timeit(lambda: opt(prob), n=5)
        obj_a = float(solve_joint(prob).objective)
        obj_o = float(solve_joint_optimal(prob).objective)
        emit(f"solver_alternating_n{n}", us_alt, f"objective={obj_a:.5f}")
        emit(f"solver_optimal_n{n}", us_opt,
             f"objective={obj_o:.5f} (+{(obj_o / max(obj_a, 1e-12) - 1):.2%})")


def bench_batch_solver_scaling(full: bool):
    """Batched multi-scenario engine (``solve_joint_batch``) vs the naive
    per-problem python loop: instances/sec at growing batch sizes."""
    from repro.core import solve_joint, solve_joint_batch, stack_problems
    from repro.core.scenarios import make_problem

    n = 64                      # devices per instance
    batch_sizes = [8, 64, 256] if full else [8, 64]
    probs = [make_problem("paper_static", seed=i, n_devices=n)
             for i in range(max(batch_sizes))]

    single = jax.jit(solve_joint)
    jax.block_until_ready(single(probs[0]).a)   # one compile, shared shapes

    def naive_loop(ps):
        out = [single(p) for p in ps]
        jax.block_until_ready(out[-1].a)
        return out

    for bsz in batch_sizes:
        batch = stack_problems(probs[:bsz])
        us_batch = _timeit(lambda batch=batch: solve_joint_batch(batch).a,
                           n=5)
        us_loop = _timeit(lambda chunk=probs[:bsz]: naive_loop(chunk),
                          n=3, warmup=1)
        ips_batch = bsz / (us_batch / 1e6)
        ips_loop = bsz / (us_loop / 1e6)
        emit(f"batch_solver_batched_b{bsz}", us_batch,
             f"instances_per_sec={ips_batch:.1f}")
        emit(f"batch_solver_loop_b{bsz}", us_loop,
             f"instances_per_sec={ips_loop:.1f} "
             f"batched_speedup={ips_batch / ips_loop:.1f}x")


def bench_fused_solver_scaling(full: bool):
    """Fused single-level solver vs the PR-1 ``solve_joint_batch`` path
    (vmapped nested-while Algorithm 2) — the tentpole speedup claim.

    Two regimes:
      * B=64 ensemble of 64-device instances: the vmapped nested loops run
        every instance to the slowest inner solve; the fused flat loop
        masks per element.
      * N=100k single instance (``mega_fleet_100k``): the chunked,
        element-sharded mega-fleet path on a fixed ``chunk_elements``
        memory bound.
    """
    from repro.core import solve_joint_batch, stack_problems
    from repro.core.scenarios import make_problem

    n, bsz = 64, 64
    probs = [make_problem("paper_static", seed=i, n_devices=n)
             for i in range(bsz)]
    batch = stack_problems(probs)

    us_base = _timeit(lambda: solve_joint_batch(batch).a, n=5)
    us_fused = _timeit(lambda: solve_joint_batch(batch, method="fused").a,
                       n=5)
    ips_base = bsz / (us_base / 1e6)
    ips_fused = bsz / (us_fused / 1e6)
    emit(f"fused_solver_base_b{bsz}", us_base,
         f"instances_per_sec={ips_base:.1f}")
    emit(f"fused_solver_fused_b{bsz}", us_fused,
         f"instances_per_sec={ips_fused:.1f} "
         f"speedup={us_base / us_fused:.1f}x")

    n_mega = 100_000
    chunk = 16_384
    mega = make_problem("mega_fleet_100k", seed=0, n_devices=n_mega)
    mega_batch = stack_problems([mega])
    # best-of-5: the 100k vmapped solve is ~20 ms and scheduler-noise on a
    # busy runner is easily +50%, which would flake the 25% absolute gate
    us_base_m = _timeit(lambda: solve_joint_batch(mega_batch).a, n=5)
    us_fused_m = _timeit(
        lambda: solve_joint_batch(mega_batch, method="fused",
                                  chunk_elements=chunk).a, n=5)
    emit(f"fused_solver_base_n{n_mega}", us_base_m,
         f"devices_per_sec={n_mega / (us_base_m / 1e6):.0f}")
    emit(f"fused_solver_fused_n{n_mega}", us_fused_m,
         f"devices_per_sec={n_mega / (us_fused_m / 1e6):.0f} "
         f"chunk_elements={chunk} speedup={us_base_m / us_fused_m:.1f}x")


def bench_dinkelbach(full: bool):
    """Algorithm 1 iterations to convergence + agreement with the
    closed-form fast path."""
    from repro.core import sample_problem
    from repro.core.power import analytic_power, dinkelbach_power
    prob = sample_problem(1, 10_000)
    a = jnp.full((10_000,), 0.05)
    d = jax.jit(lambda: dinkelbach_power(prob, a))
    an = jax.jit(lambda: analytic_power(prob, a))
    us_d = _timeit(d, n=10)
    us_a = _timeit(an, n=10)
    iters = int(dinkelbach_power(prob, a).n_iters)
    gap = float(jnp.max(jnp.abs(d().power - an().power)))
    emit("dinkelbach_10k", us_d, f"iters={iters}")
    emit("analytic_power_10k", us_a, f"max_power_gap={gap:.2e}")


# --------------------------------------------------------------- kernels

def bench_kernels(full: bool):
    """Pallas kernels (interpret=True: functional check; real perf target
    is TPU) vs their jnp oracles (the XLA path actually timed)."""
    from repro.kernels.masked_aggregate.kernel import masked_aggregate_tiled
    from repro.kernels.masked_aggregate.ref import masked_aggregate_ref
    rng = np.random.default_rng(0)
    n, d = (256, 131_072) if full else (128, 16_384)
    g = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    coef = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    ref = jax.jit(masked_aggregate_ref)
    us_ref = _timeit(ref, g, coef, n=10)
    err = float(jnp.max(jnp.abs(
        masked_aggregate_tiled(g, coef, interpret=True)
        - masked_aggregate_ref(g, coef))))
    emit("masked_aggregate_ref_xla", us_ref, f"N={n} D={d}")
    emit("masked_aggregate_kernel_check", 0.0, f"interpret_max_err={err:.2e}")

    from repro.kernels.ssd_scan.ops import ssd_apply
    from repro.models.mamba2 import ssd_chunked
    b, s, h, p, nstate = 2, 512, 4, 64, 64
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a_ = jnp.asarray(-rng.uniform(0.5, 4, h), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, nstate)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, nstate)) * 0.3, jnp.float32)
    dskip = jnp.asarray(rng.normal(size=h), jnp.float32)
    xla = jax.jit(lambda *t: ssd_chunked(*t, chunk=128)[0])
    us_x = _timeit(xla, x, dt, a_, bm, cm, dskip, n=5)
    err = float(jnp.max(jnp.abs(
        ssd_apply(x, dt, a_, bm, cm, dskip, chunk=128, interpret=True)
        - xla(x, dt, a_, bm, cm, dskip))))
    emit("ssd_chunked_xla", us_x, f"B{b}xS{s}xH{h}")
    emit("ssd_kernel_check", 0.0, f"interpret_max_err={err:.2e}")

    from repro.kernels.swa_decode.ref import swa_decode_ref
    bsz, hkv, grp, dh, w = 2, 4, 4, 128, 2048
    q = jnp.asarray(rng.normal(size=(bsz, hkv, grp, dh)), jnp.float32) * dh ** -0.5
    k = jnp.asarray(rng.normal(size=(bsz, w, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bsz, w, hkv, dh)), jnp.float32)
    pos = jnp.arange(w, dtype=jnp.int32)
    qpos = jnp.int32(w - 1)
    refd = jax.jit(lambda *t: swa_decode_ref(*t, window=1024))
    us_ref = _timeit(refd, q, k, v, pos, qpos, n=10)
    emit("swa_decode_ref_xla", us_ref, f"W={w} Hkv={hkv} G={grp}")


# ----------------------------------------------------------- FL step perf

def bench_fl_round(full: bool):
    """One FL communication round (CNN, 50 clients) — fused vs stacked
    aggregation paths."""
    from repro.core import ProbabilisticScheduler, sample_problem
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_mnist_like
    from repro.fl.engine import FLConfig, run_fl
    train, test = make_mnist_like(2000, 200, seed=0)
    parts = dirichlet_partition(train, 50, 0.3, seed=1)
    prob = sample_problem(0, 50, tau_th=0.5,
                          dirichlet_sizes=np.array([len(p) for p in parts]))
    for mode in ("fused", "stacked"):
        cfg = FLConfig(n_rounds=12, eval_every=1000, batch_per_client=8,
                       aggregate=mode, seed=0)
        t0 = time.perf_counter()
        res = run_fl(prob, ProbabilisticScheduler(), train, parts, test, cfg)
        # the final update is still in flight when run_fl returns — block so
        # the per-round figure includes it
        jax.block_until_ready(res.params)
        us = (time.perf_counter() - t0) / 12 * 1e6
        emit(f"fl_round_{mode}", us, "50 clients x 8 samples")


def bench_fl_sweep_scaling(full: bool):
    """Whole-trajectory throughput: the scan-fused vmapped sweep engine
    (``repro.fl.scan_engine``) vs the per-run python-loop reference
    (``run_fl``) on a seed-averaging grid, probabilistic strategy.

    Both sides pay their full cost per iteration: the loop re-solves the
    joint problem every run (as ``run_scenario`` does today); the sweep
    solves once, plans every trajectory, and runs one jitted call.
    """
    from repro.core import ProbabilisticScheduler, sample_problem
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_mnist_like
    from repro.fl.engine import FLConfig, run_fl
    from repro.fl.scan_engine import (init_sweep_params, plan_trajectory,
                                      run_fl_sweep, stack_plans)

    n_dev, rounds, b = 8, 12, 1
    train, test = make_mnist_like(1024, 64, seed=0)
    parts = dirichlet_partition(train, n_dev, 0.3, seed=1)
    prob = sample_problem(0, n_dev, tau_th=0.5,
                          dirichlet_sizes=np.array([len(p) for p in parts]))
    sch = ProbabilisticScheduler()

    def loop_grid(cfgs):
        out = [run_fl(prob, sch, train, parts, test, c) for c in cfgs]
        return out[-1].params

    def scan_grid(cfgs):
        state = sch.precompute(prob)
        plans = [plan_trajectory(prob, sch, parts, c, state=state)
                 for c in cfgs]
        sweep = run_fl_sweep(stack_plans(plans), train, test, cfgs[0],
                             init_sweep_params(cfgs), donate_params=False)
        return sweep.params

    for n_traj in (4, 8, 16) if full else (4, 8):
        cfgs = [FLConfig(n_rounds=rounds, eval_every=rounds,
                         batch_per_client=b, seed=s) for s in range(n_traj)]
        us_loop = _timeit(loop_grid, cfgs, n=3, warmup=1)
        us_scan = _timeit(scan_grid, cfgs, n=4, warmup=1)
        tps_loop = n_traj / (us_loop / 1e6)
        tps_scan = n_traj / (us_scan / 1e6)
        emit(f"fl_sweep_loop_t{n_traj}", us_loop,
             f"trajectories_per_sec={tps_loop:.2f}")
        emit(f"fl_sweep_scan_t{n_traj}", us_scan,
             f"trajectories_per_sec={tps_scan:.2f} "
             f"speedup={us_loop / us_scan:.1f}x")


# ------------------------------------------------------- fleet service

def bench_fleet_service_throughput(full: bool):
    """The online fleet control plane (``repro.serve``) on a drifting
    channel.  Three claims, three measurements:

    * micro-batching: the service at ``max_batch=C`` vs the same service
      draining one request per step — isolates what packing requests
      into padded slots amortises (per-step pack + dispatch overhead);
    * warm starts: inner Algorithm-1 (Dinkelbach) iterations per
      micro-batch, warm vs cold, in the paper-faithful mode.  The counts
      are deterministic (same seeds => same counts), so the ``speedup=``
      ratio is gated machine-independently by ``benchmarks/compare.py``;
    * context: a bare jitted per-request ``solve_joint_fused`` loop.  At
      paper scale on CPU the closed-form solve is so cheap that no
      serving machinery beats it (docs/serving.md discusses when the
      service earns its keep); the row keeps that trade-off visible
      rather than hiding it.

    Wall-clock rows feed the same-runner absolute gate.
    """
    from repro.core import make_problem, slice_round, solve_joint_fused
    from repro.serve import FleetControlService, ServiceConfig

    n_cells, n_dev, n_rounds = (16, 64, 10) if full else (8, 64, 8)
    cells = [make_problem("drifting_metro", seed=s, n_devices=n_dev,
                          n_rounds=n_rounds) for s in range(n_cells)]
    requests = [[(c, slice_round(prob, k)) for c, prob in enumerate(cells)]
                for k in range(n_rounds)]
    n_req = n_cells * n_rounds

    def run_service(max_batch=n_cells, **cfg_kw):
        svc = FleetControlService(ServiceConfig(max_batch=max_batch,
                                                **cfg_kw))
        for k, batch in enumerate(requests):
            svc.run(batch)
            if k == 0:
                # round 0 is all-cold and (on the first call of a config)
                # carries jit compiles; drop it from the steady-state
                # stats — the caches keep their state
                svc.stats.reset()
        return svc

    # steady state: throwaway passes warm every jit signature (batched
    # and per-request slot shapes); each timed pass then starts from
    # fresh caches, so it re-measures the same cold->warm request stream
    run_service()
    run_service(max_batch=1)
    us_svc = _timeit(lambda: run_service(), n=5, warmup=1)
    us_one = _timeit(lambda: run_service(max_batch=1), n=3, warmup=1)

    solve = jax.jit(solve_joint_fused)

    def naive_loop():
        out = None
        for batch in requests:
            for _, prob in batch:
                out = solve(prob)
        jax.block_until_ready(out.a)

    us_loop = _timeit(naive_loop, n=3, warmup=1)
    emit(f"fleet_service_batched_c{n_cells}", us_svc,
         f"solves_per_sec={n_req / (us_svc / 1e6):.1f} "
         f"speedup={us_one / us_svc:.1f}x")
    emit(f"fleet_service_unbatched_c{n_cells}", us_one,
         f"solves_per_sec={n_req / (us_one / 1e6):.1f}")
    emit(f"fleet_service_bare_loop_c{n_cells}", us_loop,
         f"solves_per_sec={n_req / (us_loop / 1e6):.1f}")

    # warm-start iteration drop, paper-faithful Dinkelbach mode: the
    # counts are deterministic, so the ratio transfers across machines
    run_service(power_solver="dinkelbach")   # compile both init signatures
    warm = run_service(power_solver="dinkelbach", warm_start=True)
    cold = run_service(power_solver="dinkelbach", warm_start=False)
    wi, ci = warm.stats.mean_inner_iters, cold.stats.mean_inner_iters
    s = warm.stats.summary()
    emit("fleet_service_warm_inner_iters", wi,
         f"p50_ms={s['p50_latency_s'] * 1e3:.2f} "
         f"p99_ms={s['p99_latency_s'] * 1e3:.2f} "
         f"warm_fraction={s['warm_fraction']:.2f}")
    emit("fleet_service_cold_inner_iters", ci,
         f"speedup={ci / max(wi, 1e-9):.1f}x")


def bench_fleet_service_openloop(full: bool):
    """The open-loop control plane under seeded arrival traffic — the
    serving claims of ``docs/serving.md`` measured end-to-end:

    * ``_sustained``: Poisson arrivals at 0.7x the *measured* full-batch
      capacity; ``throughput_ratio`` (sustained/offered) is dimensionless
      and gated machine-independently by ``compare.py``;
    * ``_latency``: p50/p99 request latency and the deadline-miss rate,
      with deadlines expressed in units of the measured batch cost
      (``p99_over_deadline`` therefore transfers across machines — the
      gated p99 ceiling);
    * ``_warmup``: AOT warmup cost per bucket and ``first_over_p50``,
      the no-trace-spike acceptance figure (first post-warmup request vs
      steady-state p50);
    * ``_bursty``: ON/OFF bursts over drifted + stale-tolerant cells;
      ``preemptions`` counts the priority lane actually firing.

    Wall-clock rows feed the same-runner absolute gate as usual.
    """
    from repro.core import slice_round
    from repro.serve import (FleetControlService, ServiceConfig,
                             bursty_trace, drive, make_cells,
                             measure_capacity, poisson_trace)

    n_cells, n_dev, n_rounds = (8, 64, 12) if full else (6, 48, 8)
    n_req = 240 if full else 120
    cells = make_cells(n_cells, n_devices=n_dev, n_rounds=n_rounds, seed=0)
    probe = [slice_round(c, 0) for c in cells]

    svc = FleetControlService(ServiceConfig(max_batch=8))
    wtimes = svc.warmup(probe[0], max_devices=n_dev)
    cap = measure_capacity(svc, probe)
    svc.stats.reset()

    # deadline budget in units of the measured full-batch cost: the
    # miss-rate / p99 figures then mean the same thing on any machine
    deadline = 8.0 * svc.config.max_batch / cap
    trace = poisson_trace(cells, rate_hz=0.7 * cap, n_requests=n_req,
                          seed=1, deadline_s=deadline)
    rep = drive(svc, trace, reset_stats_after=n_req // 4)
    s = svc.stats
    p50, p99 = s.latency_percentile(50), s.latency_percentile(99)
    first = rep.responses[0].latency_s   # first post-warmup request
    emit("fleet_service_openloop_sustained", rep.wall_s / n_req * 1e6,
         f"solves_per_sec={rep.sustained_rate_hz:.1f} "
         f"offered_hz={rep.offered_rate_hz:.1f} "
         f"throughput_ratio={rep.sustained_rate_hz / rep.offered_rate_hz:.3f}")
    emit("fleet_service_openloop_latency", p99 * 1e6,
         f"p50_ms={p50 * 1e3:.2f} p99_ms={p99 * 1e3:.2f} "
         f"deadline_ms={deadline * 1e3:.2f} "
         f"miss_rate={s.deadline_miss_rate:.4f} "
         f"p99_over_deadline={p99 / deadline:.3f}")
    emit("fleet_service_openloop_warmup", sum(wtimes.values()) * 1e6,
         f"buckets={len(wtimes)} first_ms={first * 1e3:.2f} "
         f"first_over_p50={first / max(p50, 1e-9):.2f}")

    # bursty: stale-tolerant (1-round) cells mixed with the drifting
    # ones; drifted cells ride the priority lane through each burst
    svc2 = FleetControlService(ServiceConfig(max_batch=8))
    svc2.warmup(probe[0], max_devices=n_dev)
    static = make_cells(2, n_devices=n_dev, n_rounds=1, seed=100)
    btrace = bursty_trace(static + cells, burst_rate_hz=2.0 * cap,
                          burst_len=3 * n_cells, n_bursts=4,
                          idle_s=4.0 * svc2.config.max_batch / cap, seed=2)
    rep2 = drive(svc2, btrace)
    s2 = svc2.stats.summary()
    emit("fleet_service_openloop_bursty",
         rep2.wall_s / len(btrace) * 1e6,
         f"preemptions={svc2.stats.n_preemptions} "
         f"priority_fraction={s2['priority_fraction']:.3f} "
         f"mean_batch={s2['solved'] / max(s2['batches'], 1):.2f}")


def bench_fleet_service_faulted(full: bool):
    """Degraded-mode serving under the seeded chaos harness
    (``docs/robustness.md``): the same Poisson load driven twice through
    identically-warmed services — once clean, once with 10% of arrivals
    corrupted (``FaultPlan``) — so the cost of sanitize + retry +
    degraded cache locality shows up as one dimensionless ratio:

    * ``_clean``: the fault-free reference drive;
    * ``_chaos``: the corrupted drive; ``degraded_throughput_ratio``
      (faulted sustained rate / clean sustained rate) is gated >= 0.5
      by ``compare.py``, and ``nan_escapes`` — non-finite values in any
      response — is gated == 0.  Both transfer across machines.

    Wall-clock per-request figures are queue-dependent tail statistics
    (``ABSOLUTE_EXEMPT``, like the open-loop rows).
    """
    from repro.core import slice_round
    from repro.serve import (FaultPlan, FleetControlService, ServiceConfig,
                             chaos_drive, drive, make_cells,
                             measure_capacity, poisson_trace)

    n_cells, n_dev, n_rounds = (8, 64, 12) if full else (6, 48, 8)
    n_req = 240 if full else 120

    cells = make_cells(n_cells, n_devices=n_dev, n_rounds=n_rounds, seed=0)
    probe = [slice_round(c, 0) for c in cells]

    def fresh():
        svc = FleetControlService(ServiceConfig(max_batch=8))
        svc.warmup(probe[0], max_devices=n_dev)
        return svc

    cap = measure_capacity(fresh(), probe)
    trace = poisson_trace(cells, rate_hz=0.6 * cap, n_requests=n_req, seed=1)

    svc = fresh()
    svc.stats.reset()
    clean = drive(svc, trace, reset_stats_after=n_req // 4)

    svc2 = fresh()
    svc2.stats.reset()
    plan = FaultPlan(seed=3, fault_rate=0.1)   # 10% of arrivals corrupted
    chaos = chaos_drive(svc2, trace, plan, clock="wall",
                        reset_stats_after=n_req // 4)

    ratio = chaos.report.sustained_rate_hz / clean.sustained_rate_hz
    emit("fleet_service_faulted_clean", clean.wall_s / n_req * 1e6,
         f"solves_per_sec={clean.sustained_rate_hz:.1f} "
         f"offered_hz={clean.offered_rate_hz:.1f}")
    emit("fleet_service_faulted_chaos",
         chaos.report.wall_s / n_req * 1e6,
         f"degraded_throughput_ratio={ratio:.3f} "
         f"nan_escapes={chaos.nan_escapes} "
         f"n_faulted={chaos.n_faulted} "
         f"unhealthy_devices={chaos.n_unhealthy_devices} "
         f"retries={chaos.counters['retries']} "
         f"shed={chaos.counters['shed']}")


# ------------------------------------------------------- multi-cell

def bench_multicell_solver(full: bool):
    """The coupled metro solver (``core.multicell``): dual decomposition
    with ONE element-sharded fused union solve per outer iteration vs the
    reference python loop of per-cell ``solve_joint_fused`` calls running
    the same fixed point (``solve_coupled_loop``).

    * the wall-clock pair carries the tentpole ``speedup=`` claim at
      C=64 cells (gated machine-independently by ``compare.py``);
    * ``multicell_warm_outer_iters`` pins the warm-dual claim: outer
      iterations on a tick seeded with the previous tick's duals vs a
      cold solve.  The counts are deterministic (same scenario seed =>
      same counts), so the ratio transfers across machines.
    """
    from repro.core import solve_coupled, solve_coupled_loop
    from repro.core.scenarios import make_problem

    c, n = 64, 64
    mc = make_problem("metro_coupled", seed=0, n_cells=c, n_devices=n)

    cold = solve_coupled(mc)            # compiles, and pins the iter count
    solve_coupled_loop(mc)              # compiles the per-cell program
    us_coupled = _timeit(lambda: solve_coupled(mc).batch.a, n=5, warmup=1)
    us_loop = _timeit(lambda: solve_coupled_loop(mc).batch.a, n=3, warmup=1)
    emit(f"multicell_coupled_c{c}", us_coupled,
         f"outer_iters={cold.outer_iters} "
         f"cells_per_sec={c / (us_coupled / 1e6):.0f} "
         f"speedup={us_loop / us_coupled:.1f}x")
    emit(f"multicell_loop_c{c}", us_loop,
         f"cells_per_sec={c / (us_loop / 1e6):.0f}")

    # deterministic warm-dual claim: outer iterations with/without the
    # previous tick's duals on the same metro
    warm = solve_coupled(mc, init=cold.resume)
    emit("multicell_warm_outer_iters", float(warm.outer_iters),
         f"residual={warm.residual:.2e} "
         f"speedup={cold.outer_iters / max(warm.outer_iters, 1):.1f}x")
    emit("multicell_cold_outer_iters", float(cold.outer_iters),
         f"mu={float(np.max(np.atleast_1d(np.asarray(cold.mu)))):.3e} "
         f"load_over_budget="
         f"{float(np.max(np.atleast_1d(np.asarray(cold.backhaul_load)))) / mc.backhaul_bits:.4f}")


# ------------------------------------------------------- closed loop

def bench_closed_loop_throughput(full: bool):
    """The drift-aware closed loop (``repro.fl.closed_loop``): per-round
    control-plane solves on a Gauss-Markov channel, warm-started service
    vs a per-round cold ``solve_joint`` loop.

    * wall-clock rows (``closed_loop_control_*``) feed the same-runner
      absolute gate;
    * the inner-iteration pair (``closed_loop_{warm,cold}_inner_iters``)
      is deterministic (same seeds => same counts), so its ``speedup=``
      ratio is gated machine-independently — the closed loop's
      drift-tracking claim;
    * ``closed_loop_pipeline`` times the whole loop (control plane +
      strategy suite + scan-fused training) end-to-end.
    """
    import functools

    from repro.core import make_problem, slice_round, solve_joint
    from repro.fl.closed_loop import (CLOSED_LOOP_STRATEGIES,
                                      ClosedLoopConfig, run_closed_loop_grid,
                                      solve_rounds)
    from repro.serve import FleetControlService, ServiceConfig

    n_dev, k_rounds = (48, 12) if full else (32, 8)
    prob = make_problem("drifting_metro", seed=0, n_devices=n_dev,
                        n_rounds=k_rounds)

    def control_warm():
        svc = FleetControlService(ServiceConfig(method="alternating",
                                                power_solver="dinkelbach"))
        return solve_rounds(prob, svc)

    solve = jax.jit(functools.partial(solve_joint,
                                      power_solver="dinkelbach"))

    def control_cold_loop():
        inner, out = 0, None
        for k in range(k_rounds):
            out = solve(slice_round(prob, k))
            inner += int(out.inner_iters)
        jax.block_until_ready(out.a)
        return inner

    control_warm()          # compile cold + warm init signatures
    control_cold_loop()
    us_warm = _timeit(control_warm, n=3, warmup=1)
    us_cold = _timeit(control_cold_loop, n=3, warmup=1)
    emit(f"closed_loop_control_warm_k{k_rounds}", us_warm,
         f"rounds_per_sec={k_rounds / (us_warm / 1e6):.1f}")
    emit(f"closed_loop_control_cold_k{k_rounds}", us_cold,
         f"rounds_per_sec={k_rounds / (us_cold / 1e6):.1f}")

    # deterministic drift-tracking claim: inner Algorithm-1 iterations
    # per round, warm-started stream vs per-round cold solves
    trace = control_warm()
    wi = trace.inner_iters / k_rounds
    ci = control_cold_loop() / k_rounds
    emit("closed_loop_warm_inner_iters", wi,
         f"warm_rounds={trace.warm_rounds}/{k_rounds}")
    emit("closed_loop_cold_inner_iters", ci,
         f"speedup={ci / max(wi, 1e-9):.1f}x")

    # end-to-end: control plane + classic strategy suite + scan-fused
    # training.  Pinned to the pre-compression five strategies so the
    # committed baseline stays comparable; the quantized joint_bits
    # strategy is benched separately (bench_bit_allocation).
    classic = tuple(s for s in CLOSED_LOOP_STRATEGIES if s != "joint_bits")
    n_strat = len(classic)
    cfg = ClosedLoopConfig(n_devices=16, n_rounds=6, n_train=512,
                           n_test=128, eval_every=3)
    us_pipe = _timeit(lambda: run_closed_loop_grid(cfg, classic),
                      n=3, warmup=1)
    emit("closed_loop_pipeline", us_pipe,
         f"strategies={n_strat} rounds={cfg.n_rounds} "
         f"trajectories_per_sec={n_strat / (us_pipe / 1e6):.2f}")


# -------------------------------------------------------- bit allocation

def bench_bit_allocation(full: bool):
    """Joint bit/power/selection (docs/compression.md): participation and
    per-participant energy vs fixed fp32 on the bandwidth-starved
    scenario, plus the quantized masked-aggregate kernel vs its jnp
    oracle.  ``participants_ratio`` is deterministic (same scenario seed
    => same solve) and gated machine-independently in compare.py."""
    import dataclasses as _dc

    from repro.core import make_problem, solve_joint_fused
    from repro.kernels.masked_aggregate.ops import quantized_masked_aggregate
    from repro.kernels.masked_aggregate.ref import (
        quantized_masked_aggregate_ref)

    n_dev = 64 if full else 32
    menu = (8, 16, 32)
    prob = make_problem("bandwidth_starved", seed=1, n_devices=n_dev)

    sol32 = solve_joint_fused(prob)
    solm = solve_joint_fused(prob, bit_menu=menu)
    us32 = _timeit(lambda: solve_joint_fused(prob), n=5, warmup=1)
    usm = _timeit(lambda: solve_joint_fused(prob, bit_menu=menu),
                  n=5, warmup=1)

    def per_round(sol, p):
        a = np.asarray(sol.a)
        e_dev = np.asarray(p.upload_energy(sol.power)
                           + p.compute_energy())
        return float(a.sum()), float((a * e_dev).sum())

    parts32, energy32 = per_round(sol32, prob)
    prob_b = _dc.replace(prob, bits=solm.bits)
    parts_m, energy_m = per_round(solm, prob_b)
    epp32 = energy32 / max(parts32, 1e-12)
    epp_m = energy_m / max(parts_m, 1e-12)
    emit(f"bit_allocation_solve_fp32_n{n_dev}", us32,
         f"expected_participants={parts32:.2f}")
    emit("bit_allocation_participation", usm,
         f"participants_ratio={parts_m / max(parts32, 1e-12):.2f} "
         f"energy_per_participant_ratio={epp_m / max(epp32, 1e-12):.2f} "
         f"menu={'/'.join(str(b) for b in menu)} N={n_dev}")

    rng = np.random.default_rng(0)
    n, d = (256, 131_072) if full else (128, 16_384)
    g = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    coef = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    noise = jnp.asarray(rng.uniform(0, 1, (n, d)), jnp.float32)
    bits = jnp.asarray(rng.choice([4.0, 8.0, 16.0, 32.0], n), jnp.float32)
    ref = jax.jit(quantized_masked_aggregate_ref)
    us_ref = _timeit(ref, g, coef, noise, bits, n=10)
    err = float(jnp.max(jnp.abs(
        quantized_masked_aggregate(g, coef, noise, bits, interpret=True)
        - ref(g, coef, noise, bits))))
    emit("bit_allocation_quantized_aggregate_ref_xla", us_ref,
         f"N={n} D={d}")
    emit("bit_allocation_kernel_check", 0.0,
         f"interpret_max_err={err:.2e}")


# ------------------------------------------------------------- roofline

def bench_roofline(full: bool):
    """Summarise dry-run artifacts into the §Roofline table."""
    art = Path("experiments/artifacts")
    rows = 0
    for f in sorted(art.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        rows += 1
        emit(f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dominant={r['dominant']} useful={r['useful_ratio']:.2f}")
    if not rows:
        print("# no dry-run artifacts found; run repro.launch.dryrun first")


BENCHES = {
    "paper_tables": bench_paper_tables,
    "solver_scaling": bench_solver_scaling,
    "batch_solver_scaling": bench_batch_solver_scaling,
    "fused_solver_scaling": bench_fused_solver_scaling,
    "dinkelbach": bench_dinkelbach,
    "kernels": bench_kernels,
    "fl_round": bench_fl_round,
    "fl_sweep_scaling": bench_fl_sweep_scaling,
    "fleet_service_throughput": bench_fleet_service_throughput,
    "fleet_service_openloop": bench_fleet_service_openloop,
    "fleet_service_faulted": bench_fleet_service_faulted,
    "multicell_solver": bench_multicell_solver,
    "closed_loop_throughput": bench_closed_loop_throughput,
    "bit_allocation": bench_bit_allocation,
    "roofline": bench_roofline,
}


def _write_json(path: str, args) -> None:
    rec = {
        "meta": {
            "argv": sys.argv[1:],
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "benches": {name: {"us_per_call": us, "derived": derived}
                    for name, us, derived in ROWS},
    }
    Path(path).write_text(json.dumps(rec, indent=1))
    print(f"# wrote {path} ({len(ROWS)} rows)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names "
                         f"(choices: {', '.join(sorted(BENCHES))})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + metadata as JSON (CI gate input)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the timed region in jax.profiler.trace(DIR) "
                         "(TensorBoard/Perfetto trace of every bench run)")
    ap.add_argument("--host-devices", type=int, default=0, metavar="N",
                    help="force N virtual host (CPU) devices so the sharded "
                         "paths exercise a multi-device mesh; must be set "
                         "before any jax computation runs")
    args = ap.parse_args(argv)
    if args.host_devices > 0:
        # effective only because the backend has not been initialised yet:
        # nothing above touches a jax array before benches run
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.host_devices}").strip()
    names = args.only.split(",") if args.only else list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choices: {sorted(BENCHES)}")
    print("name,us_per_call,derived")
    profile = (jax.profiler.trace(args.profile) if args.profile
               else contextlib.nullcontext())
    with profile:
        for name in names:
            print(f"# --- {name} ---", flush=True)
            BENCHES[name](args.full)
    if args.profile:
        print(f"# profiler trace written to {args.profile}")
    if args.json:
        _write_json(args.json, args)


if __name__ == "__main__":
    main()
