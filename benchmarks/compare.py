"""Benchmark-regression gate: diff a ``benchmarks.run --json`` record
against a baseline and fail on slowdowns.

Two kinds of checks:

* **absolute**: any tracked bench whose ``us_per_call`` exceeds the
  baseline's by more than ``--threshold`` (default 25%) is a regression.
  Only meaningful when baseline and candidate ran on comparable machines
  — in CI the baseline is regenerated on the same runner from the PR's
  base commit.
* **ratio floors** (``--ratios-only`` skips the absolute check): derived
  ``speedup=<x>x`` figures are same-machine time ratios, so they transfer
  across machines.  Floors below assert the architectural speedups the
  repo claims (scan-fused FL sweep, batched solver) never silently rot.
  A floor applies whenever the baseline file covers its bench row; a
  covered row that is missing from the candidate fails the gate rather
  than being skipped.
* **derived bounds** (checked in both modes, same coverage rule): named
  ``key=value`` figures in a row's derived field that are dimensionless
  or counter-based — throughput ratios, miss rates, p99/deadline ratios,
  preemption counts — get per-key floors/ceilings.  These gate the
  open-loop serving claims (``fleet_service_openloop_*``) without
  depending on the runner's absolute speed.

Usage::

    PYTHONPATH=src python -m benchmarks.run \
        --only fl_sweep_scaling --host-devices 2 --json BENCH_pr.json
    python -m benchmarks.compare benchmarks/baselines/fl_sweep.json \
        BENCH_pr.json                     # same-machine: absolute + ratios
    python -m benchmarks.compare benchmarks/baselines/fl_sweep.json \
        BENCH_pr.json --ratios-only       # cross-machine: ratios only

Exit code 0 = green, 1 = regression(s), 2 = bad input.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# benches whose timings the absolute check covers (prefix match); the
# paper-table rows are simulation outputs, not timings, and the roofline
# rows depend on which dry-run artifacts exist
TRACKED_PREFIXES = (
    "fl_sweep_",
    "fl_round_",
    "batch_solver_",
    "fused_solver_",
    "fleet_service_",
    "multicell_",
    "closed_loop_",
    "bit_allocation_",
    "solver_",
    "dinkelbach",
    "analytic_power",
)

# rows exempt from the absolute check even though their prefix is
# tracked: open-loop figures (wall/request, p99) are queue-dependent
# tail statistics, not best-of-n microbenchmarks — run-to-run noise on
# one machine exceeds the 25% threshold.  They are gated by
# DERIVED_BOUNDS below instead (dimensionless, machine-independent).
ABSOLUTE_EXEMPT = ("fleet_service_openloop_", "fleet_service_faulted_")

# minimum same-machine speedups (parsed from a row's ``speedup=<x>x``
# derived field).  Kept below the locally measured figures to absorb
# runner noise; the committed baseline records the actual numbers.
SPEEDUP_FLOORS = {
    "fl_sweep_scan_t8": 3.5,      # measured ~5-6x on a 2-core container
    "batch_solver_loop_b64": 3.0,  # batched vs loop solver, measured ~10x
    # fused single-level solver vs the PR-1 vmapped nested-while path on
    # 2 virtual CPU devices (ISSUE 3 acceptance: >= 4x); measured ~11x
    "fused_solver_fused_b64": 4.0,
    # fleet service micro-batching vs the same service draining one
    # request per step; measured ~5x
    "fleet_service_batched_c8": 2.0,
    # warm-started vs cold Dinkelbach inner iterations per micro-batch
    # on the drifting_metro stream.  Deterministic (same seeds => same
    # counts), so the ratio is machine-independent; measured 3.9x
    "fleet_service_cold_inner_iters": 2.5,
    # closed loop: per-round warm-started service stream vs a per-round
    # cold solve_joint loop on the same drifting trajectory, inner
    # Algorithm-1 iterations per round.  Deterministic; measured 4.5x
    "closed_loop_cold_inner_iters": 2.5,
    # coupled metro tick (one fused union solve per outer iteration) vs
    # the per-cell python-loop reference running the same fixed point at
    # C=64 (ISSUE 7 acceptance: >= 3x); measured ~17-20x
    "multicell_coupled_c64": 3.0,
    # warm-dual tick vs cold outer-iteration count on the same metro.
    # Deterministic (same scenario seed => same counts); measured 12x
    "multicell_warm_outer_iters": 6.0,
}

_SPEEDUP_RE = re.compile(r"speedup=([0-9.]+)x")

# per-bench (floor, ceiling) bounds on named ``key=value`` figures in the
# derived field; ``None`` leaves that side unbounded.  Keep every entry
# dimensionless or counter-valued so it transfers across machines.
DERIVED_BOUNDS: dict[str, dict[str, tuple[float | None, float | None]]] = {
    # sustained/offered at 0.7x measured capacity — the service must keep
    # up with the offered Poisson load (measured ~0.85-1.0 depending on
    # how the capacity probe lands; floor leaves headroom for that)
    "fleet_service_openloop_sustained": {"throughput_ratio": (0.75, None)},
    # the p99 ceiling: p99 latency stays inside the deadline budget (8
    # measured batch costs), and essentially nothing misses
    "fleet_service_openloop_latency": {"p99_over_deadline": (None, 1.0),
                                       "miss_rate": (None, 0.02)},
    # AOT warmup: the first post-warmup request pays no trace spike
    # (ISSUE acceptance: within 3x the steady-state p50)
    "fleet_service_openloop_warmup": {"first_over_p50": (None, 3.0)},
    # the priority lane actually preempts under bursty traffic
    "fleet_service_openloop_bursty": {"preemptions": (1.0, None)},
    # degraded-mode serving (docs/robustness.md): with 10% of arrivals
    # corrupted the service must keep >= half the clean throughput —
    # sanitize copies, retries and cache misses are the honest cost —
    # and no corruption may ever echo into a response (nan_escapes == 0)
    "fleet_service_faulted_chaos": {"degraded_throughput_ratio": (0.5, None),
                                    "nan_escapes": (None, 0.0)},
    # joint bit allocation on the bandwidth-starved scenario: the {8,16,32}
    # menu must keep buying participation over fixed fp32 (deterministic:
    # same scenario seed => same solve; measured 4.0x, floor leaves room
    # for solver-tolerance drift)
    "bit_allocation_participation": {"participants_ratio": (1.5, None)},
}


def _derived_value(derived: str, key: str) -> float | None:
    m = re.search(rf"(?:^|\s){re.escape(key)}=([-+0-9.eE]+)", derived)
    return float(m.group(1)) if m else None


def load(path: str) -> dict:
    rec = json.loads(Path(path).read_text())
    if "benches" not in rec:
        raise ValueError(f"{path} is not a benchmarks.run --json record")
    return rec["benches"]


def tracked(name: str) -> bool:
    return name.startswith(TRACKED_PREFIXES)


def compare(baseline: dict, new: dict, threshold: float,
            ratios_only: bool) -> list[str]:
    problems: list[str] = []

    if not ratios_only:
        for name, base_row in sorted(baseline.items()):
            if not tracked(name) or name.startswith(ABSOLUTE_EXEMPT):
                continue
            if name not in new:
                problems.append(f"{name}: tracked bench missing from candidate")
                continue
            base_us, new_us = base_row["us_per_call"], new[name]["us_per_call"]
            if base_us > 0 and new_us > base_us * (1 + threshold):
                problems.append(
                    f"{name}: {new_us / base_us - 1:+.0%} "
                    f"({base_us / 1e3:.1f} ms -> {new_us / 1e3:.1f} ms, "
                    f"threshold +{threshold:.0%})")

    for name, floor in sorted(SPEEDUP_FLOORS.items()):
        if name not in baseline:
            continue        # this baseline file doesn't cover that bench
        row = new.get(name)
        if row is None:
            # the baseline has the row, so its absence from the candidate
            # means the floor would silently stop being checked — fail
            problems.append(f"{name}: floored bench missing from candidate")
            continue
        m = _SPEEDUP_RE.search(row.get("derived", ""))
        if not m:
            problems.append(f"{name}: no speedup figure in derived field "
                            f"{row.get('derived', '')!r}")
            continue
        speedup = float(m.group(1))
        if speedup < floor:
            problems.append(f"{name}: speedup {speedup:.1f}x below the "
                            f"{floor:.1f}x floor")

    for name, bounds in sorted(DERIVED_BOUNDS.items()):
        if name not in baseline:
            continue        # this baseline file doesn't cover that bench
        row = new.get(name)
        if row is None:
            problems.append(f"{name}: bounded bench missing from candidate")
            continue
        for key, (lo, hi) in sorted(bounds.items()):
            val = _derived_value(row.get("derived", ""), key)
            if val is None:
                problems.append(f"{name}: no {key}= figure in derived "
                                f"field {row.get('derived', '')!r}")
                continue
            if lo is not None and val < lo:
                problems.append(f"{name}: {key}={val:g} below the "
                                f"{lo:g} floor")
            if hi is not None and val > hi:
                problems.append(f"{name}: {key}={val:g} above the "
                                f"{hi:g} ceiling")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional slowdown (default 0.25)")
    ap.add_argument("--ratios-only", action="store_true",
                    help="skip absolute-time checks (cross-machine compare)")
    args = ap.parse_args(argv)
    try:
        baseline, new = load(args.baseline), load(args.candidate)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"benchmark compare: {e}", file=sys.stderr)
        return 2

    problems = compare(baseline, new, args.threshold, args.ratios_only)
    mode = "ratio floors" if args.ratios_only else \
        f"abs +{args.threshold:.0%} & ratio floors"
    n_tracked = sum(tracked(n) for n in new)
    if problems:
        print(f"BENCH GATE FAILED ({mode}; {len(problems)} problem(s)):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"bench gate OK ({mode}; {n_tracked} tracked rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
