"""Pallas TPU kernel: single-token decode attention over a (ring-buffer)
KV cache — the latency-critical op of decode_32k / long_500k serving.

One grid cell = (batch b, kv-block j).  The query tile [Hkv, G, dh] stays
VMEM-resident across the KV-block grid dimension while KV blocks stream
HBM -> VMEM; online-softmax running stats (max m, normaliser l,
accumulator acc) live in VMEM scratch, so the full cache row is read
exactly once from HBM at streaming bandwidth — the op is perfectly
memory-bound and the kernel's job is to hit that roofline (the XLA path
materialises the [H, W] score matrix in HBM at long W).

GQA is handled inside the tile: q is viewed as [Hkv, G, dh] so each kv
head's block serves its G query heads without materialising repeated K/V.
Ring-buffer semantics: a position buffer pos[W] (-1 = empty) provides the
causal/window mask: valid = (0 <= pos_k <= qpos) & (pos_k > qpos - window).

Block sizes: KV_BLK = 512 rows — at dh = 128, K + V tiles are
2 x 512 x Hkv x 128 x 2 B, inside VMEM with double buffering for
Hkv <= 16; ops.py drops to KV_BLK 256 for fatter kv configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref,
            out_ref, m_scr, l_scr, acc_scr, *, window, n_kv_blocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # [Hkv, G, dh] (pre-scaled)
    k = k_ref[0].astype(jnp.float32)              # [KV_BLK, Hkv, dh]
    v = v_ref[0].astype(jnp.float32)              # [KV_BLK, Hkv, dh]
    kpos = pos_ref[0]                             # [KV_BLK] int32
    qpos = qpos_ref[0, 0]

    # scores[h, g, s] = sum_d q[h,g,d] * k[s,h,d]
    s = jnp.einsum("hgd,shd->hgs", q, k,
                   preferred_element_type=jnp.float32)
    valid = (kpos >= 0) & (kpos <= qpos)
    if window is not None:
        valid &= kpos > qpos - window
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_scr[...]                           # [Hkv, G]
    m_new = jnp.maximum(m_prev, s.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid[None, None, :], p, 0.0)

    l_scr[...] = l_scr[...] * alpha + p.sum(-1)
    acc_scr[...] = acc_scr[...] * alpha[..., None] \
        + jnp.einsum("hgs,shd->hgd", p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        out_ref[...] = (acc_scr[...] /
                        jnp.maximum(l_scr[...], 1e-30)[..., None]
                        )[None].astype(out_ref.dtype)


def swa_decode_tiled(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos_buf: jax.Array, qpos: jax.Array,
                     *, window: int | None, kv_blk: int = 512,
                     interpret: bool = False):
    """q [B, Hkv, G, dh] (pre-scaled by dh^-0.5), k/v [B, W, Hkv, dh],
    pos_buf [W] int32, qpos scalar int32 -> out [B, Hkv, G, dh]."""
    bsz, hkv, g, dh = q.shape
    w = k.shape[1]
    assert w % kv_blk == 0, (w, kv_blk)
    nkv = w // kv_blk
    grid = (bsz, nkv)
    kernel = functools.partial(_kernel, window=window, n_kv_blocks=nkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hkv, g, dh), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, kv_blk, hkv, dh), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, kv_blk, hkv, dh), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, kv_blk), lambda b, j: (0, j)),
            pl.BlockSpec((1, 1), lambda b, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, g, dh), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),          # running max
            pltpu.VMEM((hkv, g), jnp.float32),          # normaliser
            pltpu.VMEM((hkv, g, dh), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v, pos_buf[None], qpos[None, None])
