"""Pure-jnp oracle for swa_decode: dense masked softmax attention of one
query token against the full ring-buffer cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                   pos_buf: jax.Array, qpos: jax.Array,
                   *, window: int | None) -> jax.Array:
    """q [B,Hkv,G,dh] (pre-scaled), k/v [B,W,Hkv,dh], pos_buf [W] ->
    [B,Hkv,G,dh]."""
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    valid = (pos_buf >= 0) & (pos_buf <= qpos)
    if window is not None:
        valid &= pos_buf > qpos - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
