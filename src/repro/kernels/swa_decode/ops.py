"""Jit'd wrapper for the decode-attention kernel, shaped to drop into
layers.attn_decode_step (q [B,1,H,dh] + KVCache)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.swa_decode.kernel import swa_decode_tiled


@partial(jax.jit, static_argnames=("window", "n_heads", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos_buf: jax.Array, qpos: jax.Array,
                     *, window: int | None, n_heads: int,
                     interpret: bool = True) -> jax.Array:
    """q [B,1,H,dh]; k/v [B,W,Hkv,dh]; returns [B,1,H,dh]."""
    bsz, _, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    w = k.shape[1]
    kv_blk = 512 if w % 512 == 0 else (256 if w % 256 == 0 else
                                       (128 if w % 128 == 0 else w))
    qg = (q[:, 0] * dh ** -0.5).reshape(bsz, hkv, g, dh)
    out = swa_decode_tiled(qg, k, v, pos_buf.astype(jnp.int32),
                           qpos.astype(jnp.int32), window=window,
                           kv_blk=kv_blk, interpret=interpret)
    return out.reshape(bsz, 1, h, dh)
