"""Jit'd public wrappers for the selection_solve kernels.

``solve_joint_kernel`` takes one WirelessFLProblem and returns a
JointSolution (drop-in for ``core.optimal.solve_joint_optimal``);
``solve_joint_fused_kernel`` is the same wrapper around the fused
alternating fixed point (drop-in for ``core.alternating.solve_joint`` /
``solve_joint_fused``).  The ``*_batch`` variants take a
``core.batch.ProblemBatch`` and return a ``BatchSolution`` — the problem
(7) element set is separable per ``(instance, device, round)``, so the
whole batch flattens into one tiled kernel launch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.alternating import JointSolution
from repro.core.problem import WirelessFLProblem

_ROWS_BLK = 256 * 128   # elements per kernel tile: (256, 128) f32


def _pack(x, n_pad):
    x = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, n_pad),
                constant_values=1.0)
    return x.reshape(-1, 128)


def _bcast_rounds(x: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast per-device x to per-(device, round) rank of ``like``."""
    return x if x.ndim == like.ndim else jnp.broadcast_to(
        x[..., None], like.shape)


def _solve_elements(problem: WirelessFLProblem, pg: jax.Array,
                    interpret: bool, tiled_fn=None,
                    **tiled_kw) -> tuple[jax.Array, jax.Array]:
    """Run a tiled kernel over every element of ``pg`` (any shape),
    returning (a*, P*) with ``pg``'s shape.  Scalar constraint data is
    broadcast from the problem; per-device vectors are broadcast across
    rounds."""
    if tiled_fn is None:
        from repro.kernels.selection_solve.kernel import selection_solve_tiled
        tiled_fn = selection_solve_tiled

    bw = _bcast_rounds(problem.bandwidth_hz, pg)
    emax = _bcast_rounds(problem.energy_budget_j, pg)
    ec = _bcast_rounds(problem.compute_energy(), pg)

    n = pg.size
    m_pad = -(-n // _ROWS_BLK) * _ROWS_BLK
    n_pad = m_pad - n
    args = [_pack(v, n_pad) for v in (pg, bw, emax, ec)]
    a, p = tiled_fn(
        *args, s_bits=problem.grad_size_bits, tau=problem.tau_th,
        p_max=problem.p_max, interpret=interpret, **tiled_kw)
    return (a.reshape(-1)[:n].reshape(pg.shape),
            p.reshape(-1)[:n].reshape(pg.shape))


@partial(jax.jit, static_argnames=("interpret",))
def solve_joint_kernel(problem: WirelessFLProblem,
                       interpret: bool = True) -> JointSolution:
    a, p = _solve_elements(problem, problem.path_gain(), interpret)
    return JointSolution(a=a, power=p, objective=problem.objective(a),
                         n_iters=jnp.int32(60), converged=jnp.asarray(True))


@partial(jax.jit, static_argnames=("interpret",))
def solve_joint_kernel_batch(batch, interpret: bool = True):
    """Pallas fast path for ``core.batch.solve_joint_batch``.

    Flattens the [B, N_max] (or [B, N_max, K]) element set into one tiled
    ``selection_solve`` launch.  Solves the same per-element bisection
    problem as ``solve_joint_optimal`` (the paper's Algorithm 2 is a local
    method; the kernel computes the exact per-element optimum).
    """
    from repro.core.batch import _mask_solution

    problem = batch.problem
    # per-instance rank-sensitive broadcasting lives in path_gain(); vmap it
    # rather than reimplementing the [B, N, K] case here.
    pg = jax.vmap(WirelessFLProblem.path_gain)(problem)
    a, p = _solve_elements(problem, pg, interpret)
    b = batch.mask.shape[0]
    sol = JointSolution(a=a, power=p,
                        objective=jax.vmap(WirelessFLProblem.objective)(problem, a),
                        n_iters=jnp.full((b,), 60, jnp.int32),
                        converged=jnp.ones((b,), bool))
    return _mask_solution(sol, batch.mask)


# ------------------------------------------- fused alternating fixed point

@partial(jax.jit, static_argnames=("n_iters", "faithful_eq13_typo",
                                   "interpret"))
def solve_joint_fused_kernel(problem: WirelessFLProblem,
                             n_iters: int = 50,
                             faithful_eq13_typo: bool = False,
                             interpret: bool = True) -> JointSolution:
    """Pallas fused Algorithm-2 solve for one problem (drop-in for
    ``core.alternating.solve_joint_fused``; agreement <= 1e-5)."""
    from repro.kernels.selection_solve.kernel import fused_solve_tiled

    a, p = _solve_elements(problem, problem.path_gain(), interpret,
                           tiled_fn=fused_solve_tiled, n_iters=n_iters,
                           faithful_eq13_typo=faithful_eq13_typo)
    return JointSolution(a=a, power=p, objective=problem.objective(a),
                         n_iters=jnp.int32(n_iters),
                         converged=jnp.asarray(True))


@partial(jax.jit, static_argnames=("n_iters", "faithful_eq13_typo",
                                   "interpret"))
def solve_joint_fused_kernel_batch(batch, n_iters: int = 50,
                                   faithful_eq13_typo: bool = False,
                                   interpret: bool = True):
    """Pallas fused path for ``core.batch.solve_joint_batch``: the whole
    [B * N_max (* K)] element set runs the alternating fixed point in one
    tiled launch, every iterate VMEM-resident."""
    from repro.core.batch import _mask_solution
    from repro.kernels.selection_solve.kernel import fused_solve_tiled

    problem = batch.problem
    pg = jax.vmap(WirelessFLProblem.path_gain)(problem)
    a, p = _solve_elements(problem, pg, interpret,
                           tiled_fn=fused_solve_tiled, n_iters=n_iters,
                           faithful_eq13_typo=faithful_eq13_typo)
    b = batch.mask.shape[0]
    sol = JointSolution(a=a, power=p,
                        objective=jax.vmap(WirelessFLProblem.objective)(problem, a),
                        n_iters=jnp.full((b,), n_iters, jnp.int32),
                        converged=jnp.ones((b,), bool))
    return _mask_solution(sol, batch.mask)
