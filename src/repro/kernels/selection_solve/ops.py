"""Jit'd public wrapper for the selection_solve kernel: takes a
WirelessFLProblem, returns a JointSolution (drop-in for
core.optimal.solve_joint_optimal)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.alternating import JointSolution
from repro.core.problem import WirelessFLProblem
from repro.kernels.selection_solve.kernel import selection_solve_tiled

_TILE = 128 * 256


def _pack(x, n_pad):
    x = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, n_pad),
                constant_values=1.0)
    return x.reshape(-1, 128)


@partial(jax.jit, static_argnames=("interpret",))
def solve_joint_kernel(problem: WirelessFLProblem,
                       interpret: bool = True) -> JointSolution:
    pg = problem.path_gain()
    n = pg.size
    m128 = -(-n // 128) * 128
    rows_blk = 256 * 128
    m_pad = -(-m128 // rows_blk) * rows_blk
    n_pad = m_pad - n

    args = [_pack(v, n_pad) for v in
            (pg, problem.bandwidth_hz, problem.energy_budget_j,
             problem.compute_energy())]
    a, p = selection_solve_tiled(
        *args, s_bits=problem.grad_size_bits, tau=problem.tau_th,
        p_max=problem.p_max, interpret=interpret)
    a = a.reshape(-1)[:n].reshape(pg.shape)
    p = p.reshape(-1)[:n].reshape(pg.shape)
    return JointSolution(a=a, power=p, objective=problem.objective(a),
                         n_iters=jnp.int32(60), converged=jnp.asarray(True))
