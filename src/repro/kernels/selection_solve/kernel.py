"""Pallas TPU kernel: fleet-scale joint selection/power solve.

Solves the per-device global optimum of problem (7) (the monotone
bisection of core/optimal.py) for a *fleet tile at a time*: device state
(path gain, bandwidth, budgets, compute energy) is streamed HBM -> VMEM in
(ROWS, 128) blocks and the fixed-iteration bisection runs entirely on the
VPU — branch-free elementwise ops, no host loop, no re-materialisation of
intermediates in HBM.  For planetary-scale FL fleets (10^5-10^7 devices x
rounds) this is the compute hot-spot of the paper's technique; the pure
XLA path (ref.py) materialises each bisection iterate in HBM, the kernel
keeps all 60 iterates VMEM-resident.

Inputs are pre-flattened [M, 128] tiles (ops.py handles padding/reshape):
    path_gain   g / (d^2 sigma^2)           [M,128] f32
    bandwidth   B_i                         [M,128] f32
    e_max       per-round energy budget     [M,128] f32
    e_comp      E^c_i                       [M,128] f32
scalars (SMEM): S (bits), tau, p_max.
Outputs: a* and P* = min-power at a* (clipped), both [M,128] f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LN2 = 0.6931471805599453

DEFAULT_ROWS = 256      # (256, 128) f32 tile = 128 KiB/operand in VMEM
N_BISECT = 60


def _feasible(a, pg, bw, emax, ec, s_bits, tau, p_max):
    """F(a): P^min(a) <= P^max  and  tau P^min(a) + a E^c <= E^max."""
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p_min = jnp.expm1(expo * LN2) / pg
    power_ok = p_min <= p_max
    energy_ok = tau * p_min + a * ec <= emax
    return power_ok & energy_ok


def _solve_tile(pg, bw, emax, ec, *, s_bits, tau, p_max):
    ones = jnp.ones_like(pg)
    feas1 = _feasible(ones, pg, bw, emax, ec, s_bits, tau, p_max)
    lo = jnp.zeros_like(pg)
    hi = ones

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = _feasible(mid, pg, bw, emax, ec, s_bits, tau, p_max)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_BISECT, body, (lo, hi))
    a = jnp.where(feas1, 1.0, lo)
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p = jnp.clip(jnp.expm1(expo * LN2) / pg, 0.0, p_max)
    return a, p


def _kernel(pg_ref, bw_ref, emax_ref, ec_ref, a_ref, p_ref,
            *, s_bits, tau, p_max):
    a, p = _solve_tile(pg_ref[...], bw_ref[...], emax_ref[...], ec_ref[...],
                       s_bits=s_bits, tau=tau, p_max=p_max)
    a_ref[...] = a
    p_ref[...] = p


def selection_solve_tiled(pg, bw, emax, ec, *, s_bits: float, tau: float,
                          p_max: float, rows: int = DEFAULT_ROWS,
                          interpret: bool = False):
    """pg/bw/emax/ec: [M, 128] f32 with M % rows == 0."""
    m, lanes = pg.shape
    assert lanes == 128 and m % rows == 0, (m, lanes, rows)
    grid = (m // rows,)
    blk = pl.BlockSpec((rows, 128), lambda i: (i, 0))
    kernel = functools.partial(_kernel, s_bits=float(s_bits), tau=float(tau),
                               p_max=float(p_max))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk] * 4,
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct((m, 128), jnp.float32)] * 2,
        interpret=interpret,
    )(pg, bw, emax, ec)
