"""Pallas TPU kernels: fleet-scale joint selection/power solve.

Two solvers over the same pre-flattened element tiles:

* ``selection_solve_tiled`` — the per-device *global* optimum of problem
  (7) (the monotone bisection of core/optimal.py), 60 fixed bisection
  iterations.
* ``fused_solve_tiled``     — the paper's Algorithm 2 as the fused
  single-level alternating fixed point (core/alternating.py
  ``fused_fixed_point``): closed-form power update, eq.-10 energy gate
  and eq.-13 selection update per iteration, a fixed ``n_iters``
  unrolled on the VPU.  Same local optimum as ``solve_joint`` (<= 1e-5
  elementwise).

Device state (path gain, bandwidth, budgets, compute energy) is streamed
HBM -> VMEM in (ROWS, 128) blocks and every iterate stays VMEM-resident —
branch-free elementwise ops, no host loop, no re-materialisation of
intermediates in HBM.  For planetary-scale FL fleets (10^5-10^7 devices x
rounds) this is the compute hot-spot of the paper's technique; the pure
XLA paths materialise each iterate in HBM.

Inputs are pre-flattened [M, 128] tiles (ops.py handles padding/reshape):
    path_gain   g / (d^2 sigma^2)           [M,128] f32
    bandwidth   B_i                         [M,128] f32
    e_max       per-round energy budget     [M,128] f32
    e_comp      E^c_i                       [M,128] f32
scalars (compiled in): S (bits), tau, p_max.
Outputs: a* and P*, both [M,128] f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.alternating import FleetElements, _fused_step, fused_init

LN2 = 0.6931471805599453

DEFAULT_ROWS = 256      # (256, 128) f32 tile = 128 KiB/operand in VMEM
N_BISECT = 60
N_ALT = 50              # fused alternating iterations (solve_joint max_iters)


def _feasible(a, pg, bw, emax, ec, s_bits, tau, p_max):
    """F(a): P^min(a) <= P^max  and  tau P^min(a) + a E^c <= E^max."""
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p_min = jnp.expm1(expo * LN2) / pg
    power_ok = p_min <= p_max
    energy_ok = tau * p_min + a * ec <= emax
    return power_ok & energy_ok


def _solve_tile(pg, bw, emax, ec, *, s_bits, tau, p_max):
    ones = jnp.ones_like(pg)
    feas1 = _feasible(ones, pg, bw, emax, ec, s_bits, tau, p_max)
    lo = jnp.zeros_like(pg)
    hi = ones

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = _feasible(mid, pg, bw, emax, ec, s_bits, tau, p_max)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_BISECT, body, (lo, hi))
    a = jnp.where(feas1, 1.0, lo)
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p = jnp.clip(jnp.expm1(expo * LN2) / pg, 0.0, p_max)
    return a, p


def _kernel(pg_ref, bw_ref, emax_ref, ec_ref, a_ref, p_ref,
            *, s_bits, tau, p_max):
    a, p = _solve_tile(pg_ref[...], bw_ref[...], emax_ref[...], ec_ref[...],
                       s_bits=s_bits, tau=tau, p_max=p_max)
    a_ref[...] = a
    p_ref[...] = p


def selection_solve_tiled(pg, bw, emax, ec, *, s_bits: float, tau: float,
                          p_max: float, rows: int = DEFAULT_ROWS,
                          interpret: bool = False):
    """pg/bw/emax/ec: [M, 128] f32 with M % rows == 0."""
    kernel = functools.partial(_kernel, s_bits=float(s_bits), tau=float(tau),
                               p_max=float(p_max))
    return _launch_tiled(kernel, pg, bw, emax, ec, rows=rows,
                         interpret=interpret)


def _launch_tiled(kernel, pg, bw, emax, ec, *, rows: int, interpret: bool):
    m, lanes = pg.shape
    assert lanes == 128 and m % rows == 0, (m, lanes, rows)
    grid = (m // rows,)
    blk = pl.BlockSpec((rows, 128), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk] * 4,
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct((m, 128), jnp.float32)] * 2,
        interpret=interpret,
    )(pg, bw, emax, ec)


# ----------------------------------------- fused alternating fixed point

def _fused_solve_tile(pg, bw, emax, ec, *, s_bits, tau, p_max, n_iters,
                      faithful_eq13_typo):
    """The fused alternation on one tile, reusing the *same* step and
    init as the XLA solver (``core/alternating.py`` — plain elementwise
    jnp, legal inside a Pallas body), so the kernel can never drift from
    ``solve_joint_fused``; only the loop shape differs (fixed trip count,
    the iteration is stationary past its fixed point)."""
    el = FleetElements(pg=pg, bw=bw, emax=emax, ec=ec)
    step = functools.partial(_fused_step, el=el, s_bits=s_bits, tau=tau,
                             p_max=p_max, power_solver="analytic",
                             faithful_eq13_typo=faithful_eq13_typo)
    a0, _ = fused_init(el, s_bits=s_bits, tau=tau, p_max=p_max,
                       faithful_eq13_typo=faithful_eq13_typo)

    def body(_, ap):
        return step(ap[0])[:2]

    # the seeding step(a0) is iteration 1, as in fused_fixed_point /
    # solve_joint — n_iters total steps, not n_iters + 1 (the step's third
    # output, the inner Dinkelbach count, is always 0 in analytic mode)
    return jax.lax.fori_loop(1, n_iters, body, step(a0)[:2])


def _fused_kernel(pg_ref, bw_ref, emax_ref, ec_ref, a_ref, p_ref,
                  *, s_bits, tau, p_max, n_iters, faithful_eq13_typo):
    a, p = _fused_solve_tile(pg_ref[...], bw_ref[...], emax_ref[...],
                             ec_ref[...], s_bits=s_bits, tau=tau,
                             p_max=p_max, n_iters=n_iters,
                             faithful_eq13_typo=faithful_eq13_typo)
    a_ref[...] = a
    p_ref[...] = p


def fused_solve_tiled(pg, bw, emax, ec, *, s_bits: float, tau: float,
                      p_max: float, n_iters: int = N_ALT,
                      faithful_eq13_typo: bool = False,
                      rows: int = DEFAULT_ROWS, interpret: bool = False):
    """Fused alternating fixed point over [M, 128] f32 tiles.

    ``n_iters`` is a fixed trip count (fori, fully VMEM-resident): past
    its fixed point the iteration is stationary, so running the
    ``solve_joint`` iteration budget unconditionally trades a negligible
    amount of VPU work for branch-free tiles.
    """
    kernel = functools.partial(_fused_kernel, s_bits=float(s_bits),
                               tau=float(tau), p_max=float(p_max),
                               n_iters=int(n_iters),
                               faithful_eq13_typo=bool(faithful_eq13_typo))
    return _launch_tiled(kernel, pg, bw, emax, ec, rows=rows,
                         interpret=interpret)
