"""Pure-jnp oracles for the selection_solve kernels (same math as
core/optimal.py and core/alternating.py, restated on the kernels'
flattened operands)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.selection_solve.kernel import (
    LN2,
    N_ALT,
    N_BISECT,
    _fused_solve_tile,
)


def _feasible(a, pg, bw, emax, ec, s_bits, tau, p_max):
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p_min = jnp.expm1(expo * LN2) / pg
    return (p_min <= p_max) & (tau * p_min + a * ec <= emax)


def selection_solve_ref(pg, bw, emax, ec, *, s_bits: float, tau: float,
                        p_max: float):
    ones = jnp.ones_like(pg)
    feas1 = _feasible(ones, pg, bw, emax, ec, s_bits, tau, p_max)
    lo, hi = jnp.zeros_like(pg), ones

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = _feasible(mid, pg, bw, emax, ec, s_bits, tau, p_max)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_BISECT, body, (lo, hi))
    a = jnp.where(feas1, 1.0, lo)
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p = jnp.clip(jnp.expm1(expo * LN2) / pg, 0.0, p_max)
    return a, p


def fused_solve_ref(pg, bw, emax, ec, *, s_bits: float, tau: float,
                    p_max: float, n_iters: int = N_ALT,
                    faithful_eq13_typo: bool = False):
    """XLA reference for ``fused_solve_tiled``: the identical tile math
    run outside ``pallas_call`` (every iterate materialised in HBM)."""
    return _fused_solve_tile(pg, bw, emax, ec, s_bits=float(s_bits),
                             tau=float(tau), p_max=float(p_max),
                             n_iters=int(n_iters),
                             faithful_eq13_typo=bool(faithful_eq13_typo))
