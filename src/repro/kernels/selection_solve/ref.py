"""Pure-jnp oracle for the selection_solve kernel (same math as
core/optimal.py, restated on the kernel's flattened operands)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.selection_solve.kernel import LN2, N_BISECT


def _feasible(a, pg, bw, emax, ec, s_bits, tau, p_max):
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p_min = jnp.expm1(expo * LN2) / pg
    return (p_min <= p_max) & (tau * p_min + a * ec <= emax)


def selection_solve_ref(pg, bw, emax, ec, *, s_bits: float, tau: float,
                        p_max: float):
    ones = jnp.ones_like(pg)
    feas1 = _feasible(ones, pg, bw, emax, ec, s_bits, tau, p_max)
    lo, hi = jnp.zeros_like(pg), ones

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = _feasible(mid, pg, bw, emax, ec, s_bits, tau, p_max)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_BISECT, body, (lo, hi))
    a = jnp.where(feas1, 1.0, lo)
    expo = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    p = jnp.clip(jnp.expm1(expo * LN2) / pg, 0.0, p_max)
    return a, p
