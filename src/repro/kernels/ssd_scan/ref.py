"""Pure-jnp oracle for the SSD scan kernel: the sequential SSM recurrence
(the definitionally-correct O(S) form, independent of the chunked
algorithm under test)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a, b_mat, c_mat, d_skip):
    """x [BH,S,P]; dt [BH,S]; a [BH]; b/c [BH,S,N]; d_skip [BH] -> [BH,S,P].

    state_t = exp(dt_t a) state_{t-1} + dt_t x_t B_t^T;  y = C_t state + D x.
    """
    x32 = x.astype(jnp.float32)

    def per_seq(x_s, dt_s, a_s, b_s, c_s, d_s):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            state = jnp.exp(dtt * a_s) * state + dtt * xt[:, None] * bt[None, :]
            y = state @ ct + d_s * xt
            return state, y
        p, n = x_s.shape[-1], b_s.shape[-1]
        s0 = jnp.zeros((p, n), jnp.float32)
        _, ys = jax.lax.scan(step, s0, (x_s, dt_s, b_s, c_s))
        return ys

    return jax.vmap(per_seq)(x32, dt.astype(jnp.float32), a.astype(jnp.float32),
                             b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
                             d_skip.astype(jnp.float32)).astype(x.dtype)
