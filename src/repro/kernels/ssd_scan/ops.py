"""Jit'd wrapper: Mamba2-shaped SSD via the Pallas kernel — drop-in for
models.mamba2.ssd_chunked (head-grouped B/C broadcast + batch*head fold)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_tiled


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_apply(x, dt, a, b_mat, c_mat, d_skip, *, chunk: int = 128,
              interpret: bool = True):
    """Same signature as models.mamba2.ssd_chunked (minus init_state):
    x [B,S,H,P]; dt [B,S,H]; a [H]; b/c [B,S,N]; d_skip [H] -> y [B,S,H,P]."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    af = jnp.tile(a, bsz)
    bf = jnp.repeat(b_mat, h, axis=0).reshape(bsz, h, s, n).reshape(bsz * h, s, n)
    cf = jnp.repeat(c_mat, h, axis=0).reshape(bsz, h, s, n).reshape(bsz * h, s, n)
    df = jnp.tile(d_skip, bsz)
    y = ssd_scan_tiled(xf, dtf, af, bf, cf, df, chunk=chunk,
                       interpret=interpret)
    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
