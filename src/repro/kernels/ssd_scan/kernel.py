"""Pallas TPU kernel: Mamba2 SSD chunked scan (one head per grid row).

Grid = (B*H, n_chunks); the chunk dimension is sequential ("arbitrary")
so a VMEM scratch carries the running SSM state [P, N] across chunks —
the HBM-resident inter-chunk state tensor of the XLA path (models/mamba2)
never exists.  Per chunk the kernel computes, entirely in VMEM:

    intra  = (C B^T  .*  L) dt x        (cs x cs dual form, MXU)
    inter  = C S_in  .*  exp(cumsum dA)
    S_out  = exp(sum dA) S_in + (B dt-decay)^T x

Chunk size cs = 128..256 keeps the [cs, cs] score tile and the [P, N]
state tile (64*128 f32 = 32 KiB) VMEM-resident.

This is the TPU-native blocking of the Mamba2 CUDA kernel (DESIGN.md §5):
the warp-level parallel prefix of the GPU implementation becomes a
grid-sequential VMEM-carried state, which matches the TPU's
software-pipelined sequential grid model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x; pick
# whichever this install provides.
_compiler_params = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref,
            y_ref, state_scr, *, n_chunks):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # [cs, P]
    dt = dt_ref[0].astype(jnp.float32)      # [cs, 1] -> [cs]
    dt = dt[:, 0]
    a = a_ref[0, 0]                         # scalar (per-head A)
    b = b_ref[0].astype(jnp.float32)        # [cs, N]
    c = c_ref[0].astype(jnp.float32)        # [cs, N]
    d_skip = dskip_ref[0, 0]

    cs = x.shape[0]
    da = dt * a                              # [cs]
    da_cum = jnp.cumsum(da)                  # inclusive
    da_total = da_cum[-1]

    # intra-chunk dual form
    seg = da_cum[:, None] - da_cum[None, :]  # seg[l,s] = sum_{s<k<=l}
    tri = jnp.tril(jnp.ones((cs, cs), jnp.float32))
    l_mat = jnp.exp(jnp.where(tri > 0, seg, -jnp.inf))
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    m = scores * l_mat                       # [cs(l), cs(s)]
    y_intra = jax.lax.dot_general(m * dt[None, :], x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    s_in = state_scr[...]                    # [P, N]
    y_inter = jax.lax.dot_general(c, s_in, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(da_cum)[:, None]

    y_ref[...] = (y_intra + y_inter + d_skip * x)[None].astype(y_ref.dtype)

    # state update: S_out = exp(da_total) S_in + x^T (B * decay * dt)
    decay = jnp.exp(da_total - da_cum) * dt  # [cs]
    state_new = jnp.exp(da_total) * s_in + jax.lax.dot_general(
        x, b * decay[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = state_new


def ssd_scan_tiled(x, dt, a, b_mat, c_mat, d_skip, *, chunk: int,
                   interpret: bool = False):
    """x [BH, S, P]; dt [BH, S]; a [BH]; b/c [BH, S, N]; d_skip [BH]
    -> y [BH, S, P].  (ops.py folds batch*heads and broadcasts B/C over
    heads.)  S % chunk == 0."""
    bh, s, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    grid = (bh, nc)
    kernel = functools.partial(_kernel, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt[..., None], a[:, None], b_mat, c_mat, d_skip[:, None])
