"""Pure-jnp oracle for masked_aggregate."""
import jax
import jax.numpy as jnp


def masked_aggregate_ref(gstack: jax.Array, coef: jax.Array) -> jax.Array:
    """out[d] = sum_i coef_i g[i, d], fp32 accumulation."""
    return jnp.einsum("nd,n->d", gstack.astype(jnp.float32),
                      coef.astype(jnp.float32))


def quantizer_levels(bits) -> jax.Array:
    """Symmetric level count with the ternary floor at bits=1 (matches
    ``repro.fl.engine.quantize_levels`` for array inputs)."""
    return jnp.maximum(2.0 ** (jnp.asarray(bits, jnp.float32) - 1.0) - 1.0,
                       1.0)


def quantized_masked_aggregate_ref(gstack: jax.Array, coef: jax.Array,
                                   noise: jax.Array, bits) -> jax.Array:
    """out[d] = sum_i coef_i Q_{b_i}(g[i, :])[d] with explicit noise.

    Per-client max-scaled stochastic rounding (``engine.quantize_with_noise``
    with per-row scale) followed by the masked sum; ``bits`` is a scalar or
    per-client [N] array.
    """
    g = gstack.astype(jnp.float32)
    levels = jnp.broadcast_to(quantizer_levels(bits), (g.shape[0],))[:, None]
    scale = jnp.maximum(jnp.max(jnp.abs(g), axis=1), 1e-12)[:, None] / levels
    scaled = g / scale
    low = jnp.floor(scaled)
    q = low + (noise.astype(jnp.float32) < scaled - low)
    q = jnp.clip(q, -levels, levels) * scale
    return jnp.einsum("nd,n->d", q, coef.astype(jnp.float32))
