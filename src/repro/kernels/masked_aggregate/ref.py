"""Pure-jnp oracle for masked_aggregate."""
import jax
import jax.numpy as jnp


def masked_aggregate_ref(gstack: jax.Array, coef: jax.Array) -> jax.Array:
    """out[d] = sum_i coef_i g[i, d], fp32 accumulation."""
    return jnp.einsum("nd,n->d", gstack.astype(jnp.float32),
                      coef.astype(jnp.float32))
