"""Jit'd wrapper: aggregate a pytree of stacked client gradients with a
coefficient vector — the FL engine's ``aggregate_fn`` plug-in
(engine.run_fl(aggregate_fn=masked_aggregate_pytree))."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.masked_aggregate.kernel import (
    CLIENT_BLK, LANE_BLK, masked_aggregate_tiled)


@partial(jax.jit, static_argnames=("interpret",))
def masked_aggregate(gstack: jax.Array, coef: jax.Array,
                     interpret: bool | None = None) -> jax.Array:
    """gstack [N, ...] -> [...] (leading client axis reduced).

    ``interpret=None`` auto-selects: the compiled Pallas kernel on TPU,
    interpret mode (functional check) everywhere else.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = gstack.shape[0]
    lead_shape = gstack.shape[1:]
    d = int(np.prod(lead_shape))
    flat = gstack.reshape(n, d)
    n_pad = -(-n // CLIENT_BLK) * CLIENT_BLK - n
    d_pad = -(-d // LANE_BLK) * LANE_BLK - d
    flat = jnp.pad(flat, ((0, n_pad), (0, d_pad)))
    coef_p = jnp.pad(coef, (0, n_pad))
    out = masked_aggregate_tiled(flat, coef_p, interpret=interpret)
    return out[:d].reshape(lead_shape)


def masked_aggregate_pytree(gstack_tree, coef, interpret: bool | None = None):
    return jax.tree_util.tree_map(
        lambda g: masked_aggregate(g, coef, interpret=interpret).astype(g.dtype),
        gstack_tree)
