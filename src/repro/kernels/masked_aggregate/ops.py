"""Jit'd wrapper: aggregate a pytree of stacked client gradients with a
coefficient vector — the FL engine's ``aggregate_fn`` plug-in
(engine.run_fl(aggregate_fn=masked_aggregate_pytree))."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.masked_aggregate.kernel import (
    CLIENT_BLK, LANE_BLK, masked_aggregate_tiled,
    quantized_masked_aggregate_tiled)
from repro.kernels.masked_aggregate.ref import quantizer_levels


@partial(jax.jit, static_argnames=("interpret",))
def masked_aggregate(gstack: jax.Array, coef: jax.Array,
                     interpret: bool | None = None) -> jax.Array:
    """gstack [N, ...] -> [...] (leading client axis reduced).

    ``interpret=None`` auto-selects: the compiled Pallas kernel on TPU,
    interpret mode (functional check) everywhere else.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = gstack.shape[0]
    lead_shape = gstack.shape[1:]
    d = int(np.prod(lead_shape))
    flat = gstack.reshape(n, d)
    n_pad = -(-n // CLIENT_BLK) * CLIENT_BLK - n
    d_pad = -(-d // LANE_BLK) * LANE_BLK - d
    flat = jnp.pad(flat, ((0, n_pad), (0, d_pad)))
    coef_p = jnp.pad(coef, (0, n_pad))
    out = masked_aggregate_tiled(flat, coef_p, interpret=interpret)
    return out[:d].reshape(lead_shape)


def masked_aggregate_pytree(gstack_tree, coef, interpret: bool | None = None):
    return jax.tree_util.tree_map(
        lambda g: masked_aggregate(g, coef, interpret=interpret).astype(g.dtype),
        gstack_tree)


@partial(jax.jit, static_argnames=("interpret",))
def quantized_masked_aggregate(gstack: jax.Array, coef: jax.Array,
                               noise: jax.Array, bits,
                               interpret: bool | None = None) -> jax.Array:
    """gstack/noise [N, ...] -> [...]: per-client b_i-bit stochastic-rounding
    quantisation fused into the masked sum.  ``bits`` is a scalar or [N]
    array; ``noise`` is uniform(0,1) of gstack's shape (precomputed so the
    kernel matches the unfused quantise-then-sum path exactly)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = gstack.shape[0]
    lead_shape = gstack.shape[1:]
    d = int(np.prod(lead_shape))
    flat = gstack.reshape(n, d).astype(jnp.float32)
    noise_f = noise.reshape(n, d).astype(jnp.float32)
    levels = jnp.broadcast_to(quantizer_levels(bits), (n,))
    scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-12) / levels
    n_pad = -(-n // CLIENT_BLK) * CLIENT_BLK - n
    d_pad = -(-d // LANE_BLK) * LANE_BLK - d
    flat = jnp.pad(flat, ((0, n_pad), (0, d_pad)))
    noise_f = jnp.pad(noise_f, ((0, n_pad), (0, d_pad)), constant_values=1.0)
    coef_p = jnp.pad(coef, (0, n_pad))
    scale_p = jnp.pad(scale, (0, n_pad), constant_values=1.0)
    levels_p = jnp.pad(levels, (0, n_pad), constant_values=1.0)
    out = quantized_masked_aggregate_tiled(flat, coef_p, noise_f, scale_p,
                                           levels_p, interpret=interpret)
    return out[:d].reshape(lead_shape)


def quantized_aggregate_pytree(gstack_tree, coef, key, bits,
                               interpret: bool | None = None):
    """Key-streamed pytree front-end: splits ``key`` exactly like
    ``engine._quantize_tree`` (per leaf, then per client) so the fused
    kernel reproduces the unfused engines' noise bit-for-bit."""
    leaves, treedef = jax.tree_util.tree_flatten(gstack_tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        ks = jax.random.split(k, leaf.shape[0])
        noise = jax.vmap(
            lambda kk, shp=leaf.shape[1:]: jax.random.uniform(kk, shp))(ks)
        out.append(quantized_masked_aggregate(
            leaf, coef, noise, bits, interpret=interpret).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
