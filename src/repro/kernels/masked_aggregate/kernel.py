"""Pallas TPU kernel: mask-weighted gradient aggregation (server eq. 4).

Computes  out[d] = sum_i coef_i * g[i, d]  over a stack of client updates
g [N, D] with coef = alpha_i * m_i (participation mask x aggregation
weight).  The stack is streamed HBM -> VMEM in (CLIENT_BLK, LANE_BLK)
tiles; accumulation is fp32 in the output VMEM tile across the client
grid dimension (revisited-output accumulation), so each output element is
written to HBM exactly once per lane tile.

Tiling: LANE_BLK = 512 f32 lanes (MXU/VPU aligned, 4 x 128) and
CLIENT_BLK = 64 keeps the working set (64*512*4 B = 128 KiB input +
2 KiB coef + 2 KiB acc) comfortably inside the ~16 MiB v5e VMEM with
double buffering.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CLIENT_BLK = 64
LANE_BLK = 512


def _kernel(g_ref, coef_ref, out_ref):
    i = pl.program_id(1)          # client-block index (accumulation dim)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)            # [CLIENT_BLK, LANE_BLK]
    coef = coef_ref[...].astype(jnp.float32)      # [CLIENT_BLK, 1]
    out_ref[...] += jnp.sum(g * coef, axis=0, keepdims=True)


def _quantized_kernel(g_ref, coef_ref, noise_ref, scale_ref, levels_ref,
                      out_ref):
    i = pl.program_id(1)          # client-block index (accumulation dim)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)            # [CLIENT_BLK, LANE_BLK]
    coef = coef_ref[...].astype(jnp.float32)      # [CLIENT_BLK, 1]
    noise = noise_ref[...].astype(jnp.float32)    # [CLIENT_BLK, LANE_BLK]
    scale = scale_ref[...].astype(jnp.float32)    # [CLIENT_BLK, 1]
    levels = levels_ref[...].astype(jnp.float32)  # [CLIENT_BLK, 1]
    scaled = g / scale
    low = jnp.floor(scaled)
    q = low + (noise < scaled - low).astype(jnp.float32)
    q = jnp.clip(q, -levels, levels) * scale
    out_ref[...] += jnp.sum(q * coef, axis=0, keepdims=True)


def quantized_masked_aggregate_tiled(gstack: jax.Array, coef: jax.Array,
                                     noise: jax.Array, scale: jax.Array,
                                     levels: jax.Array,
                                     interpret: bool = False) -> jax.Array:
    """Stochastic-rounding quantisation fused into the masked sum.

    gstack/noise [N, D], coef/scale/levels [N] -> [D] fp32.  ``scale`` and
    ``levels`` are precomputed per client (scale needs the row-max over
    the *whole* leaf, which a lane tile cannot see); ``noise`` is
    precomputed uniform(0,1) so kernel-vs-reference agreement is exact
    rather than distributional.  N % CLIENT_BLK == 0, D % LANE_BLK == 0
    (ops.py pads).
    """
    n, d = gstack.shape
    assert n % CLIENT_BLK == 0 and d % LANE_BLK == 0, (n, d)
    grid = (d // LANE_BLK, n // CLIENT_BLK)
    out = pl.pallas_call(
        _quantized_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((CLIENT_BLK, LANE_BLK), lambda j, i: (i, j)),
            pl.BlockSpec((CLIENT_BLK, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((CLIENT_BLK, LANE_BLK), lambda j, i: (i, j)),
            pl.BlockSpec((CLIENT_BLK, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((CLIENT_BLK, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE_BLK), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(gstack, coef[:, None], noise, scale[:, None], levels[:, None])
    return out[0]


def masked_aggregate_tiled(gstack: jax.Array, coef: jax.Array,
                           interpret: bool = False) -> jax.Array:
    """gstack [N, D], coef [N] -> [D] fp32.  N % CLIENT_BLK == 0,
    D % LANE_BLK == 0 (ops.py pads)."""
    n, d = gstack.shape
    assert n % CLIENT_BLK == 0 and d % LANE_BLK == 0, (n, d)
    grid = (d // LANE_BLK, n // CLIENT_BLK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((CLIENT_BLK, LANE_BLK), lambda j, i: (i, j)),
            pl.BlockSpec((CLIENT_BLK, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE_BLK), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(gstack, coef[:, None])
    return out[0]
