"""Batched multi-scenario fleet solver: many problem (7) instances at once.

The paper's Algorithm 2 solves one 100-device instance.  Ensemble studies
(fading draws, bandwidth mixes, fleet-size sweeps — cf. Perazzone et al.,
arXiv:2201.07912 and Guo et al., arXiv:2205.09306, which both evaluate
over large ensembles of channel realisations) need *thousands* of
heterogeneous instances.  This module stacks them into one device-sharded
batch:

* ``ProblemBatch`` — a pytree of ``WirelessFLProblem`` leaves stacked to
  ``[B, N_max]`` (``[B, N_max, K]`` for fading), with ragged fleet sizes
  handled by padding plus a ``[B, N_max]`` validity ``mask``.  Padded
  device slots are constructed so every solver *self-deselects* them
  (zero energy budget => a* = 0) — no solver change needed.
* ``stack_problems`` / ``ProblemBatch.unstack`` — build/split the batch.
* ``solve_joint_batch`` — ``jax.vmap`` of Algorithm 2 (or the fused
  single-level solver, the exact bisection optimum, or the Pallas
  ``selection_solve``/``fused_solve`` kernel fast paths) across the
  batch, jitted once, optionally sharded over the local device mesh with
  ``jax.sharding.NamedSharding`` along the batch axis — or, for
  ``method="fused"``, along the flattened *element* axis with an optional
  ``chunk_elements`` memory bound (the mega-fleet path: a single 100k- or
  1M-device instance spreads over the mesh and solves in fixed memory).

Static metadata (``p_max``, ``tau_th``, ``grad_size_bits``, ...) is shared
batch-wide — ``stack_problems`` raises if instances disagree, since those
fields are compiled into the kernel as constants.

See ``docs/scenarios.md`` for the scenario generators that feed this API
and ``tests/test_batch_solver.py`` for the agreement guarantees.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alternating import (
    FleetElements,
    JointSolution,
    WarmStart,
    fused_fixed_point_flat,
    solve_joint,
)
from repro.core.optimal import solve_joint_optimal
from repro.core.problem import NEUTRAL_FILLS, WirelessFLProblem

# static (non-leaf) fields that must be uniform across a batch
_STATIC_FIELDS = ("grad_size_bits", "noise_power", "p_max", "tau_th",
                  "kappa", "n_rounds")
# array leaves stacked along the new batch axis, with the value used to
# fill padded device slots.  Padding is chosen so padded slots are
# *infeasible at any a > 0* (zero energy budget) yet produce no NaN/inf in
# any solver: distance 1 m keeps path gain finite, weight 0 removes the
# slot from every objective.  The same fills sanitize unhealthy devices
# (``WirelessFLProblem.sanitize``) — one idiom, one source of truth.
_PAD_VALUES = NEUTRAL_FILLS


class BatchSolution(NamedTuple):
    """Stacked per-instance solutions. All arrays lead with the batch axis."""

    a: jax.Array           # [B, N_max] (or [B, N_max, K])
    power: jax.Array       # same shape as a
    objective: jax.Array   # [B]
    n_iters: jax.Array     # [B] or scalar
    converged: jax.Array   # [B] bool
    mask: jax.Array        # [B, N_max] bool — valid device slots
    # summed inner power-solver iterations ([B] or scalar; 0 for the
    # closed-form analytic modes) — what warm starts collapse
    inner_iters: jax.Array | int = 0
    # chosen uplink bit widths (method="fused" with a bit_menu); None
    # otherwise — mirrors JointSolution.bits
    bits: Optional[jax.Array] = None

    def instance(self, b: int) -> JointSolution:
        """Per-instance JointSolution with padding stripped."""
        n = int(np.sum(np.asarray(self.mask[b])))
        return JointSolution(a=self.a[b, :n], power=self.power[b, :n],
                             objective=self.objective[b],
                             n_iters=jnp.asarray(self.n_iters)[b]
                             if jnp.ndim(self.n_iters) else self.n_iters,
                             converged=self.converged[b],
                             inner_iters=jnp.asarray(self.inner_iters)[b]
                             if jnp.ndim(self.inner_iters) else self.inner_iters,
                             bits=None if self.bits is None
                             else self.bits[b, :n])

    @property
    def resume(self) -> WarmStart:
        """Batch warm-start state for a subsequent nearby batched solve."""
        return WarmStart(a=self.a, power=self.power)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ProblemBatch:
    """B stacked ``WirelessFLProblem`` instances, padded to a common N_max.

    ``problem`` holds the stacked leaves (``[B, N_max]``; fading
    ``[B, N_max, K]``); its static metadata is the batch-wide shared
    configuration.  ``mask[b, i]`` is True iff slot ``i`` of instance ``b``
    is a real device; ``fleet_sizes[b]`` is the true (unpadded) N.
    """

    problem: WirelessFLProblem
    mask: jax.Array          # [B, N_max] bool
    fleet_sizes: jax.Array   # [B] int32

    @property
    def batch_size(self) -> int:
        return int(self.mask.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.mask.shape[1])

    def unstack(self) -> list[WirelessFLProblem]:
        """Split back into per-instance problems (padding stripped)."""
        sizes = np.asarray(self.fleet_sizes)
        out = []
        for b in range(self.batch_size):
            n = int(sizes[b])
            kw = {}
            for f in dataclasses.fields(WirelessFLProblem):
                v = getattr(self.problem, f.name)
                if f.name in _PAD_VALUES:
                    v = v[b, :n]
                elif f.name in ("fading", "interference", "bits"):
                    v = None if v is None else v[b, :n]
                kw[f.name] = v
            out.append(WirelessFLProblem(**kw))
        return out


def _pad_tail(x: jax.Array, n_max: int, fill: float) -> np.ndarray:
    # numpy, not jnp: stacking happens on the serving hot path (one
    # micro-batch per step), where B x n_fields eager jnp pad/stack ops
    # cost ~100x their numpy equivalents in dispatch overhead alone
    x = np.asarray(x)
    pad = [(0, n_max - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad, constant_values=fill)


def stack_problems(problems: Sequence[WirelessFLProblem]) -> ProblemBatch:
    """Stack instances into a ProblemBatch, padding ragged fleet sizes.

    All instances must share the static metadata (``p_max``, ``tau_th``,
    ``grad_size_bits``, ``noise_power``, ``kappa``, ``n_rounds``) — those
    are jit-compile-time constants.  Instances may freely differ in fleet
    size and in every per-device array.  Fading must be all-or-none: a
    non-fading instance solves one [N] round while a fading one solves
    [N, K] rounds, so mixing them in one batch would silently change the
    non-fading instances' objective (summed over K synthetic rounds).
    Pass explicit unit fading to opt a static-channel instance into a
    fading batch.
    """
    if not problems:
        raise ValueError("stack_problems needs at least one problem")
    ref = problems[0]
    for p in problems[1:]:
        for f in _STATIC_FIELDS:
            if getattr(p, f) != getattr(ref, f):
                raise ValueError(
                    f"static field {f!r} differs across the batch "
                    f"({getattr(p, f)} vs {getattr(ref, f)}); solve instances "
                    "with differing statics in separate batches")

    n_max = max(p.n_devices for p in problems)
    n_fading = sum(p.fading is not None for p in problems)
    if 0 < n_fading < len(problems):
        raise ValueError(
            f"{n_fading}/{len(problems)} instances carry fading; fading must "
            "be all-or-none per batch (give static-channel instances "
            "explicit unit fading to mix them in)")
    n_interf = sum(p.interference is not None for p in problems)
    if 0 < n_interf < len(problems):
        raise ValueError(
            f"{n_interf}/{len(problems)} instances carry interference; "
            "interference must be all-or-none per batch (give quiet cells "
            "explicit zero interference to mix them in)")
    if n_interf and len({p.interference.ndim for p in problems}) > 1:
        raise ValueError("interference rank ([N] vs [N, K]) must be uniform "
                         "across the batch")
    n_bits = sum(p.bits is not None for p in problems)
    if 0 < n_bits < len(problems):
        raise ValueError(
            f"{n_bits}/{len(problems)} instances carry a bits leaf; bits "
            "must be all-or-none per batch (give full-precision instances "
            "explicit bits=32 to mix them in)")
    if n_bits and len({p.bits.ndim for p in problems}) > 1:
        raise ValueError("bits rank ([N] vs [N, K]) must be uniform "
                         "across the batch")

    stacked: dict[str, jax.Array] = {}
    for name, fill in _PAD_VALUES.items():
        stacked[name] = jnp.asarray(np.stack(
            [_pad_tail(getattr(p, name), n_max, fill) for p in problems]))
    fading = None
    if n_fading:
        fading = jnp.asarray(np.stack(
            [_pad_tail(p.fading, n_max, 1.0) for p in problems]))
    interference = None
    if n_interf:
        interference = jnp.asarray(np.stack(
            [_pad_tail(p.interference, n_max, 0.0) for p in problems]))
    bits = None
    if n_bits:
        bits = jnp.asarray(np.stack(
            [_pad_tail(p.bits, n_max, 32.0) for p in problems]))

    sizes = np.array([p.n_devices for p in problems], np.int32)
    mask = jnp.asarray(np.arange(n_max)[None, :] < sizes[:, None])
    prob = WirelessFLProblem(
        fading=fading,
        interference=interference,
        bits=bits,
        **stacked,
        **{f: getattr(ref, f) for f in _STATIC_FIELDS},
    )
    return ProblemBatch(problem=prob, mask=mask,
                        fleet_sizes=jnp.asarray(sizes))


def pad_batch(batch: ProblemBatch, *, batch_size: Optional[int] = None,
              n_max: Optional[int] = None) -> ProblemBatch:
    """Pad a batch to fixed ``(batch_size, n_max)`` slot shapes.

    The serving path packs variable request micro-batches into quantised
    slot shapes so jit compiles once per bucket instead of once per
    (B, N) combination.  Padded instance rows reuse ``_PAD_VALUES`` (zero
    energy budget => every solver self-deselects them) with an all-False
    mask row and fleet size 0; ``BatchSolution.instance`` never exposes
    them.  Shrinking is not supported.
    """
    b0, n0 = batch.batch_size, batch.n_max
    bsz = b0 if batch_size is None else batch_size
    nmx = n0 if n_max is None else n_max
    if bsz < b0 or nmx < n0:
        raise ValueError(f"pad_batch cannot shrink ({b0}, {n0}) -> "
                         f"({bsz}, {nmx})")
    if (bsz, nmx) == (b0, n0):
        return batch
    db, dn = bsz - b0, nmx - n0
    kw = {}
    for f in dataclasses.fields(WirelessFLProblem):
        v = getattr(batch.problem, f.name)
        if f.name in _PAD_VALUES:
            v = jnp.asarray(np.pad(np.asarray(v), [(0, db), (0, dn)],
                                   constant_values=_PAD_VALUES[f.name]))
        elif f.name == "fading" and v is not None:
            v = jnp.asarray(np.pad(np.asarray(v), [(0, db), (0, dn), (0, 0)],
                                   constant_values=1.0))
        elif f.name == "interference" and v is not None:
            pad = [(0, db), (0, dn)] + [(0, 0)] * (np.ndim(v) - 2)
            v = jnp.asarray(np.pad(np.asarray(v), pad, constant_values=0.0))
        elif f.name == "bits" and v is not None:
            pad = [(0, db), (0, dn)] + [(0, 0)] * (np.ndim(v) - 2)
            v = jnp.asarray(np.pad(np.asarray(v), pad, constant_values=32.0))
        kw[f.name] = v
    mask = jnp.asarray(np.pad(np.asarray(batch.mask), [(0, db), (0, dn)],
                              constant_values=False))
    sizes = jnp.asarray(np.pad(np.asarray(batch.fleet_sizes), (0, db)))
    return ProblemBatch(problem=WirelessFLProblem(**kw), mask=mask,
                        fleet_sizes=sizes)


# --------------------------------------------------------------- sharding

def batch_sharding(batch_size: int,
                   mesh: Optional[jax.sharding.Mesh] = None
                   ) -> Optional[jax.sharding.NamedSharding]:
    """NamedSharding that splits the batch axis over the local devices.

    A user-supplied ``mesh`` may use any axis naming; the batch axis is
    split along the mesh's *first* axis.  Returns None when sharding is a
    no-op (single device) or impossible (batch not divisible by the device
    count — jax requires equal shards).
    """
    if mesh is None:
        devices = jax.devices()
        if len(devices) <= 1:
            return None
        mesh = jax.sharding.Mesh(np.array(devices), ("batch",))
    axis = mesh.axis_names[0]
    n_shards = mesh.shape[axis]
    if n_shards <= 1 or batch_size % n_shards != 0:
        return None
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(axis))


def shard_batch(batch: ProblemBatch,
                mesh: Optional[jax.sharding.Mesh] = None) -> ProblemBatch:
    """Place every leaf of the batch with its batch axis split over devices."""
    sharding = batch_sharding(batch.batch_size, mesh)
    if sharding is None:
        return batch
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sharding), batch)


# ----------------------------------------------------------------- solver

def _mask_solution(sol: JointSolution, mask: jax.Array) -> BatchSolution:
    m = mask if sol.a.ndim == mask.ndim else mask[..., None]
    return BatchSolution(a=jnp.where(m, sol.a, 0.0),
                         power=jnp.where(m, sol.power, 0.0),
                         objective=sol.objective, n_iters=sol.n_iters,
                         converged=sol.converged, mask=mask,
                         inner_iters=sol.inner_iters,
                         bits=None if sol.bits is None
                         else jnp.where(m, sol.bits, 32.0))


@partial(jax.jit, static_argnames=("method", "power_solver",
                                   "faithful_eq13_typo", "max_iters"))
def _solve_batch_vmapped(batch: ProblemBatch, method: str, power_solver: str,
                         faithful_eq13_typo: bool, eps: float,
                         max_iters: int,
                         init: Optional[WarmStart]) -> BatchSolution:
    if method == "optimal":
        sol = jax.vmap(solve_joint_optimal)(batch.problem)
    else:
        solve = partial(solve_joint, eps=eps, max_iters=max_iters,
                        power_solver=power_solver,
                        faithful_eq13_typo=faithful_eq13_typo)
        if init is None:
            sol = jax.vmap(solve)(batch.problem)
        else:
            sol = jax.vmap(lambda p, a0, p0: solve(p, init=(a0, p0)))(
                batch.problem, init[0], init[1])
    return _mask_solution(sol, batch.mask)


def batch_elements(batch: ProblemBatch) -> FleetElements:
    """Stacked per-element constraint data, shape [B, N_max] or [B, N_max, K]."""
    problem = batch.problem
    # per-instance rank-sensitive broadcasting lives in path_gain(); vmap it
    # rather than reimplementing the [B, N, K] case here.
    pg = jax.vmap(WirelessFLProblem.path_gain)(problem)

    def b(x):
        return jnp.broadcast_to(x[..., None] if x.ndim < pg.ndim else x,
                                pg.shape)

    return FleetElements(pg=pg, bw=b(problem.bandwidth_hz),
                         emax=b(problem.energy_budget_j),
                         ec=b(jax.vmap(WirelessFLProblem.compute_energy)(problem)),
                         sbits=None if problem.bits is None
                         else b(problem.grad_size_bits * problem.bits / 32.0))


@partial(jax.jit, static_argnames=("power_solver", "faithful_eq13_typo",
                                   "max_iters", "chunk_elements", "mesh",
                                   "shard", "bit_menu"))
def _solve_batch_fused(batch: ProblemBatch, power_solver: str,
                       faithful_eq13_typo: bool, eps: float, max_iters: int,
                       chunk_elements: Optional[int],
                       mesh: Optional[jax.sharding.Mesh],
                       shard: bool,
                       init: Optional[WarmStart],
                       bit_menu: Optional[tuple] = None) -> BatchSolution:
    """The fused flat path: one convergence-masked iteration over the whole
    [B * N_max (* K)] element set — no per-instance lockstep, optionally
    chunked (fixed memory) and sharded along the *element* axis (a single
    mega-fleet instance spreads over the mesh even at B = 1)."""
    el = batch_elements(batch)
    shape = el.pg.shape
    flat = jax.tree_util.tree_map(lambda x: x.reshape(-1), el)
    flat_init = None
    if init is not None:
        flat_init = tuple(
            jnp.broadcast_to(jnp.asarray(x, jnp.float32),
                             shape).reshape(-1) for x in init)
    out = fused_fixed_point_flat(
        flat, s_bits=batch.problem.grad_size_bits, tau=batch.problem.tau_th,
        p_max=batch.problem.p_max, eps=eps, max_iters=max_iters,
        power_solver=power_solver, faithful_eq13_typo=faithful_eq13_typo,
        chunk_elements=chunk_elements, mesh=mesh, shard=shard,
        init=flat_init, bit_menu=bit_menu)
    bits = None
    if bit_menu is None:
        a, p, iters, conv, inner = out
    else:
        a, p, iters, conv, inner, bits = out
        bits = bits.reshape(shape)
    a, p, conv = a.reshape(shape), p.reshape(shape), conv.reshape(shape)
    b = shape[0]
    sol = JointSolution(
        a=a, power=p,
        objective=jax.vmap(WirelessFLProblem.objective)(batch.problem, a),
        n_iters=jnp.broadcast_to(iters, (b,)),
        converged=conv.reshape(b, -1).all(axis=1),
        inner_iters=inner, bits=bits)
    return _mask_solution(sol, batch.mask)


def solve_joint_batch(batch: ProblemBatch,
                      *,
                      method: str = "alternating",
                      power_solver: Optional[str] = None,
                      faithful_eq13_typo: bool = False,
                      eps: float = 1e-7,
                      max_iters: int = 50,
                      shard: bool = True,
                      mesh: Optional[jax.sharding.Mesh] = None,
                      chunk_elements: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      sanitize: bool = False,
                      init: Optional[WarmStart] = None,
                      bit_menu: Optional[tuple] = None) -> BatchSolution:
    """Solve every instance of ``batch`` in one jitted, device-sharded call.

    ``sanitize=True`` runs ``WirelessFLProblem.sanitize`` over the
    stacked leaves first: devices with non-finite / out-of-domain data
    self-deselect (a* = P* = 0, the padded-slot idiom) instead of
    poisoning the solve; healthy batches are bit-identical to
    ``sanitize=False`` (docs/robustness.md).

    method:
      * ``"alternating"``  — vmap of Algorithm 2 (``solve_joint``); matches
        a python loop of per-instance solves to solver tolerance.
      * ``"fused"``        — the fused single-level solver
        (``core.alternating.fused_fixed_point_flat``) over the flattened
        element set: same fixed point as ``"alternating"`` (agreement
        <= 1e-5 elementwise) but one flat convergence-masked loop — no
        nested while-loops, so the batch never waits on the slowest inner
        solve.  The mega-fleet path: honours ``chunk_elements`` and
        shards the *element* axis (not just the batch axis).
      * ``"optimal"``      — vmap of the exact bisection optimum
        (``solve_joint_optimal``).
      * ``"kernel"``       — the Pallas ``selection_solve`` kernel over the
        flattened ``[B * N_max]`` element set (solves the same bisection
        problem as ``"optimal"``; ``interpret=True`` runs it off-TPU).
      * ``"fused_kernel"`` — the Pallas ``fused_solve`` kernel: the fused
        alternating fixed point, whole tiles VMEM-resident
        (``interpret=True`` runs it off-TPU).

    ``power_solver`` (default: ``"dinkelbach"`` for ``"alternating"``,
    ``"analytic"`` — the bit-identical closed form — for the fused
    methods), ``faithful_eq13_typo``, ``eps``, and ``max_iters`` are
    Algorithm-2 knobs and apply only to the alternating/fused methods
    (the other methods compute the exact per-element optimum directly);
    requesting the eq.-13 typo with them is an error rather than a
    silent mismatch.  ``"fused_kernel"`` runs ``max_iters`` fixed
    iterations (no ``eps`` early-exit — the iteration is stationary past
    its fixed point) and rejects ``power_solver="dinkelbach"``.

    ``shard=True`` splits the batch axis (the element axis for
    ``"fused"``) over the local devices with a ``NamedSharding`` before
    solving (no-op on a single device).  ``chunk_elements`` bounds the
    fused solve's working set to a fixed number of elements regardless of
    fleet size (only valid with ``method="fused"``).  Padded device slots
    come back with ``a = power = 0``; per-instance objectives never
    include them (their objective weight is 0).

    ``init`` (a :class:`WarmStart` or ``(a0, p0)`` pair shaped like the
    batch solution, typically a previous ``BatchSolution.resume``)
    warm-starts the iterative methods; all-zero rows mean "no previous
    state" and behave exactly cold, so mixed warm/cold micro-batches need
    no special casing.  Solutions are init-independent — see
    ``core.alternating``'s warm-start notes; only iteration counts
    (``inner_iters``) change.  The direct methods ("optimal"/"kernel")
    and the fixed-trip "fused_kernel" have no iteration to warm-start
    and reject ``init``.

    ``bit_menu`` (method="fused" only) runs the joint bit/power/selection
    solve — see ``solve_joint_fused`` — and fills ``BatchSolution.bits``.
    """
    if method not in ("alternating", "fused", "optimal", "kernel",
                      "fused_kernel"):
        raise ValueError(f"unknown method {method!r}")
    if bit_menu is not None and method != "fused":
        raise ValueError(
            f"bit_menu is implemented by the fused single-level solver "
            f"only; method={method!r} would silently ignore it")
    if method in ("kernel", "fused_kernel") and batch.problem.bits is not None:
        raise ValueError(
            "the Pallas kernel methods compile a single static payload and "
            "would silently ignore the per-device bits leaf; use "
            "method='fused' (or 'alternating'/'optimal') for bit-scaled "
            "problems")
    if sanitize:
        prob, _ = batch.problem.sanitize()
        batch = dataclasses.replace(batch, problem=prob)
    if init is not None:
        if method not in ("alternating", "fused"):
            raise ValueError(
                f"init warm-starts the iterative methods only; "
                f"method={method!r} computes its solution in a fixed "
                "number of steps and would silently ignore it")
        init = WarmStart(a=jnp.asarray(init[0], jnp.float32),
                         power=jnp.asarray(init[1], jnp.float32))
    alg2 = method in ("alternating", "fused", "fused_kernel")
    if not alg2 and faithful_eq13_typo:
        raise ValueError(
            f"faithful_eq13_typo only applies to the Algorithm-2 methods "
            f"('alternating'/'fused'/'fused_kernel'); method={method!r} "
            "computes the exact per-element optimum and has no eq. (13) step")
    if chunk_elements is not None and method != "fused":
        raise ValueError(
            f"chunk_elements is a method='fused' memory bound; "
            f"method={method!r} would silently ignore it")
    if power_solver is None:
        power_solver = ("analytic" if method in ("fused", "fused_kernel")
                        else "dinkelbach")
    if method == "fused_kernel" and power_solver != "analytic":
        raise ValueError(
            f"method='fused_kernel' only implements the analytic "
            f"(closed-form) power update; power_solver={power_solver!r} "
            "would be silently ignored — use method='fused' for the "
            "Dinkelbach reference mode")
    if method == "fused":
        menu = None if bit_menu is None else tuple(
            sorted({float(b) for b in bit_menu}, reverse=True))
        return _solve_batch_fused(batch, power_solver, faithful_eq13_typo,
                                  eps, max_iters, chunk_elements, mesh, shard,
                                  init, menu)
    if shard:
        batch = shard_batch(batch, mesh)
    if method == "kernel":
        from repro.kernels.selection_solve.ops import solve_joint_kernel_batch
        return solve_joint_kernel_batch(
            batch, interpret=True if interpret is None else interpret)
    if method == "fused_kernel":
        from repro.kernels.selection_solve.ops import solve_joint_fused_kernel_batch
        # the kernel runs its full iteration budget unconditionally (fixed
        # trip count, stationary past the fixed point), so ``eps`` has no
        # kernel analogue; ``max_iters`` maps onto that budget.
        return solve_joint_fused_kernel_batch(
            batch, n_iters=max_iters, faithful_eq13_typo=faithful_eq13_typo,
            interpret=True if interpret is None else interpret)
    return _solve_batch_vmapped(batch, method, power_solver,
                                faithful_eq13_typo, eps, max_iters, init)
