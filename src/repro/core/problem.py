"""Problem specification for joint probabilistic selection + power allocation.

Implements the system model of Section II of the paper:

* OFDMA uplink rate  r_ik(P) = B_i log2(1 + P g_ik / (d_i^2 sigma^2))   (g=1 paper)
  (multi-cell: sigma^2 -> sigma^2 + I_ik with cross-cell interference I,
  see core.multicell and docs/multicell.md)
* transmission time  T_ik(P) = S / r_ik(P)                               (eq. 1)
* computation energy E^c_i   = kappa * C_i * |D_i| * gamma_i^2           (eq. 5)
* upload energy      E^u_ik  = P_ik * T_ik(P_ik)

All per-device quantities are jnp arrays of shape ``[N]`` (or ``[N, K]``
when per-round fading is enabled — a beyond-paper generalisation the
closed forms support unchanged because the problem is separable per
``(i, k)``).

Broadcasting contract (``[N]`` vs ``[N, K]``)
---------------------------------------------

Every method taking per-device decision variables (``a``, ``power``)
accepts either rank on any problem, and broadcasts all operands to the
*highest* rank present — the path gain's rank on a fading problem:

* 1-d input on a fading problem means "the same value, evaluated at each
  round's channel draw": the result has shape ``[N, K]``, column k equal
  to the call with that column explicitly (bit-for-bit — see
  ``tests/test_problem_broadcast.py``).
* 2-d input on a static problem broadcasts the per-device constants
  (``bandwidth_hz``, ``energy_budget_j``, ...) across the trailing round
  axis; the result keeps the input's ``[N, K]`` shape.
* matching ranks pass through elementwise.

Internally the rule is: broadcast 1-d operands with ``x[:, None]``
against the ``[N, K]`` path gain, never the reverse — mixing a raw
``[N]`` with an ``[N, K]`` array only "works" when K == N (and is then
silently wrong).  ``core.power`` / ``core.selection`` follow the same
contract through ``_pg`` / ``_bcast_like``.  The contract (with the
equation-by-equation code map) is documented in docs/equations.md
("Broadcasting contract"); ``interference`` follows the same rank
rules as ``fading``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

LN2 = float(np.log(2.0))

# Uncompressed payload of the paper's model: 199_210 fp32 parameters.
# The single source of truth for the magic number — examples, benchmarks
# and the bit-allocation code all import it from here.
GRAD_SIZE_BITS_FP32 = 199_210 * 32.0

# neutral per-device fills used to overwrite unhealthy device rows (see
# ``WirelessFLProblem.sanitize``): a zero energy budget makes every solver
# self-deselect the slot (a* = 0, P* = 0) while distance/bandwidth 1 keep
# all closed forms finite, and weight 0 removes it from the objective.
# ``core.batch._PAD_VALUES`` aliases this dict — padded slots and
# sanitized devices are the same idiom.
NEUTRAL_FILLS = dict(distance_m=1.0, bandwidth_hz=1.0, energy_budget_j=0.0,
                     dataset_size=1.0, cycles_per_sample=1.0, cpu_hz=1.0,
                     weights=0.0)
_FADING_FILL = 1.0
_INTERFERENCE_FILL = 0.0
_BITS_FILL = 32.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WirelessFLProblem:
    """Static description of the joint selection/power problem (7).

    Array fields are leaves (shape ``[N]`` unless noted); python floats are
    static metadata. ``K`` rounds share the same constraint data in the
    paper (channel is static), so solutions are round-independent unless
    ``fading`` (shape ``[N, K]``) is provided.
    """

    # --- per-device wireless/compute state ------------------------------
    distance_m: jax.Array          # d_i, metres to the server
    bandwidth_hz: jax.Array        # B_i
    energy_budget_j: jax.Array     # E_i^max, per-round energy budget
    dataset_size: jax.Array        # |D_i| (float for weighting math)
    cycles_per_sample: jax.Array   # C_i
    cpu_hz: jax.Array              # gamma_i
    weights: jax.Array             # w_i, objective weights (sum to 1)
    fading: Optional[jax.Array] = None   # g_ik in (0, inf), [N, K]; None => 1
    # cross-cell interference power I_ik (W) received at this cell's BS,
    # [N] or [N, K] (per-round rank-2 requires a fading problem so the
    # solution rank stays fading-driven); None => 0 (single cell).  Set
    # by the multi-cell outer loop (core.multicell) — raises the
    # effective noise floor sigma^2 -> sigma^2 + I_ik in the SINR.
    interference: Optional[jax.Array] = None
    # per-device uplink quantisation width b_i in (0, 32] bits/parameter,
    # [N] or [N, K] (per-round rank-2 requires a fading problem so the
    # solution rank stays fading-driven, same rule as ``interference``);
    # None => full-precision fp32 payload (bit-identical to the pre-bits
    # code path).  Scales the effective payload S_i = S * b_i / 32 in
    # ``tx_time`` / ``p_min`` / ``upload_energy`` (docs/compression.md).
    bits: Optional[jax.Array] = None

    # --- shared constants (static) ---------------------------------------
    grad_size_bits: float = dataclasses.field(default=GRAD_SIZE_BITS_FP32, metadata=dict(static=True))
    noise_power: float = dataclasses.field(default=1e-12, metadata=dict(static=True))       # sigma^2
    p_max: float = dataclasses.field(default=1.0, metadata=dict(static=True))               # P^max (W)
    tau_th: float = dataclasses.field(default=0.08, metadata=dict(static=True))             # tau^th (s)
    kappa: float = dataclasses.field(default=1e-28, metadata=dict(static=True))             # switched capacitance
    n_rounds: int = dataclasses.field(default=1, metadata=dict(static=True))                # K

    # ---------------------------------------------------------------- api
    @property
    def n_devices(self) -> int:
        return int(self.distance_m.shape[0])

    def path_gain(self) -> jax.Array:
        """g_ik / (d_i^2 (sigma^2 + I_ik)) — SINR per transmitted watt.

        With ``interference=None`` this is the paper's single-cell SNR
        g/(d^2 sigma^2), shape [N] or [N, K]; the ``interference`` leaf
        raises the effective noise floor (docs/multicell.md).  The
        no-interference path is kept byte-identical to the pre-multicell
        expression so single-cell results cannot drift.
        """
        g = 1.0 if self.fading is None else self.fading
        d2s = jnp.square(self.distance_m) * self.noise_power
        base = 1.0 / d2s
        if self.interference is None:
            if self.fading is None:
                return base
            # a corrupted channel draw (g = 0, NaN) against a tiny d2s
            # must gate the device out (gain 0 => P^min = inf), not emit
            # 0 * inf = NaN; g > 0 leaves healthy draws bit-identical.
            # A rank-1 fading (round-invariant draw) stays rank 1: lifting
            # base to [:, None] against an [N] g builds [N, N] garbage
            # that broadcasts silently whenever K == N.
            return jnp.where(g > 0, g * _bcast_like(base, g.ndim), 0.0)
        # d^2 sigma^2 + d^2 I: the I == 0 case reduces to d^2 sigma^2
        # exactly (adding a true zero is exact in IEEE), so zero
        # interference matches interference=None bit-for-bit.
        d2 = jnp.square(self.distance_m)
        rank = 2 if ((self.fading is not None and self.fading.ndim == 2)
                     or self.interference.ndim == 2) else 1
        iv = _bcast_like(self.interference, rank)
        denom = _bcast_like(d2s, rank) + _bcast_like(d2, rank) * iv
        pg = 1.0 / denom
        if self.fading is None:
            return pg
        gv = _bcast_like(g, pg.ndim)
        return jnp.where(gv > 0, gv * pg, 0.0)

    def _pg(self, like: jax.Array) -> jax.Array:
        """path_gain broadcast to the rank of ``like`` ([N] or [N, K])."""
        pg = self.path_gain()
        if like.ndim > pg.ndim:
            pg = pg[:, None]
        return pg

    def rate(self, power: jax.Array) -> jax.Array:
        """Achievable uplink rate r_ik(P) in bits/s (paper, Sec II-A).

        A 1-d power on a fading ([N, K]) problem broadcasts across rounds:
        the same transmit power, evaluated at each round's channel draw.
        """
        pg = self._pg(power)
        p = power if power.ndim >= pg.ndim else power[:, None]
        bw = self.bandwidth_hz
        if max(p.ndim, pg.ndim) > bw.ndim:
            bw = bw[:, None]
        return bw * jnp.log2(1.0 + p * pg)

    def payload_bits(self, rank: int = 1):
        """Effective uplink payload S_i = S * b_i / 32 in bits.

        Returns the static python float ``grad_size_bits`` unchanged when
        ``bits is None`` — every consumer then traces the exact same
        constant-folded expression as before the bits leaf existed, which
        is what keeps ``bits=None`` problems byte-identical.  With a bits
        leaf the result is an array broadcast to ``rank``.
        """
        if self.bits is None:
            return self.grad_size_bits
        return self.grad_size_bits * _bcast_like(self.bits, rank) / 32.0

    def tx_time(self, power: jax.Array) -> jax.Array:
        """Transmission time T_ik(P) = S_i / r_ik(P)  (eq. 1, bit-scaled).

        A rank-2 ``bits`` table lifts the result to ``[N, K]`` even for a
        rank-1 power (per-round payloads at a fixed transmit power) —
        the same highest-rank rule every other leaf follows.
        """
        r = jnp.maximum(self.rate(power), 1e-30)
        rank = r.ndim if self.bits is None else max(r.ndim, self.bits.ndim)
        return self.payload_bits(rank) / _bcast_like(r, rank)

    def compute_energy(self) -> jax.Array:
        """E^c_i = kappa C_i |D_i| gamma_i^2  (eq. 5)."""
        return self.kappa * self.cycles_per_sample * self.dataset_size * jnp.square(self.cpu_hz)

    def upload_energy(self, power: jax.Array) -> jax.Array:
        """E^u_ik = P T_ik(P)."""
        t = self.tx_time(power)
        p = power if power.ndim >= t.ndim else power[:, None]
        return p * t

    def round_energy(self, power: jax.Array) -> jax.Array:
        """E_ik = E^c_i + E^u_ik  (eq. 6)."""
        eu = self.upload_energy(power)
        ec = self.compute_energy()
        if eu.ndim > ec.ndim:
            ec = ec[:, None]
        return ec + eu

    def p_min(self, a: jax.Array) -> jax.Array:
        """Minimum power meeting the time constraint (7c) at probability a.

        P^min_ik = (2^{a S / (B_i tau)} - 1) / path_gain  — below this the
        expected transmission time a*T exceeds tau^th.

        A 1-d ``a`` on a fading ([N, K]) problem broadcasts across rounds
        (same probability, each round's channel), exactly like ``rate``.
        """
        pg = self._pg(a)
        rank = max(a.ndim, pg.ndim)
        if self.bits is not None:
            rank = max(rank, self.bits.ndim)
        av = _bcast_like(a, rank)
        pgv = _bcast_like(pg, rank)
        bw = _bcast_like(self.bandwidth_hz, rank)
        exponent = av * self.payload_bits(rank) / (bw * self.tau_th)
        # exp2 overflows fast; clamp exponent so infeasible entries give a
        # huge-but-finite P^min (> p_max), which downstream logic treats as
        # "infeasible at this a" rather than producing NaNs.
        exponent = jnp.minimum(exponent, 120.0)
        num = jnp.expm1(exponent * LN2)
        # zero/NaN gain (deep fade to zero, corrupted channel): P^min = inf
        # is the infeasible-device gate; the unguarded num / pg emits NaN
        # at a = 0 (0 / 0) and poisons every downstream update
        return jnp.where(pgv > 0, num / jnp.where(pgv > 0, pgv, 1.0),
                         jnp.inf)

    def objective(self, a: jax.Array) -> jax.Array:
        """Weighted sum of selection probabilities (7a) for one round."""
        w = self.weights if a.ndim == 1 else self.weights[:, None]
        return jnp.sum(a * w)

    def constraints_satisfied(self, a: jax.Array, power: jax.Array,
                              rtol: float = 1e-4) -> jax.Array:
        """Boolean feasibility of (7b)-(7e) per element (with tolerance).

        ``a`` and ``power`` may be ``[N]`` or ``[N, K]`` independently;
        1-d operands broadcast across the fading rounds (module
        docstring contract) and the result takes the highest rank.
        """
        t = self.tx_time(power)
        rank = max(a.ndim, power.ndim, t.ndim)
        av = _bcast_like(a, rank)
        pv = _bcast_like(power, rank)
        tv = _bcast_like(t, rank)
        eu = pv * tv                        # E^u = P T_ik(P), as upload_energy
        energy_ok = av * (eu + _bcast_like(self.compute_energy(), rank)) \
            <= _bcast_like(self.energy_budget_j, rank) * (1 + rtol) + 1e-12
        time_ok = av * tv <= self.tau_th * (1 + rtol)
        p_ok = (pv >= -1e-12) & (pv <= self.p_max * (1 + rtol))
        a_ok = (av >= -1e-12) & (av <= 1 + rtol)
        return energy_ok & time_ok & p_ok & a_ok

    # ------------------------------------------------ boundary hardening

    def health_mask(self, xp=jnp) -> jax.Array:
        """Per-device boolean mask, True where every field is well-formed.

        A device is *unhealthy* when any of its constraint data is
        non-finite, when a strictly-positive quantity (distance,
        bandwidth, fading gain, dataset size, CPU parameters) is <= 0, or
        when a non-negative quantity (energy budget, weight,
        interference) is negative.  Works on single-instance ``[N]``
        leaves and on batched ``[B, N]`` leaves alike (per-round fading /
        interference reduce over the trailing round axis: one bad round
        marks the device — device granularity, see docs/robustness.md).

        ``xp=np`` evaluates on the host (the serving submit path checks
        every request without a device round-trip); ``xp=jnp`` is
        jit-compatible.
        """
        def finite(x):
            return xp.isfinite(xp.asarray(x))

        positive = ("distance_m", "bandwidth_hz", "dataset_size",
                    "cycles_per_sample", "cpu_hz")
        nonneg = ("energy_budget_j", "weights")
        ok = None
        for name in positive + nonneg:
            x = xp.asarray(getattr(self, name))
            good = finite(x) & (x > 0 if name in positive else x >= 0)
            ok = good if ok is None else ok & good
        rank = xp.asarray(self.distance_m).ndim
        if self.fading is not None:
            f = xp.asarray(self.fading)
            f_ok = finite(f) & (f > 0)
            if f.ndim > rank:
                f_ok = f_ok.all(axis=-1)
            ok = ok & f_ok
        if self.interference is not None:
            iv = xp.asarray(self.interference)
            i_ok = finite(iv) & (iv >= 0)
            if iv.ndim > rank:
                i_ok = i_ok.all(axis=-1)
            ok = ok & i_ok
        if self.bits is not None:
            bv = xp.asarray(self.bits)
            b_ok = finite(bv) & (bv > 0)
            if bv.ndim > rank:
                b_ok = b_ok.all(axis=-1)
            ok = ok & b_ok
        return ok

    def sanitize(self, health: Optional[jax.Array] = None
                 ) -> tuple["WirelessFLProblem", jax.Array]:
        """Replace unhealthy device rows with :data:`NEUTRAL_FILLS`.

        Returns ``(problem, health)``.  Sanitized devices self-deselect
        in every solver (zero energy budget => a* = 0, P* = 0) instead of
        poisoning the fused while-loop with NaN/Inf; healthy rows pass
        through bit-for-bit (``where`` with an all-True mask is the
        identity).  ``health`` defaults to :meth:`health_mask`.
        """
        if health is None:
            health = self.health_mask()
        health = jnp.asarray(health, bool)
        repl = {}
        for name, fill in NEUTRAL_FILLS.items():
            x = getattr(self, name)
            repl[name] = jnp.where(health, x, jnp.asarray(fill, x.dtype))
        rank = self.distance_m.ndim
        if self.fading is not None:
            h = health[..., None] if self.fading.ndim > rank else health
            repl["fading"] = jnp.where(h, self.fading, _FADING_FILL)
        if self.interference is not None:
            h = (health[..., None] if self.interference.ndim > rank
                 else health)
            repl["interference"] = jnp.where(h, self.interference,
                                             _INTERFERENCE_FILL)
        if self.bits is not None:
            h = health[..., None] if self.bits.ndim > rank else health
            repl["bits"] = jnp.where(h, self.bits, _BITS_FILL)
        return dataclasses.replace(self, **repl), health

    def validate(self) -> None:
        """Raise ``ValueError`` naming the unhealthy devices, if any.

        The strict counterpart of :meth:`sanitize` for callers that want
        malformed input rejected rather than degraded around.
        """
        health = np.asarray(self.health_mask(xp=np))
        if not health.all():
            bad = np.flatnonzero(~health.reshape(-1))
            raise ValueError(
                f"{bad.size} device slot(s) carry non-finite or "
                f"out-of-domain constraint data (flat indices "
                f"{bad[:8].tolist()}{'...' if bad.size > 8 else ''}); "
                "sanitize() degrades them to self-deselecting no-ops")


def _bcast_like(x: jax.Array, rank: int) -> jax.Array:
    """Broadcast a per-device ``[N]`` vector to ``[N, 1]`` when the
    surrounding expression is per-round ``[N, K]`` (rank 2)."""
    return x if x.ndim >= rank else x[:, None]


def sample_problem(rng: np.random.Generator | int,
                   n_devices: int = 100,
                   *,
                   area_m: float = 1000.0,
                   total_bandwidth_hz: float = 10e6,
                   tau_th: float = 0.08,
                   p_max: float = 1.0,
                   grad_size_bits: float = GRAD_SIZE_BITS_FP32,
                   n_rounds: int = 1,
                   energy_budget_range: tuple[float, float] = (1e-3, 100.0),
                   dataset_total: int = 60_000,
                   dirichlet_sizes: Optional[np.ndarray] = None,
                   with_fading: bool = False) -> WirelessFLProblem:
    """Draw a random scenario matching the paper's simulation setup (Sec V-A).

    100 devices uniform in 1 km^2, server at the centre, B = 10 MHz shared
    equally, sigma^2 = 1e-12, per-round energy budgets log-uniform in
    [1e-3, 100] J.
    """
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    xy = rng.uniform(0.0, area_m, size=(n_devices, 2))
    centre = np.array([area_m / 2, area_m / 2])
    d = np.maximum(np.linalg.norm(xy - centre, axis=1), 1.0)

    if dirichlet_sizes is not None:
        sizes = np.asarray(dirichlet_sizes, dtype=np.float64)
    else:
        props = rng.dirichlet(np.full(n_devices, 2.0))
        sizes = np.maximum(np.round(props * dataset_total), 10.0)

    lo, hi = energy_budget_range
    budgets = np.exp(rng.uniform(np.log(lo), np.log(hi), size=n_devices))

    fading = None
    if with_fading:
        # Rayleigh block fading per round (beyond-paper option).
        fading = rng.exponential(1.0, size=(n_devices, n_rounds))

    return WirelessFLProblem(
        distance_m=jnp.asarray(d, jnp.float32),
        bandwidth_hz=jnp.full((n_devices,), total_bandwidth_hz / n_devices, jnp.float32),
        energy_budget_j=jnp.asarray(budgets, jnp.float32),
        dataset_size=jnp.asarray(sizes, jnp.float32),
        cycles_per_sample=jnp.asarray(rng.uniform(1e4, 5e4, n_devices), jnp.float32),
        cpu_hz=jnp.asarray(rng.uniform(0.5e9, 2e9, n_devices), jnp.float32),
        weights=jnp.asarray(sizes / sizes.sum(), jnp.float32),
        fading=None if fading is None else jnp.asarray(fading, jnp.float32),
        grad_size_bits=float(grad_size_bits),
        noise_power=1e-12,
        p_max=float(p_max),
        tau_th=float(tau_th),
        kappa=1e-28,
        n_rounds=int(n_rounds),
    )
