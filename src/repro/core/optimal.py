"""Beyond-paper: the *global* optimum of problem (7) by monotone bisection.

Problem (7) is separable per (i, k).  For one element, a is feasible iff
there exists P in [P^min(a), P^max] with a (P T(P) + E^c) <= E^max and
a T(P) <= tau.  The energy-minimising feasible power is P = P^min(a)
(the fractional objective is increasing in P, see power.py), for which
T = tau / a exactly, so feasibility of a reduces to

    F(a):   P^min(a) <= P^max     and     tau * P^min(a) + a E^c <= E^max.

Both terms are strictly increasing in a (P^min is exp-increasing), so the
feasible set is an interval [0, a*] and bisection finds the global optimum
a* exactly.  This dominates the paper's Algorithm 2 (which is a local
heuristic whose answer depends on its initialisation); EXPERIMENTS.md
§Repro quantifies the gap.

``solve_joint_optimal`` returns the same JointSolution structure so the
FL runtime can swap solvers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.alternating import JointSolution, _solution_shape
from repro.core.problem import WirelessFLProblem, _bcast_like


def _feasible(problem: WirelessFLProblem, a: jax.Array) -> jax.Array:
    """F(a) above, elementwise; a=0 is always feasible.

    Ranks follow the ``problem.py`` contract: ``p_min`` takes the path
    gain's rank, so every 1-d operand (including a 1-d ``a`` on a fading
    problem) is broadcast up to it.
    """
    p_min = jnp.clip(problem.p_min(a), 0.0, None)
    rank = max(a.ndim, p_min.ndim)
    av = _bcast_like(a, rank)
    ec = _bcast_like(problem.compute_energy(), rank)
    emax = _bcast_like(problem.energy_budget_j, rank)
    power_ok = p_min <= problem.p_max * (1 + 1e-9)
    energy_ok = problem.tau_th * p_min + av * ec <= emax * (1 + 1e-9)
    return (power_ok & energy_ok) | (av <= 0)


def solve_joint_optimal(problem: WirelessFLProblem,
                        *,
                        n_bisect: int = 60,
                        per_round: bool = True) -> JointSolution:
    """Exact per-element optimum of (7) via bisection on a (jit-friendly)."""
    shape = _solution_shape(problem, per_round)

    lo = jnp.zeros(shape)
    hi = jnp.ones(shape)
    # if a=1 feasible, take it outright
    feas1 = _feasible(problem, hi)

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        ok = _feasible(problem, mid)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_bisect, body, (lo, hi))
    a = jnp.where(feas1, 1.0, lo)
    power = jnp.clip(problem.p_min(a), 0.0, problem.p_max)
    return JointSolution(a=a, power=power, objective=problem.objective(a),
                         n_iters=jnp.int32(n_bisect),
                         converged=jnp.asarray(True))
