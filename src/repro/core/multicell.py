"""Multi-cell metro control plane: C coupled cells, one fused solve per tick.

The paper solves a single cell.  A metro deployment runs *C* cells whose
per-device problems are coupled two ways (docs/multicell.md):

* **inter-cell interference** — a device transmitting in cell c' raises
  the noise floor at cell c's base station.  With an aggregate coupling
  gain ``G[c, c']`` (path loss x spectral-overlap factor between the two
  cells, zero diagonal), the interference power received at BS c is::

      I_c = sum_{c' != c} G[c, c'] * sum_i a_{c'i} P_{c'i}

  (``a P`` is the *expected* transmit power of a probabilistically
  selected device).  ``I_c`` enters every SINR in cell c through the
  ``WirelessFLProblem.interference`` leaf: sigma^2 -> sigma^2 + I_c.
* **a shared backhaul budget** — all C cells upload through one metro
  aggregation link of ``backhaul_bits`` capacity per round, constraining
  the expected traffic ``sum_{c,i} a_{ci} S <= B``.

Both couplings are resolved by a **dual-decomposition outer loop**
(:func:`solve_coupled`): fix the interference estimate ``I`` and the
backhaul price ``mu``, run the existing fused flat solver
(``solve_joint_batch(method="fused")``) over the *union* (cell, device)
element set — one convergence-masked while-loop reusing its chunking and
element-axis ``NamedSharding`` — then update ``(I, mu)`` from the new
solution and repeat until the coupled-KKT residual converges.  The inner
solve is the only accelerator work; the outer updates are O(C N) numpy.

The backhaul price step is *exact* (a continuous knapsack, not a
subgradient step): given the per-element caps ``a*`` from the inner
solve, the budget-constrained selection maximising ``sum w a`` fills
devices in decreasing weight order with one fractional marginal device,
whose weight density is the optimal price ``mu``.  Complementary
slackness therefore holds exactly at every outer iteration (pinned by
``tests/test_multicell.py``).

Identity guarantee: with an all-zero coupling matrix and no backhaul
budget, the zero interference estimate is *elided* (the problem keeps
``interference=None``), so the one outer iteration runs byte-for-byte
the same compiled program as the uncoupled
``solve_joint_batch(cells, method="fused")`` — bitwise-identical
solutions, converged after a single outer step.

Serving: ``FleetControlService.solve_coupled`` batches a whole metro
tick through this loop and warm-starts ``(I, mu)`` (and the element warm
start) from the previous tick via :class:`CoupledDuals` /
``MultiCellSolution.resume`` — on a coherent channel the outer loop then
collapses to one or two iterations (``multicell_solver`` benchmarks).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alternating import WarmStart, solve_joint_fused
from repro.core.batch import (
    BatchSolution,
    ProblemBatch,
    pad_batch,
    solve_joint_batch,
    stack_problems,
)
from repro.core.problem import WirelessFLProblem


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MultiCellProblem:
    """C per-cell problem (7) instances plus their metro-level coupling.

    ``cells`` stacks the per-cell :class:`WirelessFLProblem` leaves
    (``[C, N_max]``, fading ``[C, N_max, K]``); ``coupling[c, c']`` is
    the aggregate interference gain from cell c' transmissions into cell
    c's base-station receiver (zero diagonal — own-cell traffic is
    orthogonal OFDMA, not interference); ``backhaul_bits`` is the shared
    per-round metro uplink budget in bits (``None`` = unconstrained).
    """

    cells: ProblemBatch
    coupling: jax.Array      # [C, C], >= 0, zero diagonal
    backhaul_bits: Optional[float] = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def n_cells(self) -> int:
        return self.cells.batch_size


def make_multicell(problems: Sequence[WirelessFLProblem] | ProblemBatch,
                   coupling: np.ndarray | jax.Array,
                   *, backhaul_bits: Optional[float] = None
                   ) -> MultiCellProblem:
    """Validate and assemble a :class:`MultiCellProblem`.

    ``problems`` is either per-cell instances (stacked here) or an
    already-stacked :class:`ProblemBatch`; ``coupling`` must be a
    ``[C, C]`` non-negative matrix with a zero diagonal.
    """
    cells = problems if isinstance(problems, ProblemBatch) \
        else stack_problems(list(problems))
    g = np.asarray(coupling, np.float64)
    c = cells.batch_size
    if g.shape != (c, c):
        raise ValueError(f"coupling must be [{c}, {c}] for {c} cells, "
                         f"got {g.shape}")
    if not np.isfinite(g).all():
        raise ValueError("coupling gains must be finite — a NaN/Inf entry "
                         "would poison every cell's interference estimate")
    if np.any(g < 0):
        raise ValueError("coupling gains must be non-negative")
    if np.any(np.diag(g) != 0):
        raise ValueError(
            "coupling must have a zero diagonal — own-cell OFDMA traffic "
            "is orthogonal, not interference (model extra in-cell noise "
            "through noise_power instead)")
    if backhaul_bits is not None and backhaul_bits <= 0:
        raise ValueError(f"backhaul_bits must be positive, "
                         f"got {backhaul_bits}")
    return MultiCellProblem(cells=cells, coupling=jnp.asarray(g, jnp.float32),
                            backhaul_bits=None if backhaul_bits is None
                            else float(backhaul_bits))


def grid_coupling(n_cells: int, *, gain: float, alpha: float = 2.0,
                  spacing: float = 1.0) -> np.ndarray:
    """Square-grid coupling matrix: cells on a ceil(sqrt(C)) grid, gain
    ``gain / dist^alpha`` between distinct cells (``dist`` in units of
    ``spacing``), zero diagonal.  ``gain`` is the nearest-neighbour
    coupling; diagonal neighbours get ``gain / 2^(alpha/2)`` and so on.
    """
    side = int(np.ceil(np.sqrt(n_cells)))
    xy = np.stack(np.divmod(np.arange(n_cells), side), axis=1) * spacing
    d = np.linalg.norm(xy[:, None, :] - xy[None, :, :], axis=-1)
    with np.errstate(divide="ignore"):
        g = gain * spacing ** alpha / np.maximum(d, 1e-30) ** alpha
    np.fill_diagonal(g, 0.0)
    return g


def pad_metro(mc: MultiCellProblem, *, n_cells: Optional[int] = None,
              n_max: Optional[int] = None) -> MultiCellProblem:
    """Pad a metro to fixed ``(n_cells, n_max)`` slot shapes.

    The serving path quantises metro shapes into buckets so jit compiles
    once per bucket (exactly like :func:`repro.core.batch.pad_batch`,
    which this wraps).  Padded cells get zero coupling rows/columns and
    the standard padded-device leaves (zero weights and energy budgets),
    so they select nothing, radiate nothing, and add no backhaul load.
    """
    cells = pad_batch(mc.cells, batch_size=n_cells, n_max=n_max)
    c0, c1 = mc.n_cells, cells.batch_size
    if c1 == c0 and cells is mc.cells:
        return mc
    g = np.zeros((c1, c1), np.float32)
    g[:c0, :c0] = np.asarray(mc.coupling)
    return MultiCellProblem(cells=cells, coupling=jnp.asarray(g),
                            backhaul_bits=mc.backhaul_bits)


class CoupledDuals(NamedTuple):
    """Warm-start state carried across metro ticks (``.resume``)."""

    interference: np.ndarray          # [C] (or [C, K]) last I estimate, W
    mu: np.ndarray                    # scalar (or [K]) backhaul price
    warm: Optional[WarmStart] = None  # element warm start for the inner solve


class MultiCellSolution(NamedTuple):
    """Converged coupled solve: the union solution plus the dual state."""

    batch: BatchSolution      # per-cell (a*, P*), padded [C, N_max(, K)]
    interference: np.ndarray  # [C] or [C, K] consistent with batch
    mu: np.ndarray            # scalar or [K] backhaul price (weight / unit a)
    backhaul_load: np.ndarray  # scalar or [K] expected metro uplink bits
    outer_iters: int          # dual-decomposition iterations run
    residual: float           # final coupled-KKT residual
    converged: bool           # residual <= outer_tol within the budget
    # True when the outer loop ran out of iterations: the returned state
    # is then the *best-residual* iterate seen (best-feasible-so-far),
    # not the last step's — see docs/robustness.md
    hit_iter_cap: bool = False

    @property
    def resume(self) -> CoupledDuals:
        """Dual/warm state seeding the next tick's :func:`solve_coupled`."""
        return CoupledDuals(interference=self.interference, mu=self.mu,
                            warm=WarmStart(a=self.batch.a,
                                           power=self.batch.power))


def cell_interference(coupling: np.ndarray, a: np.ndarray,
                      power: np.ndarray) -> np.ndarray:
    """I_c = sum_{c'} G[c, c'] sum_i a_{c'i} P_{c'i} — the interference
    power each BS receives given the fleet's expected transmit powers.

    ``a``/``power`` are ``[C, N]`` or ``[C, N, K]`` (padded slots carry
    ``a = 0`` and drop out); returns ``[C]`` or ``[C, K]``.
    """
    tx = np.asarray(a, np.float64) * np.asarray(power, np.float64)
    per_cell = tx.sum(axis=1)                  # [C] or [C, K]
    return np.asarray(coupling, np.float64) @ per_cell


def _knapsack_round(caps: np.ndarray, w: np.ndarray, s_bits: float,
                    budget: float) -> tuple[np.ndarray, float, float]:
    """Exact budget projection for one round: maximise ``sum w a`` over
    ``0 <= a <= caps`` s.t. ``sum a * s_bits <= budget``.

    Continuous knapsack with uniform per-unit cost: fill by decreasing
    weight, one fractional marginal element.  Returns ``(a, mu, load)``
    where ``mu`` is the marginal element's weight — the exact dual price
    of the budget constraint (0 when it does not bind), so
    ``mu * (load - budget) == 0`` holds by construction.
    """
    caps = np.asarray(caps, np.float64).ravel()
    w = np.asarray(w, np.float64).ravel()
    total = caps.sum() * s_bits
    if total <= budget:
        return caps, 0.0, total
    order = np.argsort(-w, kind="stable")
    bits = caps[order] * s_bits
    csum = np.cumsum(bits)
    j = int(np.searchsorted(csum, budget, side="left"))
    a = np.zeros_like(caps)
    a[order[:j]] = caps[order[:j]]
    spent = csum[j - 1] if j > 0 else 0.0
    a[order[j]] = (budget - spent) / s_bits
    return a, float(w[order[j]]), float(budget)


def _backhaul_project(a_cap: np.ndarray, w: np.ndarray, s_bits: float,
                      budget: Optional[float]
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Apply the knapsack per round.  ``a_cap`` is ``[C, N]`` or
    ``[C, N, K]``; the budget applies to each round independently.
    Returns ``(a, mu, load)`` with ``mu``/``load`` scalar or ``[K]``.
    """
    a_cap = np.asarray(a_cap, np.float64)
    if budget is None:
        load = a_cap.sum(axis=(0, 1)) * s_bits    # scalar-0d or [K]
        return a_cap, np.zeros_like(load), load
    if a_cap.ndim == 2:
        a, mu, load = _knapsack_round(a_cap, w, s_bits, budget)
        return a.reshape(a_cap.shape), np.float64(mu), np.float64(load)
    k_rounds = a_cap.shape[-1]
    a = np.empty_like(a_cap)
    mu = np.zeros(k_rounds)
    load = np.zeros(k_rounds)
    for k in range(k_rounds):
        ak, mu[k], load[k] = _knapsack_round(a_cap[:, :, k], w, s_bits,
                                             budget)
        a[:, :, k] = ak.reshape(a_cap.shape[:2])
    return a, mu, load


def _with_interference(cells: ProblemBatch,
                       interference: np.ndarray) -> ProblemBatch:
    """``cells`` with per-cell interference ``[C]``/``[C, K]`` broadcast
    to every device slot.  An all-zero estimate is *elided* (the problem
    keeps its original ``interference`` leaf — ``None`` for a plain
    metro), so the zero-coupling path compiles and runs exactly the
    uncoupled program (the bitwise-identity guarantee)."""
    interference = np.asarray(interference)
    if not interference.any():
        return cells
    c, n_max = cells.batch_size, cells.n_max
    if interference.ndim == 1:
        arr = np.broadcast_to(interference[:, None], (c, n_max))
    else:
        arr = np.broadcast_to(interference[:, None, :],
                              (c, n_max, interference.shape[-1]))
    base = cells.problem.interference
    if base is not None:                       # exogenous interference adds
        arr = arr + np.asarray(base)
    prob = dataclasses.replace(cells.problem,
                               interference=jnp.asarray(arr, jnp.float32))
    return dataclasses.replace(cells, problem=prob)


def _relative_delta(old: np.ndarray, new: np.ndarray) -> float:
    scale = max(float(np.max(np.abs(old), initial=0.0)),
                float(np.max(np.abs(new), initial=0.0)), 1e-30)
    return float(np.max(np.abs(new - old), initial=0.0)) / scale


def _masked_weights(cells: ProblemBatch) -> np.ndarray:
    w = np.asarray(cells.problem.weights, np.float64)
    return np.where(np.asarray(cells.mask), w, 0.0)


def solve_coupled(mc: MultiCellProblem,
                  *,
                  outer_iters: int = 25,
                  outer_tol: float = 1e-3,
                  damping: float = 0.5,
                  method: str = "fused",
                  power_solver: Optional[str] = None,
                  eps: float = 1e-7,
                  max_iters: int = 50,
                  chunk_elements: Optional[int] = None,
                  mesh: Optional[jax.sharding.Mesh] = None,
                  shard: bool = True,
                  warm_start: bool = True,
                  sanitize: bool = False,
                  init: Optional[CoupledDuals] = None) -> MultiCellSolution:
    """Dual-decomposition solve of a coupled metro tick.

    Each outer iteration (host python; the module docstring derives it):

    1. **inner solve** — fix the interference estimate ``I``; solve the
       union (cell, device) element set in ONE fused flat call,
       ``solve_joint_batch(cells + I, method="fused")``, inheriting its
       ``chunk_elements`` bound and element-axis sharding.  ``I`` enters
       through the ``interference`` leaf only — no solver change.
    2. **backhaul price** — project the per-element caps ``a*`` onto the
       shared budget with the exact knapsack dual (`mu` = marginal
       weight; complementary slackness exact).
    3. **interference update** — recompute ``I`` from the projected
       solution and relax with ``damping`` (1.0 = undamped fixed point;
       smaller values damp the power <-> interference feedback on
       strongly coupled grids).

    Stops when the coupled-KKT residual — the max of the relative
    interference-fixed-point error and the relative price change — drops
    to ``outer_tol``, or after ``outer_iters``.  ``init`` (a
    :class:`CoupledDuals`, typically ``prev.resume``) warm-starts
    ``(I, mu)`` and the element iterates; shape-mismatched state is
    ignored (cold start) so fleet reconfigurations need no special
    casing.  Solutions are init-independent to solver tolerance; only
    outer/inner iteration counts change (the serving claim the
    ``multicell_solver`` bench gates).

    ``sanitize=True`` forwards to ``solve_joint_batch`` (unhealthy
    devices self-deselect).  If the loop exhausts ``outer_iters`` the
    returned solution is the **best-residual iterate seen** with
    ``hit_iter_cap=True`` — degraded but usable, never the last
    (possibly oscillating) step by accident; converged solves are
    bit-identical to the pre-flag behaviour.
    """
    cells = mc.cells
    if damping <= 0.0 or damping > 1.0:
        raise ValueError(f"damping must be in (0, 1], got {damping}")
    if outer_iters < 1:
        raise ValueError(f"outer_iters must be >= 1, got {outer_iters}")
    coupling = np.asarray(mc.coupling, np.float64)
    per_round = cells.problem.fading is not None
    k_rounds = cells.problem.fading.shape[-1] if per_round else None
    i_shape = (mc.n_cells, k_rounds) if per_round else (mc.n_cells,)
    s_bits = cells.problem.grad_size_bits
    w = _masked_weights(cells)

    interference = np.zeros(i_shape)
    mu = np.zeros(k_rounds) if per_round else np.float64(0.0)
    warm = None
    if init is not None:
        if np.shape(init.interference) == i_shape:
            interference = np.asarray(init.interference, np.float64)
        if np.shape(init.mu) == np.shape(mu):
            mu = np.asarray(init.mu, np.float64)
        if warm_start and init.warm is not None:
            sol_shape = i_shape[:1] + (cells.n_max,) + i_shape[1:]
            if tuple(init.warm.a.shape) == sol_shape:
                warm = init.warm

    bs = None
    a_proj: np.ndarray | jax.Array = jnp.zeros(0)
    load = np.zeros(k_rounds) if per_round else np.float64(0.0)
    residual, converged, t = float("inf"), False, 0
    best = None   # best-residual iterate: (residual, bs, a_proj, mu, load, I)
    for t in range(1, outer_iters + 1):  # noqa: B007 - read after the loop
        bs = solve_joint_batch(
            _with_interference(cells, interference), method=method,
            power_solver=power_solver, eps=eps, max_iters=max_iters,
            chunk_elements=chunk_elements, mesh=mesh, shard=shard,
            sanitize=sanitize,
            init=warm if warm_start else None)
        if mc.backhaul_bits is None:
            # no projection: keep the solver's arrays untouched so the
            # zero-coupling path stays bitwise identical to the
            # uncoupled solve
            a_proj = bs.a
            mu_new = np.zeros_like(mu)
            load = np.asarray(bs.a, np.float64).sum(axis=(0, 1)) * s_bits
            i_src = np.asarray(bs.a, np.float64)
        else:
            a_proj, mu_new, load = _backhaul_project(
                np.asarray(bs.a), w, s_bits, mc.backhaul_bits)
            i_src = a_proj
        i_new = cell_interference(coupling, i_src, np.asarray(bs.power))
        residual = max(_relative_delta(interference, i_new),
                       _relative_delta(np.atleast_1d(mu),
                                       np.atleast_1d(mu_new)))
        converged = residual <= outer_tol
        if best is None or residual < best[0]:
            best = (residual, bs, a_proj, mu_new, load, i_new)
        interference = i_new if converged or damping >= 1.0 \
            else interference + damping * (i_new - interference)
        mu = mu_new
        if warm_start:
            warm = bs.resume
        if converged:
            break

    hit_iter_cap = not converged
    if hit_iter_cap:
        # iteration cap: hand back the best-residual iterate seen, not
        # whatever the last (possibly oscillating) step produced
        residual, bs, a_proj, mu, load, interference = best

    if mc.backhaul_bits is None:
        final = bs
    else:
        a_arr = jnp.asarray(a_proj, jnp.float32)
        w_b = w if a_arr.ndim == 2 else w[:, :, None]
        objective = jnp.asarray(
            np.sum(np.asarray(a_proj, np.float64) * w_b, axis=tuple(
                range(1, np.ndim(a_proj)))), jnp.float32)
        final = bs._replace(a=a_arr, objective=objective)
    return MultiCellSolution(batch=final, interference=interference, mu=mu,
                             backhaul_load=load, outer_iters=t,
                             residual=residual, converged=converged,
                             hit_iter_cap=hit_iter_cap)


@functools.lru_cache(maxsize=32)
def _loop_cell_solve(power_solver: str, eps: float, max_iters: int):
    """Jitted per-cell solve for :func:`solve_coupled_loop`, cached per
    solver configuration so repeated calls reuse one executable per
    problem structure."""
    return jax.jit(functools.partial(
        solve_joint_fused, power_solver=power_solver, eps=eps,
        max_iters=max_iters, shard=False))


def solve_coupled_loop(mc: MultiCellProblem,
                       *,
                       outer_iters: int = 25,
                       outer_tol: float = 1e-3,
                       damping: float = 0.5,
                       power_solver: Optional[str] = None,
                       eps: float = 1e-7,
                       max_iters: int = 50) -> MultiCellSolution:
    """Reference implementation: the same dual decomposition with a
    *python loop of per-cell* ``solve_joint_fused`` calls per outer
    iteration instead of one union solve — C jit dispatches per step.

    Agreement oracle for the tests and the baseline the
    ``multicell_solver`` benchmark's compare.py floor measures
    :func:`solve_coupled` against (the issue's "per-cell loop with the
    fixed point in python").
    """
    cells = mc.cells
    if outer_iters < 1:
        raise ValueError(f"outer_iters must be >= 1, got {outer_iters}")
    power_solver = power_solver or "analytic"
    # jit the per-cell solve: bare ``solve_joint_fused`` dispatches its
    # while_loop eagerly, which recompiles per call — C x outer_iters
    # fresh LLVM modules per solve would exhaust the process map budget
    cell_solve = _loop_cell_solve(power_solver, eps, max_iters)
    problems = cells.unstack()
    coupling = np.asarray(mc.coupling, np.float64)
    per_round = cells.problem.fading is not None
    k_rounds = cells.problem.fading.shape[-1] if per_round else None
    i_shape = (mc.n_cells, k_rounds) if per_round else (mc.n_cells,)
    s_bits = cells.problem.grad_size_bits
    w = _masked_weights(cells)
    n_max = cells.n_max

    def pad(x, n):
        pad_width = [(0, n_max - n)] + [(0, 0)] * (x.ndim - 1)
        return np.pad(np.asarray(x, np.float64), pad_width)

    interference = np.zeros(i_shape)
    mu = np.zeros(k_rounds) if per_round else np.float64(0.0)
    a_pad = np.zeros(i_shape[:1] + (n_max,) + i_shape[1:])
    p_pad = np.zeros_like(a_pad)
    residual, converged, t = float("inf"), False, 0
    conv_all = True
    for t in range(1, outer_iters + 1):  # noqa: B007 - read after the loop
        sols = []
        for c, prob in enumerate(problems):
            i_c = interference[c]
            if np.any(i_c):
                shape = (prob.n_devices,) if not per_round \
                    else (prob.n_devices, k_rounds)
                prob = dataclasses.replace(
                    prob, interference=jnp.asarray(
                        np.broadcast_to(np.reshape(i_c, (1,) + i_c.shape),
                                        shape), jnp.float32))
            sols.append(cell_solve(prob))
        a_pad = np.stack([pad(s.a, p.n_devices)
                          for s, p in zip(sols, problems)])
        p_pad = np.stack([pad(s.power, p.n_devices)
                          for s, p in zip(sols, problems)])
        conv_all = all(bool(np.all(np.asarray(s.converged))) for s in sols)
        a_proj, mu_new, load = _backhaul_project(a_pad, w, s_bits,
                                                 mc.backhaul_bits)
        i_new = cell_interference(coupling, a_proj, p_pad)
        residual = max(_relative_delta(interference, i_new),
                       _relative_delta(np.atleast_1d(mu),
                                       np.atleast_1d(mu_new)))
        converged = residual <= outer_tol
        interference = i_new if converged or damping >= 1.0 \
            else interference + damping * (i_new - interference)
        mu = mu_new
        a_pad = a_proj
        if converged:
            break

    w_b = w if a_pad.ndim == 2 else w[:, :, None]
    batch = BatchSolution(
        a=jnp.asarray(a_pad, jnp.float32),
        power=jnp.asarray(p_pad, jnp.float32),
        objective=jnp.asarray(np.sum(a_pad * w_b, axis=tuple(
            range(1, a_pad.ndim))), jnp.float32),
        n_iters=jnp.asarray(t), converged=jnp.asarray(
            np.full(mc.n_cells, conv_all)),
        mask=cells.mask)
    return MultiCellSolution(batch=batch, interference=interference, mu=mu,
                             backhaul_load=load, outer_iters=t,
                             residual=residual, converged=converged,
                             hit_iter_cap=not converged)
