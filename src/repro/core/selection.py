"""Closed-form selection probabilities (eq. 13) given fixed powers.

With P fixed, problem (12) separates per (i, k) into a linear program in a
with box constraints, whose optimum saturates the tightest constraint:

    a*_ik = min( 1,
                 tau^th / T_ik(P_ik),                 # time constraint (7c)
                 E^max_i / (P_ik T_ik(P_ik) + E^c_i)  # energy constraint (7b)
               )

NOTE (paper erratum, DESIGN.md §1): the paper prints the middle term as
``tau^th / (S * T_ik)``; the extra S is dimensionally inconsistent with
(7c) and would violate the paper's own constraint.  The corrected form is
the default; ``faithful_eq13_typo=True`` reproduces the verbatim formula.

``selection_update_elements`` is the element-level form (raw arrays, any
common shape) shared by the fused flat solver and the Pallas kernel
oracle; ``optimal_selection`` is the :class:`WirelessFLProblem` shim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import WirelessFLProblem, _bcast_like


def selection_update_elements(power, tx_time, emax, ec, *, tau: float,
                              s_bits: float,
                              faithful_eq13_typo: bool = False) -> jax.Array:
    """a*_ik per eq. (13) on raw element arrays.

    ``tx_time`` is T_ik(P_ik) evaluated at ``power`` (callers already have
    it from the power update; passing it avoids a second rate evaluation).
    """
    time_term = tau / jnp.maximum(tx_time, 1e-30)
    if faithful_eq13_typo:
        time_term = time_term / s_bits
    energy_term = emax / jnp.maximum(power * tx_time + ec, 1e-30)
    a = jnp.minimum(jnp.minimum(1.0, time_term), energy_term)
    # P = 0 (e.g. a collapsed to 0 earlier) transmits nothing: T = inf.
    a = jnp.where(power > 0, a, 0.0)
    return jnp.clip(a, 0.0, 1.0)


def optimal_selection(problem: WirelessFLProblem,
                      power: jax.Array,
                      *,
                      faithful_eq13_typo: bool = False) -> jax.Array:
    """a*_ik per eq. (13). ``power`` has shape [N] or [N, K]; a 1-d
    ``power`` on a fading problem broadcasts across rounds (the
    ``problem.py`` contract) and yields an [N, K] result."""
    t = problem.tx_time(power)
    rank = max(power.ndim, t.ndim)
    ec = _bcast_like(problem.compute_energy(), rank)
    emax = _bcast_like(problem.energy_budget_j, rank)
    return selection_update_elements(_bcast_like(power, rank), t, emax, ec,
                                     tau=problem.tau_th,
                                     s_bits=problem.payload_bits(rank),
                                     faithful_eq13_typo=faithful_eq13_typo)
