"""Client-selection strategies: the paper's approach + benchmark schedulers.

Every scheduler exposes:

    state = scheduler.precompute(problem)          # one-off solve
    draw  = scheduler.sample(state, key, k)        # per-round participation

returning a ``ParticipationDraw`` with the Bernoulli participation mask,
per-client transmit powers, and the aggregation weights alpha_i used by the
server update (eq. 4).  Schedulers differ in:

* **probabilistic** (ours, Alg. 2/3): a* from the joint solve; participate
  w.p. a*_ik at power P*_ik; alpha proportional to |D_i|.
* **deterministic**: the rounded (a* >= 0.5) binary version (paper Sec. V).
* **uniform** [McMahan et al.]: M clients uniformly at random, transmit at
  P^max; ignores the wireless/energy constraints.
* **equally_weighted** [Nishio & Yonetani]: binary selection, equal
  objective weights and equal aggregation weights.
* **greedy_channel**: per-round top-M devices by instantaneous channel
  gain at the minimum tau-feasible power — the channel-aware baseline
  every wireless-FL comparison fields (cf. Yang et al., energy-efficient
  FL over wireless networks).
* **lyapunov**: virtual-queue drift-plus-penalty scheduling in the
  spirit of Perazzone et al. (communication-efficient device scheduling
  via stochastic optimisation): a per-device energy-budget queue
  Q_i(k+1) = max(Q_i(k) + m_i E_ik - E^max_i, 0) throttles devices whose
  realised energy overshoots their per-round budget, and round k selects
  the devices whose utility V w_i outweighs the queue-weighted energy
  price Q_i(k) E_ik.

All schedulers are pure-JAX and jit/vmap friendly; the channel-aware pair
(greedy_channel, lyapunov) produce per-round ``[N, K]`` states on fading
problems, which both engines and the closed-loop pipeline
(``repro.fl.closed_loop``) consume round-by-round.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.alternating import (
    JointSolution,
    WarmStart,
    solve_joint,
    solve_joint_fused,
)
from repro.core.batch import BatchSolution, ProblemBatch, solve_joint_batch
from repro.core.optimal import solve_joint_optimal
from repro.core.problem import WirelessFLProblem


class ParticipationDraw(NamedTuple):
    mask: jax.Array         # [N] bool — who transmits this round
    power: jax.Array        # [N] transmit power for participants
    agg_weights: jax.Array  # [N] alpha_i for the server update (eq. 4)
    probs: jax.Array        # [N] the selection probabilities used


class SchedulerState(NamedTuple):
    a: jax.Array            # [N] or [N, K]
    power: jax.Array
    agg_weights: jax.Array  # [N]


def _round_slice(x: jax.Array, k) -> jax.Array:
    return x if x.ndim == 1 else x[:, k]


def _data_weights(problem: WirelessFLProblem) -> jax.Array:
    return problem.dataset_size / jnp.sum(problem.dataset_size)


@dataclasses.dataclass(frozen=True)
class ProbabilisticScheduler:
    """The paper's joint probabilistic selection + power allocation."""

    solver: str = "alternating"        # "alternating" (paper) | "fused" | "optimal" (ours)
    power_solver: str = "dinkelbach"   # "dinkelbach" (paper) | "analytic" (fast path)
    unbiased_aggregation: bool = False  # beyond-paper alpha_i / a_i correction
    faithful_eq13_typo: bool = False
    # joint bit/power/selection: menu of uplink widths, e.g. (4, 6, 8, 16,
    # 32) — fused solver only (docs/compression.md); None = fp32 payload
    bit_menu: Optional[tuple] = None

    def solve(self, problem: WirelessFLProblem,
              init: Optional[WarmStart] = None) -> JointSolution:
        """Run the configured joint solver.

        ``init`` (a previous ``JointSolution.resume``) warm-starts the
        iterative solvers — bit-identical results, fewer inner iterations
        on a drifted problem (see ``core.alternating``).  The exact
        "optimal" solver has no iteration to warm-start and rejects it.
        """
        if self.bit_menu is not None and self.solver != "fused":
            raise ValueError(
                f"bit_menu is implemented by the fused single-level solver "
                f"only; solver={self.solver!r} would silently ignore it")
        if self.solver == "optimal":
            if init is not None:
                raise ValueError("solver='optimal' computes the exact "
                                 "optimum directly; init would be ignored")
            return solve_joint_optimal(problem)
        if self.solver == "fused":
            # the fused single-level solver always uses the closed-form
            # (analytic) power update — it IS the Dinkelbach fixed point
            return solve_joint_fused(problem,
                                     faithful_eq13_typo=self.faithful_eq13_typo,
                                     init=init, bit_menu=self.bit_menu)
        return solve_joint(problem, power_solver=self.power_solver,
                           faithful_eq13_typo=self.faithful_eq13_typo,
                           init=init)

    def precompute(self, problem: WirelessFLProblem,
                   init: Optional[WarmStart] = None) -> SchedulerState:
        sol = self.solve(problem, init=init)
        return SchedulerState(a=sol.a, power=sol.power,
                              agg_weights=_data_weights(problem))

    def sample(self, state: SchedulerState, key: jax.Array, k: int = 0) -> ParticipationDraw:
        a = _round_slice(state.a, k)
        p = _round_slice(state.power, k)
        mask = jax.random.bernoulli(key, a)
        alpha = state.agg_weights
        if self.unbiased_aggregation:
            alpha = alpha / jnp.maximum(a, 1e-6)
        return ParticipationDraw(mask=mask, power=p, agg_weights=alpha, probs=a)

    def expected_participants(self, state: SchedulerState) -> jax.Array:
        a = state.a if state.a.ndim == 1 else state.a.mean(axis=1)
        return jnp.sum(a)

    # ---- batched (multi-scenario) path ---------------------------------
    def solve_batch(self, batch: ProblemBatch, **kw) -> BatchSolution:
        """One device-sharded solve for a whole ProblemBatch of scenarios.

        Keyword overrides win over the scheduler's configuration, so e.g.
        ``solve_batch(batch, method="kernel")`` reaches the Pallas fast
        path, and ``solve_batch(batch, init=prev.resume)`` warm-starts
        the iterative methods from a previous batch solution.  As with
        ``solve()``, the Algorithm-2 knobs (power solver, eq.-13 typo
        flag) only apply to the alternating method.
        """
        kw.setdefault("method", self.solver
                      if self.solver in ("optimal", "fused") else "alternating")
        if kw["method"] == "alternating":
            kw.setdefault("power_solver", self.power_solver)
        if kw["method"] in ("alternating", "fused", "fused_kernel"):
            kw.setdefault("faithful_eq13_typo", self.faithful_eq13_typo)
        if kw["method"] == "fused":
            kw.setdefault("bit_menu", self.bit_menu)
        return solve_joint_batch(batch, **kw)

    def precompute_batch(self, batch: ProblemBatch, **kw) -> SchedulerState:
        """Batched ``precompute``: every array gains a leading batch axis.

        Consume with ``sample_batch`` (or ``jax.vmap(self.sample)`` over
        split keys).  Padded device slots have a = 0, so they never
        participate, and aggregation weight 0.
        """
        sol = self.solve_batch(batch, **kw)
        masked_sizes = batch.problem.dataset_size * batch.mask
        alpha = masked_sizes / masked_sizes.sum(axis=1, keepdims=True)
        return SchedulerState(a=sol.a, power=sol.power, agg_weights=alpha)

    def sample_batch(self, state: SchedulerState, key: jax.Array,
                     k: int = 0) -> ParticipationDraw:
        """Per-instance independent participation draws, shape [B, N]."""
        keys = jax.random.split(key, state.a.shape[0])
        return jax.vmap(lambda s, kk: self.sample(s, kk, k))(state, keys)


def _top_m_binary(score: jax.Array, m: jax.Array) -> jax.Array:
    """Binary [N] mask selecting the ``m`` highest-scoring devices."""
    order = jnp.argsort(-score)
    ranks = jnp.argsort(order)
    return (ranks < m).astype(score.dtype)


def _round_preserving_count(a: jax.Array, per_round: bool = False) -> jax.Array:
    """Binarise probabilities keeping the expected participant count.

    The paper rounds a* "up or down" but also states the expected number of
    selected devices matches the probabilistic version — i.e. the
    ceil(sum a) highest-probability devices are selected (a plain 0.5
    threshold would select nobody here, since per-element a* rarely exceeds
    ~0.3 under the paper's wireless constants). See DESIGN.md §1.

    For a per-round ``[N, K]`` input the default keeps the paper's static
    reading (round 0's selection broadcast across rounds);
    ``per_round=True`` re-binarises each round's column independently —
    the drift-tracking variant the closed-loop pipeline uses.
    """
    def one_round(col: jax.Array) -> jax.Array:
        k = jnp.clip(jnp.round(jnp.sum(col)), 1, col.shape[0]).astype(jnp.int32)
        return _top_m_binary(col, k)

    if a.ndim == 1:
        return one_round(a)
    if per_round:
        return jax.vmap(one_round, in_axes=1, out_axes=1)(a)
    return jnp.broadcast_to(one_round(a[:, 0])[:, None], a.shape)


@dataclasses.dataclass(frozen=True)
class DeterministicScheduler:
    """Rounded binary version of the probabilistic solution (paper Sec. V),
    expected-count preserving.  ``per_round=True`` re-binarises every
    fading round independently (drift-tracking top-k) instead of
    broadcasting round 0's selection."""

    inner: ProbabilisticScheduler = ProbabilisticScheduler()
    per_round: bool = False

    def precompute(self, problem: WirelessFLProblem) -> SchedulerState:
        sol = self.inner.solve(problem)
        a_bin = _round_preserving_count(sol.a, per_round=self.per_round)
        return SchedulerState(a=a_bin, power=sol.power,
                              agg_weights=_data_weights(problem))

    def sample(self, state: SchedulerState, key: jax.Array, k: int = 0) -> ParticipationDraw:
        a = _round_slice(state.a, k)
        return ParticipationDraw(mask=a > 0, power=_round_slice(state.power, k),
                                 agg_weights=state.agg_weights, probs=a)


@dataclasses.dataclass(frozen=True)
class UniformScheduler:
    """M clients uniformly at random at P^max; constraint-oblivious [1]."""

    m: int = 10

    def precompute(self, problem: WirelessFLProblem) -> SchedulerState:
        n = problem.n_devices
        a = jnp.full((n,), self.m / n)
        p = jnp.full((n,), problem.p_max)
        return SchedulerState(a=a, power=p, agg_weights=_data_weights(problem))

    def sample(self, state: SchedulerState, key: jax.Array, k: int = 0) -> ParticipationDraw:
        n = state.a.shape[0]
        perm = jax.random.permutation(key, n)
        mask = jnp.zeros((n,), bool).at[perm[: self.m]].set(True)
        return ParticipationDraw(mask=mask, power=state.power,
                                 agg_weights=state.agg_weights, probs=state.a)


@dataclasses.dataclass(frozen=True)
class EquallyWeightedScheduler:
    """Binary selection with equal device weights, per [6] (Nishio &
    Yonetani): maximise the *count* of participants under the constraints;
    aggregation also equally weighted."""

    inner: ProbabilisticScheduler = ProbabilisticScheduler()

    def precompute(self, problem: WirelessFLProblem) -> SchedulerState:
        equal = dataclasses.replace(
            problem, weights=jnp.full_like(problem.weights,
                                           1.0 / problem.n_devices))
        sol = self.inner.solve(equal)
        a_bin = _round_preserving_count(sol.a)
        n_sel = jnp.maximum(jnp.sum(a_bin if a_bin.ndim == 1 else a_bin[:, 0]), 1.0)
        alpha = jnp.full_like(problem.weights, 1.0) / n_sel
        return SchedulerState(a=a_bin, power=sol.power, agg_weights=alpha)

    def sample(self, state: SchedulerState, key: jax.Array, k: int = 0) -> ParticipationDraw:
        a = _round_slice(state.a, k)
        return ParticipationDraw(mask=a > 0, power=_round_slice(state.power, k),
                                 agg_weights=state.agg_weights, probs=a)


def _tau_feasible_power(problem: WirelessFLProblem) -> jax.Array:
    """Minimum power transmitting within tau at full participation:
    clip(P^min(a=1), 0, P^max) — [N], or [N, K] on a fading problem
    (each round's channel).  Devices whose P^min(1) exceeds P^max are
    clamped (they violate tau; channel-aware selection avoids them)."""
    ones = jnp.ones((problem.n_devices,), jnp.float32)
    return jnp.clip(problem.p_min(ones), 0.0, problem.p_max)


@dataclasses.dataclass(frozen=True)
class GreedyChannelScheduler:
    """Channel-aware greedy: every round, the M devices with the best
    instantaneous channel (highest path gain) transmit at the minimum
    tau-feasible power.  The standard opportunistic baseline (cf. Yang et
    al., energy-efficient FL): it tracks the fading but ignores energy
    budgets and data weights."""

    m: int = 10

    def precompute(self, problem: WirelessFLProblem) -> SchedulerState:
        gain = problem.path_gain()                  # [N] or [N, K]
        power = _tau_feasible_power(problem)
        m = jnp.int32(min(self.m, problem.n_devices))
        if gain.ndim == 1:
            a = _top_m_binary(gain, m)
        else:
            a = jax.vmap(_top_m_binary, in_axes=(1, None),
                         out_axes=1)(gain, m)
        return SchedulerState(a=a.astype(jnp.float32), power=power,
                              agg_weights=_data_weights(problem))

    def sample(self, state: SchedulerState, key: jax.Array, k: int = 0) -> ParticipationDraw:
        a = _round_slice(state.a, k)
        return ParticipationDraw(mask=a > 0, power=_round_slice(state.power, k),
                                 agg_weights=state.agg_weights, probs=a)


@dataclasses.dataclass(frozen=True)
class LyapunovScheduler:
    """Virtual-queue drift-plus-penalty scheduler (cf. Perazzone et al.,
    arXiv:2201.07912).

    Each device carries an energy-budget virtual queue

        Q_i(k+1) = max(Q_i(k) + m_i(k) E_ik - E^max_i, 0),   Q_i(0) = 0,

    where ``E_ik`` is the device's round-k energy at the minimum
    tau-feasible power and ``E^max_i`` its per-round budget.  Round k
    greedily solves the drift-plus-penalty subproblem
    ``max sum_i (V w_i - Q_i(k) E_ik) m_i`` over binary masks: device i
    participates iff its utility ``V w_i`` outweighs the queue-weighted
    energy price ``Q_i(k) E_ik``.  Devices that overdraw their budget
    accumulate queue and are throttled, so long-run average energy per
    round approaches the budget — stochastic-constraint scheduling,
    where the paper's scheme enforces (7b) per round.

    ``v`` is the standard Lyapunov utility/backlog trade-off knob; the
    queue recursion is deterministic given the channel trajectory, so the
    whole schedule precomputes to a per-round binary ``[N, K]`` state.
    """

    v: float = 1.0
    n_rounds: Optional[int] = None    # static problems: schedule length

    def _energy_table(self, problem: WirelessFLProblem
                      ) -> tuple[jax.Array, jax.Array]:
        """(power, e_rounds [N, K]): per-round full-participation energy."""
        power = _tau_feasible_power(problem)
        e = problem.round_energy(power)           # [N] or [N, K]
        if e.ndim == 1:
            k = self.n_rounds if self.n_rounds else max(problem.n_rounds, 1)
            e = jnp.broadcast_to(e[:, None], (e.shape[0], k))
        return power, e

    def queue_trajectory(self, problem: WirelessFLProblem) -> jax.Array:
        """Virtual-queue path [K+1, N] (Q(0) = 0 first row) — diagnostics
        and test surface for the queue stability invariants."""
        _, e_rounds = self._energy_table(problem)
        _, qs = jax.lax.scan(self._step(problem), self._q0(problem),
                             e_rounds.T)
        return jnp.concatenate([self._q0(problem)[None], qs[0]], axis=0)

    def _q0(self, problem: WirelessFLProblem) -> jax.Array:
        return jnp.zeros((problem.n_devices,), jnp.float32)

    def _step(self, problem: WirelessFLProblem):
        w, emax = problem.weights, problem.energy_budget_j

        def body(q, e_k):
            sel = self.v * w > q * e_k
            q_new = jnp.maximum(q + jnp.where(sel, e_k, 0.0) - emax, 0.0)
            return q_new, (q_new, sel)
        return body

    def precompute(self, problem: WirelessFLProblem) -> SchedulerState:
        power, e_rounds = self._energy_table(problem)
        _, (_, sels) = jax.lax.scan(self._step(problem), self._q0(problem),
                                    e_rounds.T)       # sels [K, N]
        return SchedulerState(a=sels.T.astype(jnp.float32), power=power,
                              agg_weights=_data_weights(problem))

    def sample(self, state: SchedulerState, key: jax.Array, k: int = 0) -> ParticipationDraw:
        a = _round_slice(state.a, k)
        return ParticipationDraw(mask=a > 0, power=_round_slice(state.power, k),
                                 agg_weights=state.agg_weights, probs=a)


SCHEDULERS = {
    "probabilistic": ProbabilisticScheduler,
    "deterministic": DeterministicScheduler,
    "uniform": UniformScheduler,
    "equally_weighted": EquallyWeightedScheduler,
    "greedy_channel": GreedyChannelScheduler,
    "lyapunov": LyapunovScheduler,
}


def make_scheduler(name: str, **kwargs):
    return SCHEDULERS[name](**kwargs)
