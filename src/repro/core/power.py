"""Power allocation: Dinkelbach's method (Algorithm 1), vectorised.

The per-(i, k) fractional program (9)

    min_{P^min <= P <= P^max}   a S P / (B log2(1 + P * pg))

is solved for the *whole fleet at once*: the paper iterates devices one by
one on a CPU; on TPU we batch every (i, k) subproblem into element-wise
vector ops inside a single ``lax.while_loop`` with per-element convergence
masking.  This is the hardware adaptation described in DESIGN.md §5.

Closed-form inner step (setting d/dP of (11) to zero):

    P*(lambda) = lambda * B / (a S ln 2) - 1 / pg        (then clipped)

lambda update:  lambda_j = a S P* / (B log2(1 + P* pg)) = a P* T(P*) objective.

Because the ratio P / log(1+cP) is strictly increasing on P > 0, the true
minimiser is the *lower boundary* P = clip(P^min(a), 0, P^max); Dinkelbach
converges there through the clipping.  ``analytic_power`` exposes that
shortcut (bit-identical solution, ~30x fewer flops) as a beyond-paper
solver optimisation; tests assert both agree.

Every update is available in two layers:

* **element level** (``*_elements``): raw ``(a, pg, bw, ...)`` arrays of
  any common shape — the separable (instance, device, round) element set.
  These are the single source of truth for the closed forms; the fused
  flat solver (``core/alternating.py``), the batched engine
  (``core/batch.py``) and the Pallas kernel oracle all build on them.
* **problem level** (``dinkelbach_power`` / ``analytic_power``): the
  original :class:`WirelessFLProblem` API, now thin broadcast shims over
  the element level (bit-identical to the pre-refactor implementations).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.problem import LN2, WirelessFLProblem, _bcast_like

_A_FLOOR = 1e-12   # guards the a -> 0 division in P*(lambda)


class PowerSolution(NamedTuple):
    power: jax.Array        # P*_ik
    lam: jax.Array          # converged Dinkelbach lambda (= min energy E^u at a)
    n_iters: jax.Array      # scalar int32, iterations to fleet-wide convergence
    feasible: jax.Array     # bool, P^min(a) <= P^max elementwise


# -------------------------------------------------------- element level

def element_p_min(a, pg, bw, *, s_bits: float, tau: float) -> jax.Array:
    """P^min_ik = (2^{a S / (B tau)} - 1) / pg, exponent-clamped (eq. 7c).

    Mirrors ``WirelessFLProblem.p_min`` on raw element arrays.
    """
    exponent = jnp.minimum(a * s_bits / (bw * tau), 120.0)
    num = jnp.expm1(exponent * LN2)
    # zero/NaN gain (deep fade to zero, corrupted channel): P^min = inf is
    # the infeasible-device gate — the raw division emits 0 / 0 = NaN at
    # a = 0 and poisons the fused while-loop (docs/robustness.md)
    return jnp.where(pg > 0, num / jnp.where(pg > 0, pg, 1.0), jnp.inf)


def element_tx_time(power, pg, bw, *, s_bits: float) -> jax.Array:
    """T_ik(P) = S / r_ik(P) with r = B log2(1 + P pg)  (eq. 1)."""
    return s_bits / jnp.maximum(bw * jnp.log2(1.0 + power * pg), 1e-30)


def _element_lam(a, power, pg, bw, *, s_bits: float) -> jax.Array:
    """Objective (9a): a P T(P), defined 0 where a = 0 (rate(0) = 0)."""
    t = element_tx_time(power, pg, bw, s_bits=s_bits)
    return jnp.where(a > 0, jnp.maximum(a, _A_FLOOR) * power * t, 0.0)


def analytic_power_elements(a, pg, bw, *, s_bits: float, tau: float,
                            p_max: float
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Closed-form optimum of (9) per element: P* = clip(P^min(a), 0, P^max).

    Returns ``(power, lam, feasible)`` with ``lam`` the objective (9a) at
    the optimum — exactly what Dinkelbach's lambda converges to.
    """
    p_min = jnp.clip(element_p_min(a, pg, bw, s_bits=s_bits, tau=tau),
                     0.0, None)
    feasible = p_min <= p_max * (1 + 1e-6)
    p = jnp.minimum(p_min, p_max)
    return p, _element_lam(a, p, pg, bw, s_bits=s_bits), feasible


def dinkelbach_power_elements(a, pg, bw, *, s_bits: float, tau: float,
                              p_max: float,
                              lam0: float | jax.Array = 1e-3,
                              eps: float = 1e-6, max_iters: int = 64
                              ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Vectorised Algorithm 1 over raw element arrays.

    Returns ``(power, lam, n_iters, feasible)``.  Retained as the faithful
    reference for ``analytic_power_elements`` (which is its fixed point in
    closed form); the while-loop makes this a *nested* iteration when used
    inside the fused solver, so it is a reference mode there.

    ``lam0`` seeds the lambda iteration and may be a per-element array —
    the warm-start hook: Dinkelbach converges to the same fixed point from
    any start (Newton on a concave F(lambda)), so a ``lam0`` taken from a
    nearby problem's converged lambda (see :func:`element_warm_lambda`)
    changes nothing but the iteration count.
    """
    a_safe = jnp.maximum(a, _A_FLOOR)
    p_min = jnp.clip(element_p_min(a, pg, bw, s_bits=s_bits, tau=tau),
                     0.0, None)
    p_lo = jnp.minimum(p_min, p_max)   # clip box; feasibility reported separately
    feasible = p_min <= p_max * (1 + 1e-6)

    def p_star(lam):
        # pg <= 0 (gated-out element): drop the -1/pg offset instead of
        # producing -inf/NaN; the clip to [p_lo, p_max] dominates anyway
        inv_pg = jnp.where(pg > 0, 1.0 / jnp.where(pg > 0, pg, 1.0), 0.0)
        p = lam * bw / (a_safe * s_bits * LN2) - inv_pg
        return jnp.clip(p, p_lo, p_max)

    def lam_of(p):
        # guard P=0 (a=0 rows): rate(0)=0 -> T=inf, but a*P=0; define energy 0.
        return _element_lam(a, p, pg, bw, s_bits=s_bits)

    def cond(state):
        _, lam, lam_prev, it, done = state
        return (~jnp.all(done)) & (it < max_iters)

    def body(state):
        p, lam, lam_prev, it, done = state
        p_new = p_star(lam)
        lam_new = lam_of(p_new)
        # relative criterion: energies span ~1e-12..1e2 J across the fleet,
        # so an absolute epsilon would freeze small-energy elements early.
        done_new = jnp.abs(lam_new - lam) <= eps * jnp.maximum(jnp.abs(lam_new), 1e-30)
        # frozen elements keep their converged values
        p_out = jnp.where(done, p, p_new)
        lam_out = jnp.where(done, lam, lam_new)
        return p_out, lam_out, lam, it + 1, done | done_new

    lam_init = jnp.full_like(a, lam0)
    p_init = p_star(lam_init)
    state = (p_init, lam_of(p_init), lam_init, jnp.int32(0), jnp.zeros_like(a, bool))
    p, lam, _, iters, _ = jax.lax.while_loop(cond, body, state)
    return p, lam, iters, feasible


def energy_gate_elements(a, lam, emax, ec) -> jax.Array:
    """Algorithm 2 line 4: objective (9a) <= H_ik = E^max - a E^c (eq. 10)."""
    h = emax - a * ec
    return lam <= h + 1e-9


def element_warm_lambda(a0, p0, pg, bw, *, s_bits: float,
                        lam_floor: float = 1e-3) -> jax.Array:
    """Per-element Dinkelbach seed from a previous solution ``(a0, p0)``.

    Evaluates the objective (9a) at the previous powers on the *current*
    channel: lam0 = a0 P0 T(P0).  On a drifting channel this lands within
    the drift of the new converged lambda, so Algorithm 1 terminates in
    1-3 iterations instead of its cold ~10-60 (see docs/serving.md).
    Elements with no usable previous state (a0 = 0 or P0 = 0, e.g. padded
    slots or newly admitted devices) fall back to the cold-start constant
    ``lam_floor`` — the same 1e-3 the cold path uses.
    """
    lam = _element_lam(a0, p0, pg, bw, s_bits=s_bits)
    return jnp.where((a0 > 0) & (p0 > 0) & (lam > 0), lam, lam_floor)


# -------------------------------------------------------- problem level

def _element_operands(problem: WirelessFLProblem, a: jax.Array):
    """``(a, pg, bw, s)`` broadcast to a common element rank.

    A 1-d ``a`` on a fading problem is materialised to the path gain's
    ``[N, K]`` shape ("same probability, each round's channel" — the
    ``problem.py`` broadcasting contract) so the element-level while
    loops carry shape-stable state; ``bw`` gains a trailing round axis
    whenever any operand is per-round.  ``s`` is the effective payload
    :meth:`WirelessFLProblem.payload_bits` at that rank — the static
    python float when the problem has no ``bits`` leaf (the element
    closed forms are pure elementwise jnp math, so float and array
    payloads trace identically apart from the extra broadcast).
    """
    pg = problem._pg(a)
    bw = problem.bandwidth_hz
    rank = max(a.ndim, pg.ndim)
    if rank > bw.ndim:
        bw = bw[:, None]
    if a.ndim < pg.ndim:
        a = jnp.broadcast_to(a[:, None], pg.shape)
    return a, pg, bw, problem.payload_bits(rank)


def dinkelbach_power(problem: WirelessFLProblem,
                     a: jax.Array,
                     *,
                     lam0: float | jax.Array = 1e-3,
                     eps: float = 1e-6,
                     max_iters: int = 64) -> PowerSolution:
    """Vectorised Algorithm 1 over every (i, k) subproblem simultaneously."""
    a, pg, bw, s = _element_operands(problem, a)
    p, lam, iters, feasible = dinkelbach_power_elements(
        a, pg, bw, s_bits=s, tau=problem.tau_th,
        p_max=problem.p_max, lam0=lam0, eps=eps, max_iters=max_iters)
    return PowerSolution(power=p, lam=lam, n_iters=iters, feasible=feasible)


def analytic_power(problem: WirelessFLProblem, a: jax.Array) -> PowerSolution:
    """Closed-form optimum of (9): the ratio is increasing in P, so
    P* = clip(P^min(a), 0, P^max).  Beyond-paper solver fast path."""
    a, pg, bw, s = _element_operands(problem, a)
    p, lam, feasible = analytic_power_elements(
        a, pg, bw, s_bits=s, tau=problem.tau_th,
        p_max=problem.p_max)
    return PowerSolution(power=p, lam=lam, n_iters=jnp.int32(0), feasible=feasible)


def energy_bound_ok(problem: WirelessFLProblem, a: jax.Array, sol: PowerSolution) -> jax.Array:
    """Algorithm 2 line 4: is objective (9a) <= H_ik = E^max - a E^c (eq. 10)?

    Ranks follow the ``problem.py`` contract: a 1-d ``a`` against a
    per-round ``sol.lam`` (fading problem) broadcasts across rounds.
    """
    rank = max(a.ndim, jnp.ndim(sol.lam))
    ec = _bcast_like(problem.compute_energy(), rank)
    emax = _bcast_like(problem.energy_budget_j, rank)
    return energy_gate_elements(_bcast_like(a, rank), sol.lam, emax, ec)
