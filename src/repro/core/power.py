"""Power allocation: Dinkelbach's method (Algorithm 1), vectorised.

The per-(i, k) fractional program (9)

    min_{P^min <= P <= P^max}   a S P / (B log2(1 + P * pg))

is solved for the *whole fleet at once*: the paper iterates devices one by
one on a CPU; on TPU we batch every (i, k) subproblem into element-wise
vector ops inside a single ``lax.while_loop`` with per-element convergence
masking.  This is the hardware adaptation described in DESIGN.md §5.

Closed-form inner step (setting d/dP of (11) to zero):

    P*(lambda) = lambda * B / (a S ln 2) - 1 / pg        (then clipped)

lambda update:  lambda_j = a S P* / (B log2(1 + P* pg)) = a P* T(P*) objective.

Because the ratio P / log(1+cP) is strictly increasing on P > 0, the true
minimiser is the *lower boundary* P = clip(P^min(a), 0, P^max); Dinkelbach
converges there through the clipping.  ``analytic_power`` exposes that
shortcut (bit-identical solution, ~30x fewer flops) as a beyond-paper
solver optimisation; tests assert both agree.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.problem import LN2, WirelessFLProblem

_A_FLOOR = 1e-12   # guards the a -> 0 division in P*(lambda)


class PowerSolution(NamedTuple):
    power: jax.Array        # P*_ik
    lam: jax.Array          # converged Dinkelbach lambda (= min energy E^u at a)
    n_iters: jax.Array      # scalar int32, iterations to fleet-wide convergence
    feasible: jax.Array     # bool, P^min(a) <= P^max elementwise


def _energy_objective(problem: WirelessFLProblem, a: jax.Array, power: jax.Array) -> jax.Array:
    """Objective (9a): a * P * T(P) = a S P / r(P)."""
    return a * power * problem.tx_time(power)


def dinkelbach_power(problem: WirelessFLProblem,
                     a: jax.Array,
                     *,
                     lam0: float = 1e-3,
                     eps: float = 1e-6,
                     max_iters: int = 64) -> PowerSolution:
    """Vectorised Algorithm 1 over every (i, k) subproblem simultaneously."""
    pg = problem._pg(a)
    bw = problem.bandwidth_hz if a.ndim == 1 else problem.bandwidth_hz[:, None]
    s_bits = problem.grad_size_bits
    a_safe = jnp.maximum(a, _A_FLOOR)

    p_min = jnp.clip(problem.p_min(a), 0.0, None)
    p_lo = jnp.minimum(p_min, problem.p_max)   # clip box; feasibility reported separately
    feasible = p_min <= problem.p_max * (1 + 1e-6)

    def p_star(lam):
        p = lam * bw / (a_safe * s_bits * LN2) - 1.0 / pg
        return jnp.clip(p, p_lo, problem.p_max)

    def lam_of(p):
        # guard P=0 (a=0 rows): rate(0)=0 -> T=inf, but a*P=0; define energy 0.
        e = _energy_objective(problem, a_safe, p)
        return jnp.where(a > 0, e, 0.0)

    def cond(state):
        _, lam, lam_prev, it, done = state
        return (~jnp.all(done)) & (it < max_iters)

    def body(state):
        p, lam, lam_prev, it, done = state
        p_new = p_star(lam)
        lam_new = lam_of(p_new)
        # relative criterion: energies span ~1e-12..1e2 J across the fleet,
        # so an absolute epsilon would freeze small-energy elements early.
        done_new = jnp.abs(lam_new - lam) <= eps * jnp.maximum(jnp.abs(lam_new), 1e-30)
        # frozen elements keep their converged values
        p_out = jnp.where(done, p, p_new)
        lam_out = jnp.where(done, lam, lam_new)
        return p_out, lam_out, lam, it + 1, done | done_new

    lam_init = jnp.full_like(a, lam0)
    p_init = p_star(lam_init)
    state = (p_init, lam_of(p_init), lam_init, jnp.int32(0), jnp.zeros_like(a, bool))
    p, lam, _, iters, _ = jax.lax.while_loop(cond, body, state)
    return PowerSolution(power=p, lam=lam, n_iters=iters, feasible=feasible)


def analytic_power(problem: WirelessFLProblem, a: jax.Array) -> PowerSolution:
    """Closed-form optimum of (9): the ratio is increasing in P, so
    P* = clip(P^min(a), 0, P^max).  Beyond-paper solver fast path."""
    p_min = jnp.clip(problem.p_min(a), 0.0, None)
    feasible = p_min <= problem.p_max * (1 + 1e-6)
    p = jnp.minimum(p_min, problem.p_max)
    lam = jnp.where(a > 0, _energy_objective(problem, jnp.maximum(a, _A_FLOOR), p), 0.0)
    return PowerSolution(power=p, lam=lam, n_iters=jnp.int32(0), feasible=feasible)


def energy_bound_ok(problem: WirelessFLProblem, a: jax.Array, sol: PowerSolution) -> jax.Array:
    """Algorithm 2 line 4: is objective (9a) <= H_ik = E^max - a E^c (eq. 10)?"""
    ec = problem.compute_energy()
    emax = problem.energy_budget_j
    if a.ndim > 1:
        ec, emax = ec[:, None], emax[:, None]
    h = emax - a * ec
    return sol.lam <= h + 1e-9
