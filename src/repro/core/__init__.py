"""Core library: the paper's joint probabilistic client selection and
power allocation for federated learning (Marnissi et al., 2024)."""
from repro.core.alternating import (
    FleetElements,
    JointSolution,
    WarmStart,
    fused_fixed_point,
    fused_fixed_point_flat,
    problem_elements,
    select_best_bits,
    solve_joint,
    solve_joint_fused,
    solve_joint_trace,
)
from repro.core.batch import (
    BatchSolution,
    ProblemBatch,
    batch_elements,
    pad_batch,
    shard_batch,
    solve_joint_batch,
    stack_problems,
)
from repro.core.multicell import (
    CoupledDuals,
    MultiCellProblem,
    MultiCellSolution,
    cell_interference,
    grid_coupling,
    make_multicell,
    solve_coupled,
    solve_coupled_loop,
)
from repro.core.optimal import solve_joint_optimal
from repro.core.power import PowerSolution, analytic_power, dinkelbach_power, energy_bound_ok
from repro.core.problem import (GRAD_SIZE_BITS_FP32, WirelessFLProblem,
                                sample_problem)
from repro.core.schedulers import (
    SCHEDULERS,
    DeterministicScheduler,
    EquallyWeightedScheduler,
    GreedyChannelScheduler,
    LyapunovScheduler,
    ParticipationDraw,
    ProbabilisticScheduler,
    SchedulerState,
    UniformScheduler,
    make_scheduler,
)
from repro.core.scenarios import (
    SCENARIOS,
    Scenario,
    gauss_markov_fading,
    make_batch,
    make_mixed_batch,
    make_problem,
    slice_round,
)
from repro.core.selection import optimal_selection

__all__ = [
    "WirelessFLProblem", "sample_problem", "GRAD_SIZE_BITS_FP32",
    "select_best_bits",
    "ProblemBatch", "BatchSolution", "stack_problems", "shard_batch",
    "solve_joint_batch", "batch_elements", "pad_batch", "WarmStart",
    "Scenario", "SCENARIOS", "make_problem", "make_batch", "make_mixed_batch",
    "gauss_markov_fading", "slice_round",
    "PowerSolution", "dinkelbach_power", "analytic_power", "energy_bound_ok",
    "optimal_selection",
    "JointSolution", "solve_joint", "solve_joint_trace", "solve_joint_optimal",
    "solve_joint_fused", "FleetElements", "problem_elements",
    "fused_fixed_point", "fused_fixed_point_flat",
    "MultiCellProblem", "MultiCellSolution", "CoupledDuals",
    "make_multicell", "grid_coupling", "cell_interference",
    "solve_coupled", "solve_coupled_loop",
    "ParticipationDraw", "SchedulerState",
    "ProbabilisticScheduler", "DeterministicScheduler", "UniformScheduler",
    "EquallyWeightedScheduler", "GreedyChannelScheduler", "LyapunovScheduler",
    "SCHEDULERS", "make_scheduler",
]
