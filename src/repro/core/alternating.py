"""Algorithm 2: alternating optimisation of (7).

repeat:
    P^{n+1}  <- Dinkelbach(problem, a^n)          (Algorithm 1, batched)
    if objective (9a) bounded by H (eq. 10):      (feasibility gate, line 4)
        a^{n+1} <- closed form (13)
until |obj^{n+1} - obj^n| < eps

The objective is monotone non-decreasing and bounded by sum(w) = 1, so the
loop converges to a local optimum (paper, Sec. IV-B).  Elements whose
energy gate fails keep their previous a (the paper "breaks"; per-element
freezing is the batched equivalent and can only do better).

Two implementations:
  * ``solve_joint``       — jit-friendly ``lax.while_loop`` fleet solve.
  * ``solve_joint_trace`` — python loop that records the objective path
                            (used by the convergence benchmark/tests).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.power import PowerSolution, analytic_power, dinkelbach_power, energy_bound_ok
from repro.core.problem import WirelessFLProblem
from repro.core.selection import optimal_selection


class JointSolution(NamedTuple):
    a: jax.Array           # selection probabilities a*_ik
    power: jax.Array       # transmit powers P*_ik
    objective: jax.Array   # scalar, sum_i w_i a_i (per round)
    n_iters: jax.Array     # outer iterations used
    converged: jax.Array   # bool


def _init_state(problem: WirelessFLProblem, shape) -> tuple[jax.Array, jax.Array]:
    """Feasible (a^0, P^0): transmit at P^max, then a^0 from (13)."""
    p0 = jnp.full(shape, problem.p_max)
    a0 = optimal_selection(problem, p0)
    return a0, p0


def _solution_shape(problem: WirelessFLProblem, per_round: bool):
    n = problem.n_devices
    if per_round and (problem.fading is not None):
        return (n, problem.n_rounds)
    return (n,)


def solve_joint(problem: WirelessFLProblem,
                *,
                eps: float = 1e-7,
                max_iters: int = 50,
                power_solver: str = "dinkelbach",
                faithful_eq13_typo: bool = False,
                per_round: bool = True) -> JointSolution:
    """Run Algorithm 2 to convergence for the whole fleet (jit-compatible)."""
    shape = _solution_shape(problem, per_round)
    a0, p0 = _init_state(problem, shape)
    solver: Callable[..., PowerSolution] = (
        analytic_power if power_solver == "analytic" else dinkelbach_power)

    def step(a):
        sol = solver(problem, a) if power_solver == "analytic" else solver(problem, a)
        ok = energy_bound_ok(problem, a, sol) & sol.feasible
        a_new = optimal_selection(problem, sol.power,
                                  faithful_eq13_typo=faithful_eq13_typo)
        # freeze elements whose power subproblem is infeasible / unbounded
        a_new = jnp.where(ok, a_new, a)
        return a_new, sol.power

    def cond(state):
        _, _, obj, obj_prev, it = state
        return (jnp.abs(obj - obj_prev) >= eps) & (it < max_iters)

    def body(state):
        a, p, obj, _, it = state
        a_new, p_new = step(a)
        return a_new, p_new, problem.objective(a_new), obj, it + 1

    a1, p1 = step(a0)
    state = (a1, p1, problem.objective(a1), problem.objective(a0), jnp.int32(1))
    a, p, obj, obj_prev, iters = jax.lax.while_loop(cond, body, state)
    return JointSolution(a=a, power=p, objective=obj, n_iters=iters,
                         converged=jnp.abs(obj - obj_prev) < eps)


def solve_joint_trace(problem: WirelessFLProblem,
                      *,
                      eps: float = 1e-7,
                      max_iters: int = 50,
                      power_solver: str = "dinkelbach",
                      faithful_eq13_typo: bool = False) -> tuple[JointSolution, list[float]]:
    """Python-loop variant of Algorithm 2 recording the objective trace."""
    shape = _solution_shape(problem, per_round=True)
    a, p = _init_state(problem, shape)
    solver = analytic_power if power_solver == "analytic" else dinkelbach_power
    trace = [float(problem.objective(a))]
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        sol = solver(problem, a)
        ok = energy_bound_ok(problem, a, sol) & sol.feasible
        a_new = optimal_selection(problem, sol.power,
                                  faithful_eq13_typo=faithful_eq13_typo)
        a = jnp.where(ok, a_new, a)
        p = sol.power
        trace.append(float(problem.objective(a)))
        if abs(trace[-1] - trace[-2]) < eps:
            converged = True
            break
    res = JointSolution(a=a, power=p, objective=jnp.asarray(trace[-1]),
                        n_iters=jnp.int32(it), converged=jnp.asarray(converged))
    return res, trace
