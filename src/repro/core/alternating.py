"""Algorithm 2: alternating optimisation of (7).

repeat:
    P^{n+1}  <- power update (Algorithm 1 / closed form) at a^n
    if objective (9a) bounded by H (eq. 10):      (feasibility gate, line 4)
        a^{n+1} <- closed form (13)
until converged

The objective is monotone non-decreasing and bounded by sum(w) = 1, so the
loop converges to a local optimum (paper, Sec. IV-B).  Elements whose
energy gate fails keep their previous a (the paper "breaks"; per-element
freezing is the batched equivalent and can only do better).

Three implementations:

* ``solve_joint``       — the paper-shaped solve: a ``lax.while_loop``
                          whose stopping rule is the *global* objective
                          delta, with the power subproblem solved by
                          Dinkelbach's inner ``while_loop`` by default.
* ``solve_joint_trace`` — python loop recording the objective path.  It
                          runs exactly the same ``_alternating_step`` and
                          the same f32 stopping predicate ``_converged``
                          as ``solve_joint``, so both count iterations
                          identically (no off-by-one: both perform at most
                          ``max_iters`` steps and ``n_iters`` is the
                          number of steps actually taken).
* ``solve_joint_fused`` — the fused single-level solver: one flat,
                          convergence-masked fixed-point iteration over
                          the separable (instance, device, round) element
                          set.  The closed-form ``analytic_power`` update
                          (the Dinkelbach fixed point, see power.py), the
                          eq.-10 energy gate and the eq.-13 selection
                          update run in a single ``lax.while_loop`` body;
                          there is **no nested loop**, so vmapped/stacked
                          ensembles never wait on the slowest inner solve.
                          Stopping is per element (max |Δa| < eps), which
                          implies the global rule: sum(w) = 1 means
                          |Δobj| <= max|Δa| < eps.  Supports a
                          ``chunk_elements`` memory bound and an
                          element-axis ``NamedSharding`` for mega-fleet
                          (10^5..10^6 device) solves — see
                          ``fused_fixed_point_flat``.

Warm starts (the online / serving path)
---------------------------------------

``solve_joint`` and ``solve_joint_fused`` accept an optional
``init=(a0, p0)`` resumable state — typically ``previous.resume`` from an
earlier :class:`JointSolution` on a nearby problem (a drifted channel,
a perturbed energy budget).  Semantics, chosen so warm starts can never
change the answer:

* The selection iterate still starts from the canonical feasible point
  (eq. 13 at P^max).  Algorithm 2's alternation is monotone
  non-increasing in ``a`` — the eq.-13 time term at P = P^min(a) is
  exactly ``a`` — so seeding ``a`` from a stale solution would ratchet
  the objective down over a stream of drifting solves instead of
  tracking the true optimum.  The canonical start is a closed form, so
  there is nothing to save there anyway.
* What the warm start *does* seed is the iterative machinery: with
  ``power_solver="dinkelbach"`` the inner Algorithm-1 lambda iteration
  starts from the init state's energy ``lam0 = a0 P0 T(P0)`` (evaluated
  on the current channel) instead of the cold constant.  Dinkelbach is
  globally convergent, so the solution is unchanged (bit-for-bit in
  practice) while the inner iteration count collapses ~10x on a
  coherent channel — ``JointSolution.inner_iters`` reports it, and the
  ``fleet_service_throughput`` benchmark gates it.  The closed-form
  ``"analytic"`` mode has no inner iterations to save; it accepts
  ``init`` as a no-op so callers can thread state unconditionally.

When ``init`` is omitted every solver is bit-identical to the cold path.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.power import (
    PowerSolution,
    analytic_power,
    analytic_power_elements,
    dinkelbach_power,
    dinkelbach_power_elements,
    element_tx_time,
    element_warm_lambda,
    energy_bound_ok,
    energy_gate_elements,
)
from repro.core.problem import WirelessFLProblem
from repro.core.selection import optimal_selection, selection_update_elements


class WarmStart(NamedTuple):
    """Resumable solver state: a previous solution's ``(a, power)``.

    Feed it back as ``solve_joint(..., init=state)`` (or the fused/batch
    equivalents) to warm-start the next solve on a nearby problem.  Any
    ``(a0, p0)`` pair of the right shape works — the NamedTuple is just
    the canonical carrier, obtained from ``JointSolution.resume``.
    """

    a: jax.Array
    power: jax.Array


class JointSolution(NamedTuple):
    a: jax.Array           # selection probabilities a*_ik
    power: jax.Array       # transmit powers P*_ik
    objective: jax.Array   # scalar, sum_i w_i a_i (per round)
    n_iters: jax.Array     # outer iterations used
    converged: jax.Array   # bool
    # total inner power-solver (Algorithm 1) iterations summed over the
    # outer steps; 0 for the closed-form analytic mode.  The figure warm
    # starts collapse — see the module docstring.
    inner_iters: jax.Array | int = 0
    # per-element uplink bit widths chosen by the bit-allocation step —
    # only set when solving with a ``bit_menu`` (docs/compression.md);
    # None otherwise.
    bits: Optional[jax.Array] = None

    @property
    def resume(self) -> WarmStart:
        """The resumable warm-start state for a subsequent nearby solve."""
        return WarmStart(a=self.a, power=self.power)


def _init_state(problem: WirelessFLProblem, shape) -> tuple[jax.Array, jax.Array]:
    """Feasible (a^0, P^0): transmit at P^max, then a^0 from (13)."""
    p0 = jnp.full(shape, problem.p_max)
    a0 = optimal_selection(problem, p0)
    return a0, p0


def _solution_shape(problem: WirelessFLProblem, per_round: bool):
    n = problem.n_devices
    if problem.fading is not None:
        if not per_round:
            # a 1-d iterate against the [N, K] path gain only "works"
            # when K == N, and is then silently wrong — refuse instead
            raise ValueError(
                "per_round=False is meaningless on a fading problem: the "
                "closed forms are separable per (i, k), so solve with "
                "per_round=True (solution shape [N, K])")
        return (n, problem.n_rounds)
    return (n,)


# ------------------------------------------------- shared Algorithm-2 step

def _alternating_step(problem: WirelessFLProblem, a: jax.Array,
                      solver: Callable[..., PowerSolution],
                      faithful_eq13_typo: bool
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One Algorithm-2 alternation: power update, eq.-10 gate, eq.-13.

    Returns ``(a_new, power, inner_iters)`` — the last is the power
    subproblem's iteration count (0 for the closed-form solvers).
    """
    sol = solver(problem, a)
    ok = energy_bound_ok(problem, a, sol) & sol.feasible
    a_new = optimal_selection(problem, sol.power,
                              faithful_eq13_typo=faithful_eq13_typo)
    # freeze elements whose power subproblem is infeasible / unbounded
    a_new = jnp.where(ok, a_new, a)
    return a_new, sol.power, sol.n_iters


def _converged(obj: jax.Array, obj_prev: jax.Array, eps: float) -> jax.Array:
    """The single stopping predicate both solve_joint paths share.

    Evaluated on-device in the objective's dtype (f32): the python trace
    loop must not compare float64-upcast copies, or its iteration count
    can differ from the ``while_loop``'s by one near the threshold.
    """
    return jnp.abs(obj - obj_prev) < eps


def _warm_solver(problem: WirelessFLProblem, power_solver: str,
                 init: Optional[tuple[jax.Array, jax.Array]],
                 shape) -> Callable[..., PowerSolution]:
    """Resolve the power solver, seeding Dinkelbach's lambda from ``init``.

    The warm seed only touches the inner iteration's starting point —
    the converged power/lambda are init-independent (module docstring).
    """
    if power_solver == "analytic":
        return analytic_power          # closed form: init is a no-op
    if init is None:
        return dinkelbach_power
    a0, p0 = init
    a0 = jnp.broadcast_to(jnp.asarray(a0, jnp.float32), shape)
    p0 = jnp.broadcast_to(jnp.asarray(p0, jnp.float32), shape)
    pg = problem._pg(a0)
    bw = problem.bandwidth_hz if a0.ndim == 1 else problem.bandwidth_hz[:, None]
    lam0 = element_warm_lambda(a0, p0, pg, bw,
                               s_bits=problem.payload_bits(a0.ndim))
    return functools.partial(dinkelbach_power, lam0=lam0)


def solve_joint(problem: WirelessFLProblem,
                *,
                eps: float = 1e-7,
                max_iters: int = 50,
                power_solver: str = "dinkelbach",
                faithful_eq13_typo: bool = False,
                per_round: bool = True,
                init: Optional[tuple[jax.Array, jax.Array]] = None
                ) -> JointSolution:
    """Run Algorithm 2 to convergence for the whole fleet (jit-compatible).

    ``init=(a0, p0)`` warm-starts the solve from a previous solution's
    resumable state (``JointSolution.resume``); omitted, the solve is
    bit-identical to the cold path.  See the module docstring for the
    warm-start semantics.
    """
    shape = _solution_shape(problem, per_round)
    a0, p0 = _init_state(problem, shape)
    solver = _warm_solver(problem, power_solver, init, shape)
    step = functools.partial(_alternating_step, solver=solver,
                             faithful_eq13_typo=faithful_eq13_typo)

    def cond(state):
        _, _, obj, obj_prev, it, _ = state
        return ~_converged(obj, obj_prev, eps) & (it < max_iters)

    def body(state):
        a, p, obj, _, it, inner = state
        a_new, p_new, k = step(problem, a)
        return (a_new, p_new, problem.objective(a_new), obj, it + 1,
                inner + k)

    a1, p1, k1 = step(problem, a0)
    state = (a1, p1, problem.objective(a1), problem.objective(a0),
             jnp.int32(1), jnp.int32(0) + k1)
    a, p, obj, obj_prev, iters, inner = jax.lax.while_loop(cond, body, state)
    return JointSolution(a=a, power=p, objective=obj, n_iters=iters,
                         converged=_converged(obj, obj_prev, eps),
                         inner_iters=inner)


def solve_joint_trace(problem: WirelessFLProblem,
                      *,
                      eps: float = 1e-7,
                      max_iters: int = 50,
                      power_solver: str = "dinkelbach",
                      faithful_eq13_typo: bool = False,
                      init: Optional[tuple[jax.Array, jax.Array]] = None
                      ) -> tuple[JointSolution, list[float]]:
    """Python-loop variant of Algorithm 2 recording the objective trace.

    Shares ``_alternating_step`` and ``_converged`` with ``solve_joint``,
    so the recorded trace length and ``n_iters`` match the jitted path
    step for step (the convergence benchmark counts on this); ``init``
    has the same warm-start semantics too.
    """
    shape = _solution_shape(problem, per_round=True)
    a, p = _init_state(problem, shape)
    solver = _warm_solver(problem, power_solver, init, shape)
    step = functools.partial(_alternating_step, solver=solver,
                             faithful_eq13_typo=faithful_eq13_typo)
    obj_prev = problem.objective(a)
    trace = [float(obj_prev)]
    converged = False
    it = 0
    inner = jnp.int32(0)
    for it in range(1, max_iters + 1):  # noqa: B007 - read after the loop (n_iters)
        a, p, k = step(problem, a)
        inner = inner + k
        obj = problem.objective(a)
        trace.append(float(obj))
        if bool(_converged(obj, obj_prev, eps)):
            converged = True
            break
        obj_prev = obj
    res = JointSolution(a=a, power=p, objective=jnp.asarray(trace[-1]),
                        n_iters=jnp.int32(it), converged=jnp.asarray(converged),
                        inner_iters=inner)
    return res, trace


# --------------------------------------------- fused single-level solver

class FleetElements(NamedTuple):
    """Constraint data of the separable (instance, device, round) elements.

    All leaves share one common shape — flat ``[E]``, per-device ``[N]``,
    per-(device, round) ``[N, K]``, stacked ``[B, N]``/``[B, N, K]``; the
    solver never looks at the structure, only at elements.
    """

    pg: jax.Array      # path gain g / (d^2 sigma^2)
    bw: jax.Array      # bandwidth B_i
    emax: jax.Array    # per-round energy budget E^max_i
    ec: jax.Array      # computation energy E^c_i
    # effective uplink payload S_i = S b_i / 32 in bits (already scaled);
    # None => every element uses the solver's static ``s_bits`` payload —
    # the byte-identity idiom of the problem's optional leaves.
    sbits: Optional[jax.Array] = None


# padding for chunk/shard alignment: zero energy budget self-deselects
# (a* = 0, P* = 0) without producing NaN/inf in any update — the element
# analogue of core/batch.py's ``_PAD_VALUES``.
_ELEMENT_PAD = dict(pg=1.0, bw=1.0, emax=0.0, ec=1.0)

# below this element count, auto-sharding (shard=True without an explicit
# mesh) stays local: splitting a few thousand f32 elements over devices
# costs more in per-iteration collectives (the while-loop convergence
# reduce) than the sharded compute saves.  Element sharding exists for
# the 10^5..10^6-element mega-fleet regime.
_MIN_SHARD_ELEMENTS = 32_768


def problem_elements(problem: WirelessFLProblem,
                     per_round: bool = True) -> FleetElements:
    """Broadcast one problem's constraint data to the element set."""
    shape = _solution_shape(problem, per_round)

    def b(x):
        return jnp.broadcast_to(x[:, None] if x.ndim < len(shape) else x,
                                shape)

    return FleetElements(pg=b(problem.path_gain()),
                         bw=b(problem.bandwidth_hz),
                         emax=b(problem.energy_budget_j),
                         ec=b(problem.compute_energy()),
                         sbits=None if problem.bits is None
                         else b(problem.payload_bits(len(shape))))


def _fused_step(a: jax.Array, el: FleetElements, *, s_bits: float,
                tau: float, p_max: float, power_solver: str,
                faithful_eq13_typo: bool,
                lam0: float | jax.Array = 1e-3
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused alternation on raw elements: power + gate + eq. 13.

    With ``power_solver="analytic"`` (default) this is straight-line
    element-wise code — the whole Algorithm-2 body with no inner loop.
    ``"dinkelbach"`` is the faithful reference mode and re-introduces the
    inner Algorithm-1 iteration (slow; for agreement checks, and the mode
    whose ``lam0`` seed the warm-start path collapses).

    Returns ``(a_new, power, inner_iters)``; ``inner_iters`` is 0 in
    analytic mode.
    """
    if el.sbits is not None:
        s_bits = el.sbits        # per-element bit-scaled payload
    if power_solver == "analytic":
        p, lam, feasible = analytic_power_elements(
            a, el.pg, el.bw, s_bits=s_bits, tau=tau, p_max=p_max)
        inner = jnp.int32(0)
    elif power_solver == "dinkelbach":
        p, lam, inner, feasible = dinkelbach_power_elements(
            a, el.pg, el.bw, s_bits=s_bits, tau=tau, p_max=p_max, lam0=lam0)
    else:
        raise ValueError(f"unknown power_solver {power_solver!r}")
    ok = energy_gate_elements(a, lam, el.emax, el.ec) & feasible
    t = element_tx_time(p, el.pg, el.bw, s_bits=s_bits)
    a_new = selection_update_elements(p, t, el.emax, el.ec, tau=tau,
                                      s_bits=s_bits,
                                      faithful_eq13_typo=faithful_eq13_typo)
    return jnp.where(ok, a_new, a), p, inner


def fused_init(el: FleetElements, *, s_bits: float, tau: float,
               p_max: float, faithful_eq13_typo: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """Feasible (a^0, P^0) on raw elements: transmit at P^max, a^0 from
    eq. (13) — the element form of ``_init_state``.  Shared with the
    Pallas kernel so the two paths cannot drift."""
    if el.sbits is not None:
        s_bits = el.sbits
    p0 = jnp.full(el.pg.shape, p_max)
    t0 = element_tx_time(p0, el.pg, el.bw, s_bits=s_bits)
    a0 = selection_update_elements(p0, t0, el.emax, el.ec, tau=tau,
                                   s_bits=s_bits,
                                   faithful_eq13_typo=faithful_eq13_typo)
    return a0, p0


def _menu_payloads(el: FleetElements, *, s_bits: float, bit_menu):
    """Candidate effective payloads for each menu entry, descending width.

    Entry ``b`` maps to ``S b / 32``; a problem-level ``bits`` cap
    (``el.sbits``) composes by elementwise minimum — the device can never
    transmit more precision than its own leaf allows.  Descending order is
    load-bearing: ``jnp.argmax`` returns the *first* maximum, so exact
    ties in the candidate objective resolve to the largest bit width
    (devices with slack keep full precision; see docs/compression.md).
    """
    menu = tuple(sorted({float(b) for b in bit_menu}, reverse=True))
    if not menu or menu[0] > 32.0 or menu[-1] <= 0.0:
        raise ValueError(f"bit_menu entries must lie in (0, 32], got {bit_menu!r}")
    payloads = []
    for b in menu:
        s_b = s_bits * (b / 32.0)
        if el.sbits is not None:
            s_b = jnp.minimum(el.sbits, s_b)
        payloads.append(s_b)
    return menu, payloads


def select_best_bits(a_m: jax.Array, p_m: jax.Array, sbits_m: jax.Array,
                     *, s_bits: float, atol: float = 1e-6
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Closed-form bit-allocation: argmax over per-element candidates.

    ``a_m``/``p_m``/``sbits_m`` stack one converged candidate solution per
    menu entry along a leading axis, **ordered by descending bit width**.
    Per element the chosen entry is the first (widest) whose selection
    probability is within ``atol`` of the best — participation is the
    paper objective (7a), so any real gain justifies dropping bits, while
    near-ties (a = 1 capped, deselected a = 0, upload energy negligible
    against E^c) resolve to full precision rather than to float noise.

    Returns ``(a, power, bits)`` with ``bits = 32 * sbits / S``, the
    effective chosen width.  This is the step the golden N=3 oracle in
    ``tests/test_bit_allocation.py`` pins.
    """
    amax = jnp.max(a_m, axis=0)
    idx = jnp.argmax(a_m >= amax[None] - atol, axis=0)[None]

    def take(x):
        return jnp.take_along_axis(x, idx, axis=0)[0]

    return take(a_m), take(p_m), take(sbits_m) * (32.0 / s_bits)


def fused_fixed_point(el: FleetElements, *, s_bits: float, tau: float,
                      p_max: float, eps: float = 1e-7, max_iters: int = 50,
                      power_solver: str = "analytic",
                      faithful_eq13_typo: bool = False,
                      init: Optional[tuple[jax.Array, jax.Array]] = None,
                      bit_menu: Optional[tuple] = None
                      ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                                 jax.Array]:
    """The flat convergence-masked alternating solve.

    One ``lax.while_loop`` over the whole element set; iteration ``n``
    applies ``_fused_step`` to every element simultaneously and the loop
    exits when every element's update moved less than ``eps`` (or at
    ``max_iters`` total steps, counted like ``solve_joint``).  Per-element
    trajectories are identical to ``solve_joint``'s — the problem is
    separable, so each element's update depends only on its own ``a`` —
    only the stopping rule differs (elementwise vs global objective), and
    the elementwise rule is the stricter of the two.

    ``init=(a0, p0)`` element arrays warm-start the solve (module
    docstring): the selection iterate still starts canonically, but the
    Dinkelbach mode's inner lambda is seeded from the init state's
    energy.  Omitted, the solve is bit-identical to the cold path.

    Returns ``(a, power, n_iters, converged, inner_iters)`` with
    ``converged`` a per-element bool and ``inner_iters`` the summed inner
    power-solver iterations (0 in analytic mode).

    ``bit_menu`` (a tuple of widths in (0, 32], e.g. ``(4, 6, 8, 16, 32)``)
    enables the joint bit/power/selection solve and extends the return
    value to the 6-tuple ``(a, power, n_iters, converged, inner_iters,
    bits)``.  The menu is evaluated *vectorized inside the same
    convergence-masked single-level while loop*: the element set is
    expanded with a leading candidate axis (one slice per menu width,
    descending), every candidate's alternation runs to its own fixed
    point in the one ``lax.while_loop``, and :func:`select_best_bits`
    reduces the axis per element (argmax of the converged selection
    probability, exact-tie towards full precision).  This is exact for
    the separable per-element problem — comparing candidates only after
    one step from a shared iterate would always tie, because the eq.-13
    time term at P = P^min(a) equals ``a`` for *every* payload.  The
    ``None`` default keeps the historical 5-tuple and traces the exact
    pre-menu program.
    """
    if bit_menu is not None:
        _, payloads = _menu_payloads(el, s_bits=s_bits, bit_menu=bit_menu)
        m, shape = len(payloads), el.pg.shape

        def expand(x):
            return jnp.broadcast_to(x[None], (m,) + shape)

        sb = jnp.stack([jnp.broadcast_to(
            jnp.asarray(s_b, jnp.float32), shape) for s_b in payloads])
        el_m = FleetElements(pg=expand(el.pg), bw=expand(el.bw),
                             emax=expand(el.emax), ec=expand(el.ec),
                             sbits=sb)
        init_m = None if init is None else tuple(expand(x) for x in init)
        a_m, p_m, iters, conv_m, inner = fused_fixed_point(
            el_m, s_bits=s_bits, tau=tau, p_max=p_max, eps=eps,
            max_iters=max_iters, power_solver=power_solver,
            faithful_eq13_typo=faithful_eq13_typo, init=init_m)
        a, p, bits = select_best_bits(a_m, p_m, sb, s_bits=s_bits)
        return a, p, iters, jnp.all(conv_m, axis=0), inner, bits

    lam0 = 1e-3
    if init is not None and power_solver == "dinkelbach":
        lam0 = element_warm_lambda(init[0], init[1], el.pg, el.bw,
                                   s_bits=s_bits if el.sbits is None
                                   else el.sbits)
    a0, _ = fused_init(el, s_bits=s_bits, tau=tau, p_max=p_max,
                       faithful_eq13_typo=faithful_eq13_typo)

    step = functools.partial(_fused_step, el=el, s_bits=s_bits, tau=tau,
                             p_max=p_max, power_solver=power_solver,
                             faithful_eq13_typo=faithful_eq13_typo,
                             lam0=lam0)

    def cond(state):
        _, _, delta, it, _ = state
        return jnp.any(delta >= eps) & (it < max_iters)

    def body(state):
        a, _, _, it, inner = state
        a_new, p_new, k = step(a)
        return a_new, p_new, jnp.abs(a_new - a), it + 1, inner + k

    a1, p1, k1 = step(a0)
    state = (a1, p1, jnp.abs(a1 - a0), jnp.int32(1), jnp.int32(0) + k1)
    a, p, delta, iters, inner = jax.lax.while_loop(cond, body, state)
    return a, p, iters, delta < eps, inner


def element_mesh(mesh: Optional[jax.sharding.Mesh] = None
                 ) -> Optional[jax.sharding.Mesh]:
    """Resolve the mesh used to shard the element axis over local devices.

    Returns None when sharding is a no-op (single device).  A
    user-supplied mesh may use any axis naming; the element axis is split
    along its *first* axis (matching ``core.batch.batch_sharding``).
    """
    if mesh is None:
        devices = jax.devices()
        if len(devices) <= 1:
            return None
        mesh = jax.sharding.Mesh(np.array(devices), ("elements",))
    return mesh if mesh.shape[mesh.axis_names[0]] > 1 else None


def _pad_flat(x: jax.Array, multiple: int, fill: float) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    return x if pad == 0 else jnp.pad(x, (0, pad), constant_values=fill)


def _pad_elements(el: FleetElements, multiple: int) -> FleetElements:
    padded = {f: _pad_flat(getattr(el, f), multiple, _ELEMENT_PAD[f])
              for f in _ELEMENT_PAD}
    if el.sbits is not None:
        # any positive payload works: padded slots self-deselect via
        # emax = 0, the fill only needs to keep the closed forms finite
        padded["sbits"] = _pad_flat(el.sbits, multiple, 1.0)
    return FleetElements(**padded)


def fused_fixed_point_flat(el: FleetElements, *, s_bits: float, tau: float,
                           p_max: float, eps: float = 1e-7,
                           max_iters: int = 50,
                           power_solver: str = "analytic",
                           faithful_eq13_typo: bool = False,
                           chunk_elements: Optional[int] = None,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           shard: bool = True,
                           init: Optional[tuple[jax.Array, jax.Array]] = None,
                           bit_menu: Optional[tuple] = None
                           ) -> tuple[jax.Array, jax.Array, jax.Array,
                                      jax.Array, jax.Array]:
    """Chunked, device-sharded driver over a flat ``[E]`` element set.

    * ``chunk_elements`` bounds the working set: the element axis is padded
      to a whole number of chunks and solved chunk-by-chunk under
      ``lax.map`` (sequential, compiled once), so peak memory is
      O(chunk_elements) regardless of fleet size.  ``None`` solves all E
      elements in one call.
    * ``shard=True`` lays the element axis (the within-chunk axis when
      chunking) out across the local device mesh with a ``NamedSharding``
      — a *device-axis* sharding: a single 100k-device instance spreads
      over the mesh even at batch size 1.  Chunk sizes are rounded up to
      the device count so every shard is equal.  Auto-sharding only
      engages when the per-solve working set — min(E, chunk_elements) —
      reaches ``_MIN_SHARD_ELEMENTS`` (below that the per-iteration
      convergence all-reduce costs more than the sharded compute saves);
      passing an explicit ``mesh`` always shards, regardless of ``shard``
      and the threshold.

    Returns flat ``(a, power, n_iters, converged, inner_iters)`` of the
    original length E; padding elements are solved (to a = P = 0) and
    stripped.  ``init=(a0, p0)`` flat element arrays warm-start the solve
    (padded/chunked/sharded alongside the elements); on the chunked path
    ``inner_iters`` sums over chunks (total inner work) while ``n_iters``
    is the max.

    ``bit_menu`` forwards to :func:`fused_fixed_point` and, when set,
    extends the return value with a trailing flat ``bits`` array (the
    6-tuple contract described there).
    """
    assert el.pg.ndim == 1, "fused_fixed_point_flat takes flat [E] elements"
    e = el.pg.shape[0]

    def solve(operand):
        el_c, init_c = operand
        return fused_fixed_point(el_c, s_bits=s_bits, tau=tau,
                                 p_max=p_max, eps=eps, max_iters=max_iters,
                                 power_solver=power_solver,
                                 faithful_eq13_typo=faithful_eq13_typo,
                                 init=init_c, bit_menu=bit_menu)

    if mesh is not None:
        shard = True                       # an explicit mesh always shards
    else:
        # the while-loop all-reduce is paid per *solve*, so the auto
        # threshold looks at the per-chunk working set, not the total E
        working_set = e if chunk_elements is None else min(e, chunk_elements)
        if working_set < _MIN_SHARD_ELEMENTS:
            shard = False                  # auto-sharding: stay local
    mesh = element_mesh(mesh) if shard else None
    n_shards = 1 if mesh is None else mesh.shape[mesh.axis_names[0]]

    def pad(multiple):
        el_p = _pad_elements(el, multiple)
        init_p = None if init is None else tuple(
            _pad_flat(jnp.asarray(x).reshape(-1), multiple, 0.0)
            for x in init)
        return el_p, init_p

    def constrain(arrs, spec):
        if mesh is None:
            return arrs
        ns = jax.sharding.NamedSharding(mesh, spec)
        return jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(x, ns), arrs)

    if chunk_elements is None:
        operand = constrain(pad(n_shards),
                            jax.sharding.PartitionSpec(mesh.axis_names[0])
                            if mesh else None)
        out = solve(operand)
        if bit_menu is None:
            a, p, iters, conv, inner = out
            return a[:e], p[:e], iters, conv[:e], inner
        a, p, iters, conv, inner, bits = out
        return a[:e], p[:e], iters, conv[:e], inner, bits[:e]

    chunk = -(-chunk_elements // n_shards) * n_shards
    operand = pad(chunk)
    n_chunks = operand[0].pg.shape[0] // chunk
    operand = jax.tree_util.tree_map(
        lambda x: x.reshape(n_chunks, chunk), operand)
    operand = constrain(operand,
                        jax.sharding.PartitionSpec(None, mesh.axis_names[0])
                        if mesh else None)
    out = jax.lax.map(solve, operand)

    def unflat(x):
        return x.reshape(-1)[:e]

    if bit_menu is None:
        a, p, iters, conv, inner = out
        return (unflat(a), unflat(p), jnp.max(iters), unflat(conv),
                jnp.sum(inner))
    a, p, iters, conv, inner, bits = out
    return (unflat(a), unflat(p), jnp.max(iters), unflat(conv),
            jnp.sum(inner), unflat(bits))


def solve_joint_fused(problem: WirelessFLProblem,
                      *,
                      eps: float = 1e-7,
                      max_iters: int = 50,
                      power_solver: str = "analytic",
                      faithful_eq13_typo: bool = False,
                      per_round: bool = True,
                      chunk_elements: Optional[int] = None,
                      mesh: Optional[jax.sharding.Mesh] = None,
                      shard: bool = False,
                      sanitize: bool = False,
                      init: Optional[tuple[jax.Array, jax.Array]] = None,
                      bit_menu: Optional[tuple] = None
                      ) -> JointSolution:
    """Fused single-level Algorithm 2 for one problem (jit-compatible).

    ``sanitize=True`` maps devices with non-finite / out-of-domain
    constraint data to self-deselecting no-ops (a* = P* = 0) via
    ``WirelessFLProblem.sanitize`` before solving — the boundary
    hardening used by the serving path (docs/robustness.md); on healthy
    input it is bit-identical to ``sanitize=False``.

    Matches ``solve_joint`` to solver tolerance (tests assert <= 1e-5 on
    a*, P* and the objective) while running the whole alternation as one
    flat masked iteration — the mega-fleet path for 10^5+ device
    instances.  ``chunk_elements``/``mesh``/``shard`` are forwarded to
    :func:`fused_fixed_point_flat` (they are jit-static arguments).
    ``init=(a0, p0)`` (shaped like the solution) warm-starts the solve —
    see the module docstring; omitted, the solve is bit-identical to the
    cold path, and the returned ``JointSolution.resume`` is the state to
    feed the next solve on a drifted problem.

    Caveat: with ``faithful_eq13_typo=True`` the verbatim formula has no
    interior fixed point (each sweep contracts a by 1/S), so the
    per-element rule iterates to the collapsed solution while
    ``solve_joint``'s global-objective rule stops a couple of sweeps
    above it; the <= 1e-5 agreement guarantee covers the corrected
    formula only.

    ``bit_menu`` (e.g. ``(4, 6, 8, 16, 32)``) enables the joint
    bit/power/selection alternation: each sweep additionally picks, per
    element, the menu width maximising the eq.-13 update (ties towards
    full precision), and the returned ``JointSolution.bits`` carries the
    chosen widths.  ``None`` (the default) traces the exact historical
    program — byte-identical solutions, ``bits=None``.
    """
    if sanitize:
        problem, _ = problem.sanitize()
    # per_round=False on a fading problem is rejected by _solution_shape
    # (via problem_elements), one message for every solver entry point
    el = problem_elements(problem, per_round)
    shape = el.pg.shape
    if init is not None:
        init = tuple(jnp.broadcast_to(jnp.asarray(x, jnp.float32), shape)
                     for x in init)
    kw = dict(s_bits=problem.grad_size_bits, tau=problem.tau_th,
              p_max=problem.p_max, eps=eps, max_iters=max_iters,
              power_solver=power_solver,
              faithful_eq13_typo=faithful_eq13_typo, init=init,
              bit_menu=bit_menu)
    bits = None
    if chunk_elements is None and not shard and mesh is None:
        out = fused_fixed_point(el, **kw)
        if bit_menu is None:
            a, p, iters, conv, inner = out
        else:
            a, p, iters, conv, inner, bits = out
    else:
        kw["init"] = None if init is None else tuple(
            x.reshape(-1) for x in init)
        flat = jax.tree_util.tree_map(lambda x: x.reshape(-1), el)
        out = fused_fixed_point_flat(
            flat, chunk_elements=chunk_elements, mesh=mesh, shard=shard, **kw)
        if bit_menu is None:
            a, p, iters, conv, inner = out
        else:
            a, p, iters, conv, inner, bits = out
            bits = bits.reshape(shape)
        a, p, conv = a.reshape(shape), p.reshape(shape), conv.reshape(shape)
    return JointSolution(a=a, power=p, objective=problem.objective(a),
                         n_iters=iters, converged=jnp.all(conv),
                         inner_iters=inner, bits=bits)
