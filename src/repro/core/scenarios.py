"""Scenario registry: named wireless-FL problem generators.

One API from which benchmarks, examples, tests, and the FL engine all draw
their scenarios — the paper's simulation setup plus beyond-paper workloads
(fading ensembles, heterogeneous bandwidth, 1k-device fleets, energy-starved
sparse fleets).  Each registered scenario is a :class:`Scenario` whose
``build(seed, **overrides)`` returns one i.i.d. ``WirelessFLProblem`` draw;
``make_batch`` stacks many draws into a :class:`repro.core.batch.ProblemBatch`
for the batched solver.  The multi-cell entries (``metro_coupled``,
``interference_grid``) instead build a coupled
:class:`repro.core.multicell.MultiCellProblem` for
``core.multicell.solve_coupled``.

    from repro.core.scenarios import SCENARIOS, make_problem, make_batch

    prob  = make_problem("paper_static", seed=0)
    batch = make_batch("rayleigh_fading", n_instances=64, seed=0)

Every scenario documents the paper figure/section it reproduces (or that it
is a beyond-paper extension) in ``docs/scenarios.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.batch import ProblemBatch, stack_problems
from repro.core.multicell import MultiCellProblem, grid_coupling, make_multicell
from repro.core.problem import WirelessFLProblem, sample_problem


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, seeded generator of WirelessFLProblem instances."""

    name: str
    description: str
    paper_ref: str          # paper figure/section, or "beyond-paper"
    n_devices: int          # default fleet size of one draw
    build: Callable[..., WirelessFLProblem]   # (seed, **overrides) -> problem

    def __call__(self, seed: int = 0, **overrides) -> WirelessFLProblem:
        return self.build(seed, **overrides)


SCENARIOS: dict[str, Scenario] = {}


def register(name: str, description: str, paper_ref: str, n_devices: int):
    """Decorator: add a builder ``fn(seed, **overrides)`` to the registry."""
    def deco(fn):
        if name in SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        SCENARIOS[name] = Scenario(name=name, description=description,
                                   paper_ref=paper_ref, n_devices=n_devices,
                                   build=fn)
        return fn
    return deco


def make_problem(name: str, seed: int = 0, **overrides) -> WirelessFLProblem:
    """One draw of a registered scenario."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(SCENARIOS)}")
    return SCENARIOS[name](seed, **overrides)


def make_batch(name: str, n_instances: int, seed: int = 0,
               **overrides) -> ProblemBatch:
    """Stack ``n_instances`` i.i.d. draws (seeds ``seed .. seed+B-1``)."""
    draws = [make_problem(name, seed + i, **overrides)
             for i in range(n_instances)]
    if any(isinstance(d, MultiCellProblem) for d in draws):
        raise ValueError(
            f"scenario {name!r} builds a coupled MultiCellProblem; solve "
            "it with core.multicell.solve_coupled instead of batching "
            "(its .cells is already a ProblemBatch)")
    return stack_problems(draws)


def make_mixed_batch(names: Sequence[str], seed: int = 0,
                     **overrides) -> ProblemBatch:
    """One draw of each named scenario stacked into a single ragged batch.

    All named scenarios must share static metadata (``tau_th``, ``p_max``,
    ...); fleet sizes may differ freely (padded + masked).
    """
    return stack_problems([make_problem(n, seed + i, **overrides)
                           for i, n in enumerate(names)])


# ----------------------------------------- time-correlated channel drift

def gauss_markov_fading(rng: np.random.Generator | int, n_devices: int,
                        n_rounds: int, coherence: float = 0.9) -> np.ndarray:
    """Time-correlated Rayleigh fading power gains, shape ``[N, K]``.

    First-order Gauss–Markov (AR(1)) evolution of the complex channel —
    the discrete-time Jakes/Clarke surrogate used throughout the wireless
    FL literature (cf. Perazzone et al., arXiv:2201.07912; Yang et al.'s
    per-round re-solving):

        h_0 ~ CN(0, 1),    h_k = rho h_{k-1} + sqrt(1 - rho^2) w_k

    with ``rho = coherence`` in [0, 1) and w_k ~ CN(0, 1) i.i.d.  Power
    gains ``g_k = |h_k|^2`` are marginally Exp(1) — exactly the
    ``rayleigh_fading`` scenario's distribution — but successive rounds
    correlate as ``corr(g_k, g_{k+1}) = rho^2``, so successive per-round
    solves are near-identical: the regime the warm-started serving path
    (``repro.serve``) exploits.  ``coherence=0`` recovers i.i.d. block
    fading; ``coherence -> 1`` approaches a static channel.
    """
    if not 0.0 <= coherence < 1.0:
        raise ValueError(f"coherence must be in [0, 1), got {coherence}")
    rng = np.random.default_rng(rng) if not isinstance(
        rng, np.random.Generator) else rng

    def cn(size):
        return (rng.standard_normal(size) + 1j * rng.standard_normal(size)) \
            / np.sqrt(2.0)

    h = cn(n_devices)
    cols = [np.abs(h) ** 2]
    for _ in range(n_rounds - 1):
        h = coherence * h + np.sqrt(1.0 - coherence ** 2) * cn(n_devices)
        cols.append(np.abs(h) ** 2)
    return np.stack(cols, axis=1)


def slice_round(problem: WirelessFLProblem, k: int) -> WirelessFLProblem:
    """Round ``k`` of a fading problem as a standalone 1-round problem.

    The per-request unit of the serving path: a ``[N, K]`` drifting
    scenario becomes a stream of K single-round problems whose channels
    drift between successive requests.  Solutions have shape ``[N, 1]``.
    """
    if problem.fading is None:
        raise ValueError("slice_round needs a fading ([N, K]) problem")
    bits = problem.bits
    if bits is not None and bits.ndim == 2:
        bits = bits[:, k:k + 1]
    return dataclasses.replace(problem,
                               fading=problem.fading[:, k:k + 1],
                               bits=bits,
                               n_rounds=1)


# ------------------------------------------------------------ registry


@register("paper_static",
          "The paper's simulation setup (Sec. V-A): 100 devices uniform in "
          "1 km^2, static channel, B = 10 MHz shared equally, per-round "
          "energy budgets log-uniform in [1e-3, 100] J.",
          "Sec. V-A, Tables I-IV", n_devices=100)
def _paper_static(seed, *, n_devices: int = 100, **kw) -> WirelessFLProblem:
    return sample_problem(seed, n_devices, **kw)


@register("rayleigh_fading",
          "Paper setup with i.i.d. Rayleigh block fading per round "
          "(exponential power gain, unit mean) — the per-(i, k) separable "
          "closed forms solve each round's draw jointly.",
          "beyond-paper (cf. Perazzone et al., arXiv:2201.07912)",
          n_devices=100)
def _rayleigh_fading(seed, *, n_devices: int = 100, n_rounds: int = 10,
                     **kw) -> WirelessFLProblem:
    return sample_problem(seed, n_devices, with_fading=True,
                          n_rounds=n_rounds, **kw)


@register("hetero_bandwidth",
          "Unequal OFDMA bandwidth split: the 10 MHz total is divided by a "
          "Dirichlet(1) draw instead of equally, modelling heterogeneous "
          "subcarrier grants.",
          "beyond-paper (cf. Guo et al., arXiv:2205.09306)", n_devices=100)
def _hetero_bandwidth(seed, *, n_devices: int = 100,
                      total_bandwidth_hz: float = 10e6,
                      **kw) -> WirelessFLProblem:
    prob = sample_problem(seed, n_devices,
                          total_bandwidth_hz=total_bandwidth_hz, **kw)
    rng = np.random.default_rng(seed + 7_919)
    shares = rng.dirichlet(np.ones(n_devices))
    # floor each share at 1% of the equal split so no device is starved to
    # a numerically-degenerate rate
    shares = np.maximum(shares, 0.01 / n_devices)
    shares = shares / shares.sum()
    return dataclasses.replace(
        prob, bandwidth_hz=jnp.asarray(shares * total_bandwidth_hz,
                                       jnp.float32))


@register("dense_1k",
          "Dense metropolitan fleet: 1000 devices in 1 km^2 sharing "
          "100 MHz; stresses the fleet-scale vectorised solve.",
          "beyond-paper", n_devices=1000)
def _dense_1k(seed, *, n_devices: int = 1000, **kw) -> WirelessFLProblem:
    kw.setdefault("total_bandwidth_hz", 100e6)
    kw.setdefault("dataset_total", 600_000)
    return sample_problem(seed, n_devices, **kw)


@register("mega_fleet_100k",
          "Mega fleet: 100 000 devices in a 10 km^2 metro area sharing "
          "1 GHz of OFDMA spectrum; the fused single-level solver's "
          "chunked, element-sharded path solves it in fixed memory "
          "(``solve_joint_fused(..., chunk_elements=...)`` or "
          "``solve_joint_batch(method='fused', chunk_elements=...)``).",
          "beyond-paper", n_devices=100_000)
def _mega_fleet_100k(seed, *, n_devices: int = 100_000,
                     **kw) -> WirelessFLProblem:
    kw.setdefault("area_m", 3163.0)          # ~10 km^2
    kw.setdefault("total_bandwidth_hz", 1e9)
    kw.setdefault("dataset_total", 60_000_000)
    return sample_problem(seed, n_devices, **kw)


@register("metro_1m_users",
          "Metropolitan scale: 1 000 000 devices over 100 km^2 sharing "
          "10 GHz — the ROADMAP's million-user regime.  Solve with "
          "``method='fused'`` and a ``chunk_elements`` bound; anything "
          "that materialises per-instance intermediates at this size "
          "belongs on the chunked path.",
          "beyond-paper", n_devices=1_000_000)
def _metro_1m_users(seed, *, n_devices: int = 1_000_000,
                    **kw) -> WirelessFLProblem:
    kw.setdefault("area_m", 10_000.0)        # 100 km^2
    kw.setdefault("total_bandwidth_hz", 1e10)
    kw.setdefault("dataset_total", 600_000_000)
    return sample_problem(seed, n_devices, **kw)


@register("drifting_metro",
          "Paper-sized metro cell whose Rayleigh channel drifts between "
          "rounds (Gauss-Markov, coherence 0.9 by default): marginally "
          "identical to rayleigh_fading but with corr(g_k, g_{k+1}) = "
          "coherence^2, so successive per-round solves are near-identical "
          "— the warm-start serving regime (slice_round + "
          "solve_joint_fused(init=prev.resume), see docs/serving.md).",
          "beyond-paper (cf. Perazzone et al., arXiv:2201.07912)",
          n_devices=100)
def _drifting_metro(seed, *, n_devices: int = 100, n_rounds: int = 20,
                    coherence: float = 0.9, **kw) -> WirelessFLProblem:
    prob = sample_problem(seed, n_devices, n_rounds=n_rounds, **kw)
    fading = gauss_markov_fading(np.random.default_rng(seed + 104_729),
                                 n_devices, n_rounds, coherence)
    return dataclasses.replace(prob,
                               fading=jnp.asarray(fading, jnp.float32))


@register("drifting_mega_fleet",
          "mega_fleet_100k with Gauss-Markov channel drift (coherence "
          "0.95): 100 000 devices x K correlated rounds.  Stream it "
          "through the fleet service (or slice_round + chunked "
          "solve_joint_fused) to exercise warm starts at mega-fleet "
          "scale.",
          "beyond-paper", n_devices=100_000)
def _drifting_mega_fleet(seed, *, n_devices: int = 100_000,
                         n_rounds: int = 4, coherence: float = 0.95,
                         **kw) -> WirelessFLProblem:
    kw.setdefault("area_m", 3163.0)          # ~10 km^2, as mega_fleet_100k
    kw.setdefault("total_bandwidth_hz", 1e9)
    kw.setdefault("dataset_total", 60_000_000)
    prob = sample_problem(seed, n_devices, n_rounds=n_rounds, **kw)
    fading = gauss_markov_fading(np.random.default_rng(seed + 104_729),
                                 n_devices, n_rounds, coherence)
    return dataclasses.replace(prob,
                               fading=jnp.asarray(fading, jnp.float32))


@register("metro_coupled",
          "Coupled metro tick: 16 paper-like cells (64 devices each) on a "
          "4x4 grid, moderate inter-cell interference plus one shared "
          "backhaul budget sized to bind (~60% of the uncoupled expected "
          "uplink).  Builds a MultiCellProblem — solve with "
          "``core.multicell.solve_coupled`` (or "
          "``FleetControlService.solve_coupled``), not the single-cell "
          "solvers.",
          "beyond-paper (cf. Guo et al., arXiv:2205.09306; Yang et al., "
          "arXiv:1911.02417)", n_devices=16 * 64)
def _metro_coupled(seed, *, n_cells: int = 16, n_devices: int = 64,
                   coupling_gain: float = 2e-13, alpha: float = 2.0,
                   backhaul_fraction: float | None = 0.6,
                   backhaul_bits: float | None = None,
                   **kw) -> MultiCellProblem:
    problems = [sample_problem(seed + 7_001 * c, n_devices, **kw)
                for c in range(n_cells)]
    if backhaul_bits is None and backhaul_fraction is not None:
        # the uncoupled expected uplink is ~2.1 device-uploads per cell
        # under the paper's energy-budget distribution (weakly dependent
        # on n_devices: per-device bandwidth shrinks as fleets grow);
        # 60% of that keeps the knapsack price strictly positive.
        # backhaul_fraction=None drops the shared budget entirely
        # (interference coupling only).
        s_bits = problems[0].grad_size_bits
        backhaul_bits = backhaul_fraction * 2.1 * n_cells * s_bits
    return make_multicell(problems,
                          grid_coupling(n_cells, gain=coupling_gain,
                                        alpha=alpha),
                          backhaul_bits=backhaul_bits)


@register("interference_grid",
          "Interference-limited metro: 16 cells (32 devices each) on a "
          "4x4 grid with strong nearest-neighbour coupling and NO shared "
          "budget — pure interference fixed point, the regime where the "
          "dual-decomposition outer loop needs damping.  Builds a "
          "MultiCellProblem for ``core.multicell.solve_coupled``.",
          "beyond-paper (cf. Guo et al., arXiv:2205.09306)",
          n_devices=16 * 32)
def _interference_grid(seed, *, n_cells: int = 16, n_devices: int = 32,
                       coupling_gain: float = 1e-12, alpha: float = 2.0,
                       **kw) -> MultiCellProblem:
    problems = [sample_problem(seed + 7_001 * c, n_devices, **kw)
                for c in range(n_cells)]
    return make_multicell(problems,
                          grid_coupling(n_cells, gain=coupling_gain,
                                        alpha=alpha))


@register("bandwidth_starved",
          "Rural macro-cell: 32 devices share only 2 MHz with generous "
          "energy budgets (log-uniform in [1, 100] J) — the round deadline "
          "(7c) binds nearly everywhere, so the fp32 payload caps a*_i at "
          "tau/T_i and the joint bit-allocation step (docs/compression.md) "
          "buys participation roughly linearly in 32/b.",
          "beyond-paper", n_devices=32)
def _bandwidth_starved(seed, *, n_devices: int = 32,
                       **kw) -> WirelessFLProblem:
    kw.setdefault("total_bandwidth_hz", 2e6)
    kw.setdefault("energy_budget_range", (1.0, 100.0))
    return sample_problem(seed, n_devices, **kw)


@register("sparse_energy_starved",
          "Sparse IoT fleet: 32 devices over 4 km^2 with per-round energy "
          "budgets log-uniform in [1e-4, 1e-2] J — the energy constraint "
          "(7b), not the time constraint, binds nearly everywhere.",
          "beyond-paper", n_devices=32)
def _sparse_energy_starved(seed, *, n_devices: int = 32,
                           **kw) -> WirelessFLProblem:
    kw.setdefault("area_m", 2000.0)
    kw.setdefault("energy_budget_range", (1e-4, 1e-2))
    return sample_problem(seed, n_devices, **kw)
