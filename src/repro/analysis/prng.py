"""PRNG key-reuse detector: jaxpr-level key equivalence-class tracking.

The RNG-parity contract (``run_fl`` == scan engine == quantized
aggregation, bit-for-bit) currently rests on example-based tests.  This
pass makes the *structural* half machine-checked: no ``random.*``
consumer may be reached by the same key equivalence class twice without
an interleaved ``split`` / ``fold_in``.  That is exactly the bug class
PR 9's quantizer audit found by hand (one subkey feeding both the
participation draw and the quantiser noise).

How it works
------------
``jax.make_jaxpr`` traces the program; the walker interprets the jaxpr
abstractly, mapping every variable that carries PRNG state (typed
``key<fry>`` arrays *or* raw ``uint32[..., 2]`` buffers flowing through
``random_wrap``/``random_unwrap``) to a *key class* — a hashable path
identifying the logical key:

* roots: each distinct input/constant key is its own class;
* ``random_split``: each statically-sliced child gets class
  ``parent + ('split', eqn, i)``; consuming the whole child *array*
  (e.g. vmapped draws) is one consumption of the array's class;
* ``random_fold_in``: ``parent + ('fold', literal)`` — so two
  ``fold_in(k, 1)`` of the same ``k`` correctly *collide*;
* consumption: ``random_bits`` (every jax.random distribution bottoms
  out there); two consumptions of one class = finding.

Control flow: ``pjit``/``closed_call`` sub-jaxprs are walked inline
with the caller's classes and a shared consumption counter.  ``cond``/
``switch`` branches each see a *copy* of the counter and merge by max
(branches are exclusive at runtime).  ``scan``/``while`` bodies run
once with the carry's incoming classes; a key that is consumed in the
body *and* carried through unchanged is flagged as cross-iteration
reuse (iteration 2 would redraw with iteration 1's key).

Limits (documented in docs/analysis.md): dynamic indexing into a split
array yields a fresh conservative class (no reuse detectable through
it); host-side ``numpy.random`` streams are invisible to jaxprs; and
equal *seed literals* at two ``PRNGKey`` call sites are two distinct
roots (intentional — seeding policy is the caller's contract).
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import Counter
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import core as jax_core

__all__ = [
    "KeyReuseFinding",
    "PRNG_PROGRAMS",
    "analyze_jaxpr",
    "check_key_reuse",
    "register_prng_program",
]

KeyClass = tuple  # hashable path, e.g. ('invar', 0, 'split', 17, 1)


class KeyReuseFinding(NamedTuple):
    key_class: str        # printable class path
    n_consumed: int       # number of random_bits consumptions
    sites: tuple[str, ...]  # printable consumption sites
    kind: str             # "reuse" | "carry-reuse"

    def __str__(self) -> str:
        return (f"[{self.kind}] key {self.key_class} consumed "
                f"{self.n_consumed}x at {', '.join(self.sites)}")


def _is_key_aval(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    if dt is None:
        return False
    try:
        return jnp.issubdtype(dt, jax.dtypes.prng_key)
    except TypeError:
        return False


@dataclasses.dataclass
class _State:
    """Mutable walker state shared across inlined sub-jaxprs."""

    consumed: Counter
    sites: dict[KeyClass, list[str]]
    fresh: int = 0

    def consume(self, cls: KeyClass, site: str) -> None:
        self.consumed[cls] += 1
        self.sites.setdefault(cls, []).append(site)

    def fresh_class(self, why: str) -> KeyClass:
        self.fresh += 1
        return ("fresh", why, self.fresh)

    def copy(self) -> "_State":
        st = _State(consumed=Counter(self.consumed),
                    sites={k: list(v) for k, v in self.sites.items()})
        st.fresh = self.fresh
        return st

    def merge_max(self, branches: list["_State"]) -> None:
        """Exclusive control flow: a class's count is the max over
        branches (plus anything new a branch saw)."""
        base = Counter(self.consumed)
        merged: Counter = Counter()
        keys = set(base)
        for b in branches:
            keys |= set(b.consumed)
        for k in keys:
            merged[k] = max([base.get(k, 0)]
                            + [b.consumed.get(k, 0) for b in branches])
        self.consumed = merged
        for b in branches:
            for k, v in b.sites.items():
                mine = self.sites.setdefault(k, [])
                for s in v:
                    if s not in mine:
                        mine.append(s)
            self.fresh = max(self.fresh, b.fresh)


def _read(env: dict, var) -> Any:
    if isinstance(var, jax_core.Literal):
        return None
    return env.get(var)


def _site(eqn, where: str) -> str:
    # source_info_util is private; degrade to the structural path alone
    # if a jax upgrade moves it
    with contextlib.suppress(ImportError, AttributeError):
        from jax._src import source_info_util
        summary = source_info_util.summarize(eqn.source_info)
        if summary:
            return f"{where} ({summary})"
    return where


def _slice_descriptor(eqn) -> Optional[tuple]:
    """Static descriptor of which child a ``slice`` picks from a split
    array: the (axis, start, limit) of every *narrowed* axis.  Under
    ``vmap`` the split axis is not axis 0 (a batch axis leads), so the
    narrowed-axes form is what keeps sibling subkeys distinct."""
    start = eqn.params.get("start_indices")
    limit = eqn.params.get("limit_indices")
    if start is None or limit is None:
        return None
    in_shape = getattr(eqn.invars[0].aval, "shape", None)
    if in_shape is None:
        return None
    narrowed = tuple((ax, int(s), int(lim))
                     for ax, (s, lim, dim) in enumerate(
                         zip(start, limit, in_shape, strict=False))
                     if (lim - s) != dim)
    return narrowed


def _walk(jaxpr, env: dict, state: _State, where: str) -> list:
    """Interpret ``jaxpr`` abstractly; returns outvar values."""
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        invals = [_read(env, v) for v in eqn.invars]

        # higher-order primitives recurse and bind their own outvars
        if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call_jaxpr", "remat_call", "checkpoint"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                sub_jaxpr = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                sub_env = dict(zip(sub_jaxpr.invars, invals, strict=False))
                outs = _walk(sub_jaxpr, sub_env, state,
                             f"{where}/{eqn.params.get('name', prim)}")
                for var, val in zip(eqn.outvars, outs, strict=False):
                    if val is not None:
                        env[var] = val
            continue
        if prim in ("cond", "switch"):
            branches = eqn.params.get("branches", ())
            branch_states, branch_outs = [], []
            for br in branches:
                st = state.copy()
                br_jaxpr = br.jaxpr
                sub_env = dict(zip(br_jaxpr.invars, invals[1:], strict=False))
                branch_outs.append(_walk(br_jaxpr, sub_env, st,
                                         f"{where}/{prim}"))
                branch_states.append(st)
            state.merge_max(branch_states)
            for i, var in enumerate(eqn.outvars):
                vals = [o[i] for o in branch_outs
                        if i < len(o) and o[i] is not None]
                if vals and all(v == vals[0] for v in vals):
                    env[var] = vals[0]
            continue
        if prim == "scan":
            _walk_scan(eqn, invals, env, state, where)
            continue
        if prim == "while":
            _walk_while(eqn, invals, env, state, where)
            continue

        out = None
        if prim == "random_wrap":
            raw = invals[0]
            src = eqn.invars[0]
            if raw is not None:
                out = raw  # re-wrapping a tracked raw buffer: same class
            elif isinstance(src, jax_core.Literal):
                out = ("wrap-lit", repr(getattr(src, "val", None)))
            else:
                out = ("wrap", id(src))
        elif prim == "random_unwrap":
            out = invals[0]
        elif prim == "random_split":
            parent = invals[0] or state.fresh_class(f"split@{where}")
            out = ("splitarr", parent, id(eqn))
        elif prim == "random_fold_in":
            parent = invals[0] or state.fresh_class(f"fold@{where}")
            data = eqn.invars[1]
            if isinstance(data, jax_core.Literal):
                tag = repr(data.val)
            else:
                tag = f"dyn{id(eqn)}"
            out = parent + ("fold", tag)
        elif prim == "random_bits":
            cls = invals[0]
            if cls is not None:
                state.consume(cls, _site(eqn, where))
        elif prim in ("slice", "squeeze", "reshape", "broadcast_in_dim",
                      "transpose", "convert_element_type", "copy",
                      "device_put"):
            val = invals[0]
            if val is not None:
                if prim == "slice" and isinstance(val, tuple) \
                        and val and val[0] == "splitarr":
                    idx = _slice_descriptor(eqn)
                    out = val[1] + ("split", id(eqn.invars[0]), idx) \
                        if idx is not None \
                        else state.fresh_class(f"dynslice@{where}")
                else:
                    out = val
        elif prim in ("select_n", "select"):
            # batched cond/switch threads operands through a select; the
            # class survives only when every selectable case agrees
            cases = invals[1:]
            if cases and all(c is not None and c == cases[0] for c in cases):
                out = cases[0]
        elif prim in ("dynamic_slice", "gather"):
            # data-dependent pick out of a key array: conservative fresh
            # class per eqn (reuse through it is invisible — documented)
            if invals[0] is not None:
                out = state.fresh_class(f"{prim}@{where}")

        if out is not None and eqn.outvars:
            env[eqn.outvars[0]] = out
    return [_read(env, v) for v in jaxpr.outvars]


def _carry_findings(state: _State, in_classes, out_classes, before: Counter,
                    where: str) -> None:
    """A carried key consumed in the body and passed through unchanged
    re-feeds the same class to iteration 2: cross-iteration reuse."""
    for cin, cout in zip(in_classes, out_classes, strict=False):
        if cin is None or cin != cout:
            continue
        if state.consumed.get(cin, 0) > before.get(cin, 0):
            # mark so analyze_jaxpr reports it as carry-reuse
            state.consume(("carry-reuse",) + tuple(cin),
                          f"{where} (carried key consumed in body and "
                          "returned unchanged)")


def _walk_scan(eqn, invals, env, state: _State, where: str) -> None:
    body = eqn.params["jaxpr"].jaxpr
    n_consts = eqn.params["num_consts"]
    n_carry = eqn.params["num_carry"]
    consts = invals[:n_consts]
    carry = invals[n_consts:n_consts + n_carry]
    # xs enter sliced per iteration: track the stacked class itself so a
    # per-iteration slice of a split array keeps its identity
    xs = invals[n_consts + n_carry:]
    sub_env = dict(zip(body.invars, consts + carry + xs, strict=False))
    before = Counter(state.consumed)
    outs = _walk(body, sub_env, state, f"{where}/scan")
    _carry_findings(state, carry, outs[:n_carry], before, f"{where}/scan")
    for var, val in zip(eqn.outvars[:n_carry], outs[:n_carry],
                        strict=False):
        if val is not None:
            env[var] = val


def _walk_while(eqn, invals, env, state: _State, where: str) -> None:
    body = eqn.params["body_jaxpr"].jaxpr
    n_c = eqn.params["body_nconsts"]
    cond_nc = eqn.params["cond_nconsts"]
    carry = invals[cond_nc + n_c:]
    consts = invals[cond_nc:cond_nc + n_c]
    sub_env = dict(zip(body.invars, consts + carry, strict=False))
    before = Counter(state.consumed)
    outs = _walk(body, sub_env, state, f"{where}/while")
    _carry_findings(state, carry, outs, before, f"{where}/while")
    for var, val in zip(eqn.outvars, outs, strict=False):
        if val is not None:
            env[var] = val


def analyze_jaxpr(closed) -> list[KeyReuseFinding]:
    """Walk a ``ClosedJaxpr``; return key-reuse findings (empty = clean)."""
    jaxpr = closed.jaxpr
    env: dict = {}
    for i, var in enumerate(jaxpr.invars):
        if _is_key_aval(var.aval) or _is_raw_key_aval(var.aval):
            env[var] = ("invar", i)
    for i, (var, val) in enumerate(
            zip(jaxpr.constvars, closed.consts, strict=False)):
        if _is_key_aval(var.aval) or _looks_like_raw_key(val):
            env[var] = ("const", i)
    state = _State(consumed=Counter(), sites={})
    _walk(jaxpr, env, state, "<top>")

    findings = []
    for cls, n in sorted(state.consumed.items(), key=repr):
        if cls and cls[0] == "carry-reuse":
            findings.append(KeyReuseFinding(
                key_class=repr(cls[1:]), n_consumed=n,
                sites=tuple(state.sites.get(cls, [])), kind="carry-reuse"))
        elif n >= 2:
            findings.append(KeyReuseFinding(
                key_class=repr(cls), n_consumed=n,
                sites=tuple(state.sites.get(cls, [])), kind="reuse"))
    return findings


def _is_raw_key_aval(aval) -> bool:
    """Raw ``uint32[..., 2]`` buffers are threefry keys by convention."""
    shape = getattr(aval, "shape", None)
    dt = getattr(aval, "dtype", None)
    return (shape is not None and len(shape) >= 1 and shape[-1] == 2
            and dt == jnp.uint32)


def _looks_like_raw_key(val) -> bool:
    try:
        return _is_raw_key_aval(jax.eval_shape(lambda x: x, val))
    except (TypeError, ValueError):
        return False


def check_key_reuse(fn: Callable, *args, **kwargs) -> list[KeyReuseFinding]:
    """Trace ``fn`` on ``args`` and analyze the jaxpr for key reuse."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return analyze_jaxpr(closed)


# --------------------------------------------------------------------------
# registered production programs (the gate's clean set)
# --------------------------------------------------------------------------

PRNG_PROGRAMS: dict[str, Callable[[], list[KeyReuseFinding]]] = {}


def register_prng_program(name: str):
    def wrap(fn):
        PRNG_PROGRAMS[name] = fn
        return fn
    return wrap


def _sweep_static_and_args(*, uplink_bits=None, aggregate: str = "fused",
                           drops: bool = False, donate: bool = False):
    """The jitted scan-engine sweep program plus concrete call args —
    shared by the key-reuse gate and the hygiene donation audit."""
    from repro.analysis.hotpaths import _build_sweep_inputs
    from repro.fl import scan_engine

    plans, train, test, config, params = _build_sweep_inputs(
        uplink_bits=uplink_bits, seeds=[0, 1], aggregate=aggregate)
    if drops:
        import numpy as np
        tables = np.zeros((2, config.n_rounds, plans.probs.shape[2]), bool)
        tables[:, 1, 0] = True
        plans = dataclasses.replace(plans, drops=jnp.asarray(tables))
    static = scan_engine._Static(
        n_rounds=config.n_rounds, batch_per_client=config.batch_per_client,
        aggregate=aggregate, renormalize=config.renormalize,
        include_compute_time=config.include_compute_time,
        eval_rounds=scan_engine._eval_rounds(config), use_kernel=False,
        kernel_interpret=True, donate=donate,
        faulted=plans.drops is not None, quantized=plans.bits is not None)
    fn = scan_engine._sweep_fn(static)
    train_x, train_y = scan_engine._stack_datasets(train)
    test_x, test_y = scan_engine._stack_datasets(test)
    return fn, (plans, params, train_x, train_y, test_x, test_y)


def _sweep_findings(*, uplink_bits, aggregate, drops: bool = False):
    fn, args = _sweep_static_and_args(
        uplink_bits=uplink_bits, aggregate=aggregate, drops=drops)
    return check_key_reuse(fn, *args)


@register_prng_program("scan_engine_sweep")
def _prng_scan_engine():
    """The fused-aggregation sweep: per-round ``split`` stream."""
    return _sweep_findings(uplink_bits=None, aggregate="fused")


@register_prng_program("scan_engine_quantized")
def _prng_scan_engine_quantized():
    """The quantized-uplink sweep: participation draw uses ``sub``, the
    quantiser uses ``fold_in(sub, 1)`` — distinct classes by design
    (the exact invariant PR 9's audit checked by hand)."""
    return _sweep_findings(uplink_bits=8, aggregate="stacked")


@register_prng_program("scan_engine_faulted")
def _prng_scan_engine_faulted():
    """The chaos-harness path: degraded aggregation (drop tables) must
    not disturb the key stream (closed-loop replans replay it)."""
    return _sweep_findings(uplink_bits=None, aggregate="stacked",
                           drops=True)


@register_prng_program("mask_stream")
def _prng_mask_stream():
    """The planner's participation-mask preview (shared with the closed
    loop's drift replans): one subkey per round, no reuse."""
    from repro.fl.scan_engine import _mask_stream

    key0 = jax.random.PRNGKey(0)
    probs = jnp.full((4, 6), 0.3)
    return check_key_reuse(_mask_stream, key0, probs, jnp.int32(0),
                           jnp.int32(2))
