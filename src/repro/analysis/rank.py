"""Rank-contract checker: exhaustive [N]/[N,K] broadcast sweeps.

The ``_bcast_like`` contract (``core.problem`` module docstring) says
every closed form on :class:`WirelessFLProblem` accepts its decision
variables and optional leaves at rank 1 (``[N]``, round-invariant) or
rank 2 (``[N, K]``, per-round), broadcasting 1-d operands across the
round axis.  PRs 5, 7 and 9 each re-fixed a silent violation of this
contract for a *new* leaf — so this pass sweeps every combination
mechanically, with ``N != K`` so that a mixed-up axis can never
broadcast by coincidence.

For every method and every combination of leaf/argument ranks the
checker verifies one of two outcomes:

* the call returns the max-rank shape, and (for elementwise outputs)
  every round column is **bitwise identical** to an independent rank-1
  evaluation on the column-sliced problem — the strongest possible
  statement that rank-2 is "K independent rank-1 problems"; or
* the call raises (shape errors are acceptable for combinations outside
  the documented contract, e.g. a rank-2 ``bits`` table consumed by a
  rank-1 expression — see ``RANK2_NEEDS_RANK2_CONSUMER``).

Silent success with a wrong shape or wrong column values is always a
finding.  A raise on a *supported* combination is also a finding.

Broadcastable leaves are discovered by dataclass introspection (every
non-static field with default ``None``), so a future optional leaf is
swept automatically the day it is added — with the strict contract by
default; extend ``RANK2_NEEDS_RANK2_CONSUMER`` or ``LEAF_SAMPLES`` only
if the new leaf deliberately behaves differently.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, NamedTuple, Optional

import numpy as np

import jax.numpy as jnp

from repro.core.problem import WirelessFLProblem

__all__ = [
    "LEAF_SAMPLES",
    "RANK2_NEEDS_RANK2_CONSUMER",
    "RankFinding",
    "broadcastable_leaves",
    "sweep_rank_contract",
]


class RankFinding(NamedTuple):
    method: str
    leaf_ranks: tuple          # ((leaf, rank|None), ...)
    arg_ranks: tuple           # ((arg, rank), ...)
    kind: str                  # "error" | "shape" | "columns"
    detail: str

    def __str__(self) -> str:
        leaves = ", ".join(f"{n}={r or 'absent'}" for n, r in self.leaf_ranks)
        args = ", ".join(f"{n}@{r}d" for n, r in self.arg_ranks)
        return (f"[{self.kind}] {self.method}({args or '-'}) with "
                f"leaves ({leaves}): {self.detail}")


def broadcastable_leaves(problem_cls=WirelessFLProblem) -> tuple[str, ...]:
    """Optional array leaves: non-static dataclass fields defaulting to
    ``None`` — today fading / interference / bits; future leaves are
    picked up here automatically."""
    names = []
    for f in dataclasses.fields(problem_cls):
        if f.metadata.get("static"):
            continue
        if f.default is None:
            names.append(f.name)
    return tuple(names)


# per-leaf sample value at a given (n,) / (n, k) shape; unknown future
# leaves get a generic positive fill so the sweep still runs
LEAF_SAMPLES: dict[str, Callable[[tuple], np.ndarray]] = {
    "fading": lambda shape: 0.5 + 0.25 * np.arange(
        np.prod(shape), dtype=np.float32).reshape(shape),
    "interference": lambda shape: 1e-13 * (1.0 + np.arange(
        np.prod(shape), dtype=np.float32).reshape(shape)),
    "bits": lambda shape: np.float32(8.0) * (1.0 + (np.arange(
        np.prod(shape), dtype=np.float32).reshape(shape) % 3)),
}

# leaves whose rank-2 form is only contracted to work when the consuming
# expression already runs at rank 2.  Empty today: every current leaf
# (fading, interference, bits) follows the uniform highest-rank rule.
# Add a leaf name here (with a comment saying why) if a future leaf
# deliberately opts out of rank-2 broadcasting.
RANK2_NEEDS_RANK2_CONSUMER: frozenset[str] = frozenset()

# method -> (decision args, output kind)
#   elementwise: [N] or [N, K], column-consistent
#   per_device:  always [N]
#   scalar:      always ()
_METHODS: dict[str, tuple[tuple[str, ...], str]] = {
    "path_gain": ((), "elementwise"),
    "compute_energy": ((), "per_device"),
    "rate": (("power",), "elementwise"),
    "tx_time": (("power",), "elementwise"),
    "upload_energy": (("power",), "elementwise"),
    "round_energy": (("power",), "elementwise"),
    "p_min": (("a",), "elementwise"),
    "objective": (("a",), "scalar"),
    "constraints_satisfied": (("a", "power"), "elementwise"),
}

_ARG_SAMPLES = {
    "a": lambda shape: np.linspace(0.1, 0.9, int(np.prod(shape)),
                                   dtype=np.float32).reshape(shape),
    "power": lambda shape: np.linspace(0.05, 0.8, int(np.prod(shape)),
                                       dtype=np.float32).reshape(shape),
}


def _base_problem(n: int, problem_cls) -> WirelessFLProblem:
    return problem_cls(
        distance_m=jnp.asarray(np.linspace(50.0, 300.0, n), jnp.float32),
        bandwidth_hz=jnp.full((n,), 1e5, jnp.float32),
        energy_budget_j=jnp.full((n,), 5.0, jnp.float32),
        dataset_size=jnp.full((n,), 100.0, jnp.float32),
        cycles_per_sample=jnp.full((n,), 1e4, jnp.float32),
        cpu_hz=jnp.full((n,), 1e9, jnp.float32),
        weights=jnp.full((n,), 1.0 / n, jnp.float32),
        noise_power=1e-12,
        p_max=1.0,
        tau_th=0.5,
        n_rounds=1,
    )


def _leaf_value(name: str, rank: Optional[int], n: int, k: int):
    if rank is None:
        return None
    shape = (n,) if rank == 1 else (n, k)
    sample = LEAF_SAMPLES.get(name, lambda s: np.ones(s, np.float32))
    return jnp.asarray(sample(shape))


def _column_slice(problem: WirelessFLProblem, leaves: dict, col: int,
                  n: int, problem_cls) -> WirelessFLProblem:
    """The rank-1 problem of round ``col``: 2-d leaves sliced, 1-d kept."""
    base = _base_problem(n, problem_cls)
    updates = {}
    for name, val in leaves.items():
        if val is None:
            continue
        updates[name] = val[:, col] if val.ndim == 2 else val
    return dataclasses.replace(base, **updates)


def _supported(leaf_ranks: dict, arg_ranks: dict, method: str) -> bool:
    """Is this combination inside the documented contract?"""
    consumer_rank = max(
        [1]
        + [r for name, r in leaf_ranks.items()
           if r is not None and name not in RANK2_NEEDS_RANK2_CONSUMER]
        + list(arg_ranks.values()))
    return not any(
        r == 2 and name in RANK2_NEEDS_RANK2_CONSUMER and consumer_rank < 2
        for name, r in leaf_ranks.items())


def _bitwise_equal(a: np.ndarray, b: np.ndarray) -> bool:
    if a.shape != b.shape or a.dtype != b.dtype:
        return False
    return bool(np.array_equal(a, b, equal_nan=(a.dtype.kind == "f")))


def sweep_rank_contract(problem_cls=WirelessFLProblem, *,
                        n: int = 3, k: int = 2,
                        methods: Optional[dict] = None
                        ) -> tuple[list[RankFinding], dict]:
    """Sweep every (leaf rank) x (arg rank) combination of every method.

    Returns ``(findings, stats)``; an empty findings list means the
    contract holds.  ``n != k`` is required — with ``n == k`` a
    transposed axis broadcasts silently and the sweep proves nothing.
    """
    if n == k:
        raise ValueError("the sweep needs n != k so mixed-up axes cannot "
                         "broadcast by coincidence")
    leaves = broadcastable_leaves(problem_cls)
    methods = dict(_METHODS if methods is None else methods)
    findings: list[RankFinding] = []
    n_combos = 0

    leaf_states = list(itertools.product([None, 1, 2], repeat=len(leaves)))
    for leaf_ranks_tuple in leaf_states:
        leaf_ranks = dict(zip(leaves, leaf_ranks_tuple, strict=False))
        leaf_vals = {name: _leaf_value(name, r, n, k)
                     for name, r in leaf_ranks.items()}
        problem = dataclasses.replace(
            _base_problem(n, problem_cls),
            **{name: v for name, v in leaf_vals.items() if v is not None})

        for method, (arg_names, out_kind) in methods.items():
            for arg_ranks_tuple in itertools.product(
                    [1, 2], repeat=len(arg_names)):
                arg_ranks = dict(zip(arg_names, arg_ranks_tuple,
                                     strict=False))
                args = [jnp.asarray(_ARG_SAMPLES[name](
                    (n,) if r == 1 else (n, k)))
                    for name, r in arg_ranks.items()]
                n_combos += 1
                record = (method,
                          tuple(sorted(leaf_ranks.items())),
                          tuple(sorted(arg_ranks.items())))
                supported = _supported(leaf_ranks, arg_ranks, method)
                try:
                    out = np.asarray(getattr(problem, method)(*args))
                except Exception as e:  # noqa: BLE001 - any raise is an
                    #                     acceptable contract outcome
                    if supported:
                        findings.append(RankFinding(
                            *record, kind="error",
                            detail=f"{type(e).__name__}: {e}"))
                    continue

                findings.extend(_check_output(
                    record, out, out_kind, problem_cls, leaf_vals,
                    arg_ranks, args, method, n, k))

    stats = {"leaves": list(leaves), "n_combos": n_combos,
             "methods": sorted(methods)}
    return findings, stats


def _expected_rank(leaf_vals: dict, arg_ranks: dict, method: str) -> int:
    """Max rank among rank sources; unknown (future) leaves are assumed
    to influence every elementwise method — the strict default."""
    rank = max([1] + list(arg_ranks.values()))
    influencers = {
        "path_gain": {"fading", "interference"},
        "rate": {"fading", "interference"},
    }.get(method)
    for name, val in leaf_vals.items():
        if val is None or val.ndim < 2:
            continue
        if influencers is not None and name not in influencers:
            continue
        rank = 2
    return rank


def _check_output(record, out, out_kind, problem_cls, leaf_vals,
                  arg_ranks, args, method, n, k) -> list[RankFinding]:
    findings = []
    if out_kind == "scalar":
        if out.shape != ():
            findings.append(RankFinding(
                *record, kind="shape",
                detail=f"expected scalar, got {out.shape}"))
        return findings
    if out_kind == "per_device":
        if out.shape != (n,):
            findings.append(RankFinding(
                *record, kind="shape",
                detail=f"expected ({n},), got {out.shape}"))
        return findings

    expected_rank = _expected_rank(leaf_vals, arg_ranks, method)
    expected_shape = (n,) if expected_rank == 1 else (n, k)
    if out.shape != expected_shape:
        findings.append(RankFinding(
            *record, kind="shape",
            detail=f"expected {expected_shape}, got {out.shape}"))
        return findings
    if expected_rank == 1:
        return findings

    # column consistency: round col of the rank-2 result must be bitwise
    # the rank-1 evaluation on the column-sliced problem
    for col in range(k):
        sliced = _column_slice(None, leaf_vals, col, n, problem_cls)
        col_args = [a[:, col] if a.ndim == 2 else a for a in args]
        try:
            ref = np.asarray(getattr(sliced, method)(*col_args))
        except Exception as e:  # noqa: BLE001 - reported, not raised
            findings.append(RankFinding(
                *record, kind="columns",
                detail=f"column {col} reference eval raised "
                       f"{type(e).__name__}: {e}"))
            continue
        if not _bitwise_equal(ref, out[:, col]):
            findings.append(RankFinding(
                *record, kind="columns",
                detail=f"column {col} differs from the rank-1 "
                       f"evaluation of the column-sliced problem"))
    return findings
