"""Jaxpr-level static analysis gate.

Four passes, each usable standalone and wired into CI by
``tools/run_analysis.py --gate``:

* :mod:`repro.analysis.recompile` — :class:`CompileBudget`, the
  XLA-compilation counter/sentinel.
* :mod:`repro.analysis.hotpaths` — registered production hot paths and
  their steady-state compile budgets (``analysis/budgets.json``).
* :mod:`repro.analysis.prng` — PRNG key-reuse detector over jaxprs.
* :mod:`repro.analysis.rank` — exhaustive [N]/[N,K] rank-contract
  sweeps over ``WirelessFLProblem``.
* :mod:`repro.analysis.hygiene` — host-sync / donation / weak-type
  audits of the traced code.

See ``docs/analysis.md`` for the pass catalog and how to register new
hot paths or problem leaves.
"""
from repro.analysis.hotpaths import (HOT_PATHS, default_budgets_path,
                                     load_budgets, measure, measure_all,
                                     register_hot_path)
from repro.analysis.hygiene import (HygieneFinding, run_hygiene,
                                    scan_host_syncs, weak_scalar_findings)
from repro.analysis.prng import (PRNG_PROGRAMS, KeyReuseFinding,
                                 analyze_jaxpr, check_key_reuse)
from repro.analysis.rank import (RankFinding, broadcastable_leaves,
                                 sweep_rank_contract)
from repro.analysis.recompile import (CompileBudget, CompileBudgetExceeded,
                                      compile_event_count)

__all__ = [
    "HOT_PATHS",
    "PRNG_PROGRAMS",
    "CompileBudget",
    "CompileBudgetExceeded",
    "HygieneFinding",
    "KeyReuseFinding",
    "RankFinding",
    "analyze_jaxpr",
    "broadcastable_leaves",
    "check_key_reuse",
    "compile_event_count",
    "default_budgets_path",
    "load_budgets",
    "measure",
    "measure_all",
    "register_hot_path",
    "run_hygiene",
    "scan_host_syncs",
    "sweep_rank_contract",
    "weak_scalar_findings",
]
