"""Registered hot paths and their compile budgets.

Each entry names a production entry point and knows how to build a
self-contained workload for it: a ``warmup()`` thunk that pays every
expected trace/compile once, and a ``steady()`` thunk that re-runs the
path on *fresh same-shaped inputs* — the state a serving process lives
in.  ``measure()`` wraps both in :class:`~repro.analysis.recompile.
CompileBudget` scopes; the steady-state counts are compared against the
committed ``analysis/budgets.json`` by ``tools/run_analysis.py --gate``
(and by the slow-tier service test).

Registering a new hot path::

    @register_hot_path("my_path", doc="one-line contract")
    def _build_my_path() -> HotPathRun:
        ...build inputs eagerly here (outside the measured scopes)...
        return HotPathRun(warmup=..., steady=...)

The builder runs eagerly *before* either measured scope, so input
construction (device puts, tiny eager ops) never pollutes the counts.
Budgets are steady-state only: warmup compile counts vary with jax
version and backend and are reported, not gated.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import pathlib
from typing import Callable, Optional

import numpy as np

import jax

from repro.analysis.recompile import CompileBudget

__all__ = [
    "HOT_PATHS",
    "HotPath",
    "HotPathRun",
    "default_budgets_path",
    "load_budgets",
    "measure",
    "measure_all",
    "register_hot_path",
]


@dataclasses.dataclass
class HotPathRun:
    """Built workload: warmup pays the compiles, steady must not."""

    warmup: Callable[[], None]
    steady: Callable[[], None]


@dataclasses.dataclass(frozen=True)
class HotPath:
    name: str
    doc: str
    build: Callable[[], HotPathRun]


HOT_PATHS: dict[str, HotPath] = {}


def register_hot_path(name: str, *, doc: str):
    """Decorator registering a hot-path builder under ``name``."""
    def wrap(build: Callable[[], HotPathRun]):
        HOT_PATHS[name] = HotPath(name=name, doc=doc, build=build)
        return build
    return wrap


def measure(name: str) -> dict:
    """Build + run one hot path; returns warmup/steady compile counts."""
    hp = HOT_PATHS[name]
    run = hp.build()
    with CompileBudget(budget=None, strict=False,
                       name=f"{name}:warmup") as warm:
        run.warmup()
    with CompileBudget(budget=None, strict=False,
                       name=f"{name}:steady") as steady:
        run.steady()
    return {
        "doc": hp.doc,
        "warmup_compiles": warm.count,
        "steady_compiles": steady.count,
        "steady_programs": steady.names,
    }


def measure_all(names: Optional[list[str]] = None) -> dict[str, dict]:
    return {name: measure(name) for name in (names or sorted(HOT_PATHS))}


def default_budgets_path() -> pathlib.Path:
    """``analysis/budgets.json`` at the repo root (three levels up from
    this file: src/repro/analysis -> repo)."""
    return (pathlib.Path(__file__).resolve().parents[3]
            / "analysis" / "budgets.json")


def load_budgets(path: Optional[pathlib.Path] = None) -> dict[str, int]:
    with open(path or default_budgets_path()) as fh:
        data = json.load(fh)
    return {k: int(v) for k, v in data["steady_state_compiles"].items()}


# --------------------------------------------------------------------------
# the registered production hot paths
# --------------------------------------------------------------------------

def _two_problems(n: int):
    from repro.core.problem import sample_problem
    return sample_problem(0, n), sample_problem(1, n)


@register_hot_path(
    "solve_joint_fused",
    doc="jitted fused Algorithm-2 solve; zero recompiles across fresh "
        "same-shaped problems (the PR-7 eager-while_loop regression)")
def _build_solve_joint_fused() -> HotPathRun:
    from repro.core.alternating import solve_joint_fused

    prob_a, prob_b = _two_problems(32)
    fn = jax.jit(functools.partial(solve_joint_fused, eps=1e-6,
                                   max_iters=40))

    def warmup():
        jax.block_until_ready(fn(prob_a).a)

    def steady():
        jax.block_until_ready(fn(prob_b).a)

    return HotPathRun(warmup=warmup, steady=steady)


@register_hot_path(
    "solve_joint_batch",
    doc="batched fused solve (the service's _solve payload); zero "
        "recompiles for a fixed (batch, bucket) signature")
def _build_solve_joint_batch() -> HotPathRun:
    from repro.core.batch import pad_batch, solve_joint_batch, stack_problems
    from repro.core.problem import sample_problem

    def batch(seed0: int):
        probs = [sample_problem(seed0 + i, 16 + 4 * i) for i in range(3)]
        return pad_batch(stack_problems(probs), batch_size=4, n_max=32)

    batch_a, batch_b = batch(0), batch(10)

    def warmup():
        jax.block_until_ready(solve_joint_batch(batch_a, method="fused").a)

    def steady():
        jax.block_until_ready(solve_joint_batch(batch_b, method="fused").a)

    return HotPathRun(warmup=warmup, steady=steady)


@register_hot_path(
    "fleet_service_step",
    doc="FleetControlService.step after warmup(): the first live request "
        "and every later one must hit precompiled programs only")
def _build_fleet_service_step() -> HotPathRun:
    from repro.core.problem import sample_problem
    from repro.serve.fleet_service import FleetControlService, ServiceConfig

    service = FleetControlService(ServiceConfig(cost_smoothing=0.0))
    template = sample_problem(0, 24)
    # fresh per-cell problems for two steady rounds: round 2 exercises the
    # warm-start (cached-seed) jit signature on the live path
    rounds = [[sample_problem(100 * r + c, 24) for c in range(3)]
              for r in range(2)]

    def warmup():
        service.warmup(template, max_devices=24)

    def steady():
        now = 0.0
        for round_problems in rounds:
            for c, prob in enumerate(round_problems):
                now += 1e-4
                service.submit(f"cell-{c}", prob, now=now)
            service.step(now=now)

    return HotPathRun(warmup=warmup, steady=steady)


def _build_sweep_inputs(*, uplink_bits: Optional[int], seeds: list[int],
                        aggregate: str):
    """Stacked plans + datasets + params for a tiny scan-engine sweep."""
    from repro.core.problem import sample_problem
    from repro.core.schedulers import UniformScheduler
    from repro.data.synthetic import make_dataset
    from repro.fl.engine import FLConfig
    from repro.fl.scan_engine import (init_sweep_params, plan_trajectory,
                                      stack_plans)

    n, n_rounds = 6, 3
    problem = sample_problem(0, n)
    scheduler = UniformScheduler(m=2)
    train = make_dataset(48, seed=0)
    test = make_dataset(16, seed=1)
    parts = np.array_split(np.arange(48), n)
    configs = [FLConfig(n_rounds=n_rounds, batch_per_client=2, eval_every=2,
                        aggregate=aggregate, uplink_bits=uplink_bits,
                        seed=s) for s in seeds]
    plans = stack_plans([plan_trajectory(problem, scheduler, parts, c)
                         for c in configs])
    params = init_sweep_params(configs)
    return plans, train, test, configs[0], params


@register_hot_path(
    "scan_engine_sweep",
    doc="stacked-trajectory FL sweep: one program per static config; "
        "fresh same-shaped plans reuse it with zero recompiles")
def _build_scan_engine_sweep() -> HotPathRun:
    from repro.fl.scan_engine import run_fl_sweep

    plans_a, train, test, config, params = _build_sweep_inputs(
        uplink_bits=None, seeds=[0, 1], aggregate="fused")
    plans_b, _, _, _, params_b = _build_sweep_inputs(
        uplink_bits=None, seeds=[2, 3], aggregate="fused")

    def warmup():
        run_fl_sweep(plans_a, train, test, config, params, shard=False)

    def steady():
        run_fl_sweep(plans_b, train, test, config, params_b, shard=False)

    return HotPathRun(warmup=warmup, steady=steady)


@register_hot_path(
    "scan_engine_strategies",
    doc="scheduler strategy is plan *data*, not a jit-static: bernoulli/"
        "fixed/uniform trajectories share one program per bucket")
def _build_scan_engine_strategies() -> HotPathRun:
    from repro.core.problem import sample_problem
    from repro.core.schedulers import (DeterministicScheduler,
                                       ProbabilisticScheduler,
                                       UniformScheduler)
    from repro.data.synthetic import make_dataset
    from repro.fl.engine import FLConfig
    from repro.fl.scan_engine import (init_sweep_params, plan_trajectory,
                                      run_fl_sweep, stack_plans)

    n, n_rounds = 6, 3
    problem = sample_problem(0, n)
    train = make_dataset(48, seed=0)
    test = make_dataset(16, seed=1)
    parts = np.array_split(np.arange(48), n)
    config = FLConfig(n_rounds=n_rounds, batch_per_client=2, eval_every=2)

    def stacked(scheduler):
        plan = plan_trajectory(problem, scheduler, parts, config)
        return stack_plans([plan]), init_sweep_params([config])

    warm_inputs = stacked(UniformScheduler(m=2))
    steady_inputs = [stacked(s) for s in (ProbabilisticScheduler(),
                                          DeterministicScheduler())]

    def warmup():
        plans, params = warm_inputs
        run_fl_sweep(plans, train, test, config, params, shard=False)

    def steady():
        for plans, params in steady_inputs:
            run_fl_sweep(plans, train, test, config, params, shard=False)

    return HotPathRun(warmup=warmup, steady=steady)
