"""Recompile sentinel: count XLA compilations per entry point.

The repo's worst perf regression (PR 7) was invisible to every
correctness test: bare ``solve_joint_fused`` re-traced its eager
``while_loop`` on every call, and the C=64 multicell bench died of mmap
exhaustion before any assertion could fire.  This module makes "how
many XLA programs did this block of code build?" a first-class,
assertable quantity.

Mechanism
---------
``jax.monitoring`` emits ``/jax/core/compile/backend_compile_duration``
once per *actual* backend compilation — cache hits (both the in-process
pjit cache and the persistent compilation cache) emit nothing, which is
exactly the semantics a steady-state budget wants.  There is no
listener-removal API on the floor jax (0.4.37), so one module-level
listener appends to a process-global log forever and ``CompileBudget``
scopes itself by log *indices*, never by mutating listener state.

Compiled-program names come from the ``jax._src.dispatch`` debug log
("Finished XLA compilation of jit(<name>) ...") — captured with a
handler only while a ``CompileBudget`` is active, so steady-state
overhead is zero.  Names are best-effort (internal log format); the
*count* is the contract.

Usage::

    with CompileBudget(budget=0, name="steady-state step") as cb:
        service.step()
    # raises CompileBudgetExceeded listing the offending programs

Budgets for the registered hot paths live in ``analysis/budgets.json``
and are enforced by ``tools/run_analysis.py --gate`` (see
``repro.analysis.hotpaths``).
"""
from __future__ import annotations

import logging
import re
import threading
from typing import Optional

import jax

__all__ = [
    "CompileBudget",
    "CompileBudgetExceeded",
    "compile_event_count",
]

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

# process-global, append-only compile log: one entry (duration seconds)
# per backend compilation anywhere in the process
_LOG: list[float] = []
_LOCK = threading.Lock()
_LISTENER_INSTALLED = False


def _on_event_duration(event: str, duration: float, **_kw) -> None:
    if event == _COMPILE_EVENT:
        with _LOCK:
            _LOG.append(duration)


def _ensure_listener() -> None:
    """Install the module-level monitoring listener exactly once.

    jax 0.4.37 has ``clear_event_listeners`` but no selective removal,
    so the listener is permanent; scoping happens via log indices.
    """
    global _LISTENER_INSTALLED
    with _LOCK:
        if _LISTENER_INSTALLED:
            return
        jax.monitoring.register_event_duration_secs_listener(
            _on_event_duration)
        _LISTENER_INSTALLED = True


def compile_event_count() -> int:
    """Total backend compilations observed so far in this process."""
    _ensure_listener()
    with _LOCK:
        return len(_LOG)


# "Finished XLA compilation of jit(solve) in 0.123 sec"
_NAME_RE = re.compile(r"Finished XLA compilation of (?P<name>.+) in ")
_DISPATCH_LOGGER = "jax._src.dispatch"


class _NameCapture(logging.Handler):
    """Collects compiled-program names while attached."""

    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.names: list[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        m = _NAME_RE.search(record.getMessage())
        if m:
            self.names.append(m.group("name"))


class CompileBudgetExceeded(RuntimeError):
    """More XLA compilations happened inside a ``CompileBudget`` block
    than its budget allows."""


class CompileBudget:
    """Context manager that counts XLA compilations in its block.

    ``budget=None`` only measures; an integer budget raises
    ``CompileBudgetExceeded`` on exit when exceeded (unless
    ``strict=False``, for callers that want to inspect ``count``
    themselves — the pytest fixtures do).

    Attributes after exit: ``count`` (backend compilations inside the
    block) and ``names`` (best-effort compiled-program names).
    """

    def __init__(self, budget: Optional[int] = 0, *,
                 name: str = "", strict: bool = True) -> None:
        self.budget = budget
        self.name = name
        self.strict = strict
        self.count: int = 0
        self.names: list[str] = []
        self._start = 0
        self._handler: Optional[_NameCapture] = None
        self._prev_level: Optional[int] = None
        self._prev_propagate: Optional[bool] = None

    def __enter__(self) -> "CompileBudget":
        _ensure_listener()
        logger = logging.getLogger(_DISPATCH_LOGGER)
        self._handler = _NameCapture()
        self._prev_level = logger.level
        self._prev_propagate = logger.propagate
        logger.addHandler(self._handler)
        # the dispatch timers always log; at DEBUG unless jax_log_compiles.
        # Propagation is paused so lowering the level does not spray the
        # debug stream onto the root handlers while we capture.
        if logger.getEffectiveLevel() > logging.DEBUG:
            logger.setLevel(logging.DEBUG)
            logger.propagate = False
        with _LOCK:
            self._start = len(_LOG)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        with _LOCK:
            self.count = len(_LOG) - self._start
        logger = logging.getLogger(_DISPATCH_LOGGER)
        if self._handler is not None:
            self.names = list(self._handler.names)
            logger.removeHandler(self._handler)
            self._handler = None
        if self._prev_level is not None:
            logger.setLevel(self._prev_level)
            self._prev_level = None
        if self._prev_propagate is not None:
            logger.propagate = self._prev_propagate
            self._prev_propagate = None
        if (exc_type is None and self.strict
                and self.budget is not None and self.count > self.budget):
            label = f" [{self.name}]" if self.name else ""
            raise CompileBudgetExceeded(
                f"compile budget exceeded{label}: {self.count} XLA "
                f"compilation(s), budget {self.budget}; programs: "
                f"{self.names or '<names unavailable>'}")
