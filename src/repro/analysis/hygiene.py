"""Hot-path hygiene auditor: host syncs, donation, weak-type forks.

Three cheap static audits over the code that runs inside ``jax.jit``:

* **Host-sync scan** — an AST pass over ``src/repro`` that finds traced
  contexts (functions decorated with ``jax.jit``, or passed to
  ``lax.scan`` / ``while_loop`` / ``fori_loop`` / ``cond`` / ``switch``
  / ``vmap`` / ``grad``, plus everything nested inside them) and flags
  calls that force a device→host transfer mid-trace: ``float(x)``,
  ``x.item()``, ``x.tolist()``, ``np.asarray`` / ``np.array``,
  ``jax.device_get``.  A deliberate sync is waived by putting
  ``# analysis: host-sync-ok`` on the offending line.

* **Donation audit** — lowers the scan-engine sweep program with
  ``donate=True`` and requires one ``tf.aliasing_output`` annotation per
  donated params leaf in the StableHLO text (donation annotations
  survive CPU lowering even though the CPU runtime ignores them, so the
  gate runs anywhere).  A donated-in-name-only signature — declared via
  ``donate_argnums`` but silently dropped by an intermediate wrapper —
  is exactly what this catches.

* **Weak-type audit** — inspects the example argument pytrees of the
  registered hot paths for rank-0 leaves carrying a *strong* default
  dtype (``float32``/``int32``/``float64``/``int64`` with
  ``weak_type=False``).  Such a leaf forks the jit cache against the
  Python-scalar spelling of the same call: ``f(1.0)`` and
  ``f(jnp.float32(1.0))`` compile two programs.  Scalars that are
  jit-static (hashable aux data) never reach this check because they
  are not pytree leaves.

All three return :class:`HygieneFinding` lists; ``run_hygiene`` bundles
them for ``tools/run_analysis.py``.
"""
from __future__ import annotations

import ast
import pathlib
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "HygieneFinding",
    "WAIVER",
    "check_donation",
    "run_hygiene",
    "scan_host_syncs",
    "weak_scalar_findings",
]


WAIVER = "analysis: host-sync-ok"


class HygieneFinding(NamedTuple):
    kind: str        # "host-sync" | "donation" | "weak-type"
    site: str        # file:line or program name
    detail: str

    def __str__(self) -> str:
        return f"[{self.kind}] {self.site}: {self.detail}"


# --------------------------------------------------------------------------
# host-sync AST scan
# --------------------------------------------------------------------------

_TRACING_ENTRY_ATTRS = {
    # attribute names whose callable arguments are traced
    "scan", "while_loop", "fori_loop", "cond", "switch", "map",
    "associative_scan", "custom_root", "custom_linear_solve",
    "vmap", "grad", "value_and_grad", "jit", "checkpoint", "remat",
    "pmap", "jacfwd", "jacrev", "hessian", "custom_jvp", "custom_vjp",
}

_SYNC_BUILTINS = {"float"}
_SYNC_METHODS = {"item", "tolist"}
_NUMPY_ALIASES = {"np", "numpy", "onp"}
_NUMPY_SYNC_FNS = {"asarray", "array"}


def _dec_is_jit(dec: ast.expr) -> bool:
    """Does this decorator expression apply ``jax.jit``?"""
    if isinstance(dec, ast.Name) and dec.id == "jit":
        return True
    if isinstance(dec, ast.Attribute) and dec.attr == "jit":
        return True
    if isinstance(dec, ast.Call):
        # partial(jax.jit, ...) / functools.partial(jit, ...) /
        # jax.jit(static_argnames=...)
        if _dec_is_jit(dec.func):
            return True
        return any(_dec_is_jit(a) for a in dec.args)
    return False


def _call_traces_args(call: ast.Call) -> bool:
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None)
    return name in _TRACING_ENTRY_ATTRS


def _collect_traced_names(tree: ast.AST) -> set[str]:
    """Names of functions handed to tracing entry points anywhere."""
    traced: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_traces_args(node):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    traced.add(arg.id)
    return traced


def _is_literal(node: ast.expr) -> bool:
    try:
        ast.literal_eval(node)
    except (ValueError, TypeError, SyntaxError, MemoryError):
        return False
    return True


def _sync_calls(func: ast.AST, path: pathlib.Path,
                lines: list[str]) -> list[HygieneFinding]:
    findings = []
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        what = None
        if isinstance(fn, ast.Name) and fn.id in _SYNC_BUILTINS:
            if node.args and _is_literal(node.args[0]):
                continue            # float(0.5) is a constant, not a sync
            what = f"{fn.id}() on a (possibly traced) value"
        elif isinstance(fn, ast.Attribute):
            if fn.attr in _SYNC_METHODS:
                what = f".{fn.attr}() forces a device->host transfer"
            elif (fn.attr in _NUMPY_SYNC_FNS
                  and isinstance(fn.value, ast.Name)
                  and fn.value.id in _NUMPY_ALIASES):
                what = (f"{fn.value.id}.{fn.attr}() materialises a traced "
                        "value on the host")
            elif (fn.attr == "device_get"
                  and isinstance(fn.value, ast.Name)
                  and fn.value.id == "jax"):
                what = "jax.device_get() inside a traced context"
        if what is None:
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if WAIVER in line:
            continue
        findings.append(HygieneFinding(
            kind="host-sync",
            site=f"{path}:{node.lineno}",
            detail=what))
    return findings


def scan_host_syncs(root: Optional[pathlib.Path] = None
                    ) -> tuple[list[HygieneFinding], dict]:
    """AST-scan every module under ``root`` (default: ``src/repro``)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[1]
    findings: list[HygieneFinding] = []
    n_traced = 0
    files = sorted(root.rglob("*.py"))
    for path in files:
        if "analysis" in path.parts and path.name != "__init__.py":
            continue        # the auditor's own fixtures are out of scope
        src = path.read_text()
        try:
            tree = ast.parse(src)
        except SyntaxError:
            continue
        lines = src.splitlines()
        traced_names = _collect_traced_names(tree)
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            is_traced = (node.name in traced_names
                         or any(_dec_is_jit(d) for d in node.decorator_list))
            if not is_traced:
                continue
            n_traced += 1
            findings.extend(_sync_calls(node, path, lines))
    stats = {"files_scanned": len(files), "traced_functions": n_traced}
    return findings, stats


# --------------------------------------------------------------------------
# donation audit
# --------------------------------------------------------------------------

def check_donation() -> tuple[list[HygieneFinding], dict]:
    """The scan-engine sweep declares ``donate_argnums=(1,)`` for the
    init-params buffers when built with ``donate=True``.  Require the
    declaration to survive into the lowered StableHLO as one
    ``tf.aliasing_output`` per params leaf, and require the undonated
    build to carry none (a phantom alias would corrupt caller buffers).
    """
    from repro.analysis.prng import _sweep_static_and_args

    findings: list[HygieneFinding] = []
    stats: dict = {}
    for donate in (True, False):
        fn, args = _sweep_static_and_args(donate=donate)
        text = fn.lower(*args).as_text()
        n_alias = text.count("tf.aliasing_output")
        n_leaves = len(jax.tree_util.tree_leaves(args[1]))
        stats["aliased_outputs" if donate else
              "aliased_outputs_undonated"] = n_alias
        if donate and n_alias < n_leaves:
            findings.append(HygieneFinding(
                kind="donation",
                site="fl.scan_engine._sweep_fn(donate=True)",
                detail=f"only {n_alias}/{n_leaves} params leaves carry "
                       "tf.aliasing_output in the lowered module — "
                       "donate_argnums was declared but dropped"))
        if not donate and n_alias != 0:
            findings.append(HygieneFinding(
                kind="donation",
                site="fl.scan_engine._sweep_fn(donate=False)",
                detail=f"{n_alias} aliased output(s) in an undonated "
                       "build — caller buffers would be invalidated"))
    stats["params_leaves"] = len(jax.tree_util.tree_leaves(args[1]))
    return findings, stats


# --------------------------------------------------------------------------
# weak-type audit
# --------------------------------------------------------------------------

_STRONG_DEFAULT_DTYPES = {np.dtype(np.float32), np.dtype(np.int32),
                          np.dtype(np.float64), np.dtype(np.int64)}


def weak_scalar_findings(tree, *, program: str) -> list[HygieneFinding]:
    """Flag rank-0 leaves with a strong default dtype in a jit argument
    pytree: they fork the compile cache against the Python-scalar
    spelling of the same call."""
    findings = []
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    del treedef
    for i, leaf in enumerate(leaves):
        aval = jax.eval_shape(lambda x: x, leaf)
        if aval.shape != ():
            continue
        if getattr(aval, "weak_type", False):
            continue
        if isinstance(leaf, (bool, int, float)):
            continue        # python scalars stay weak under jit
        if jnp.issubdtype(aval.dtype, jax.dtypes.prng_key):
            continue
        if np.dtype(aval.dtype) in _STRONG_DEFAULT_DTYPES:
            findings.append(HygieneFinding(
                kind="weak-type",
                site=f"{program} (leaf {i})",
                detail=f"rank-0 {aval.dtype} leaf with weak_type=False "
                       "forks the jit cache against the python-scalar "
                       "spelling of this argument"))
    return findings


def check_weak_types() -> tuple[list[HygieneFinding], dict]:
    """Audit the argument pytrees of the production entry points whose
    inputs are cheap to build (problem pytrees and sweep plans)."""
    from repro.analysis.prng import _sweep_static_and_args
    from repro.core.batch import pad_batch, stack_problems
    from repro.core.problem import sample_problem

    findings: list[HygieneFinding] = []
    prob = sample_problem(0, 8)
    findings += weak_scalar_findings(prob, program="sample_problem")
    batch = pad_batch(stack_problems([sample_problem(i, 8)
                                      for i in range(2)]),
                      batch_size=2, n_max=8)
    findings += weak_scalar_findings(batch, program="pad_batch")
    _, args = _sweep_static_and_args(donate=False)
    findings += weak_scalar_findings(args, program="scan_engine_sweep args")
    return findings, {"programs_checked": 3}


# --------------------------------------------------------------------------

def run_hygiene() -> dict:
    """All three audits; the shape ``tools/run_analysis.py`` serialises."""
    sync_findings, sync_stats = scan_host_syncs()
    don_findings, don_stats = check_donation()
    weak_findings, weak_stats = check_weak_types()
    findings = sync_findings + don_findings + weak_findings
    return {
        "findings": [str(f) for f in findings],
        "n_findings": len(findings),
        "host_sync": sync_stats,
        "donation": don_stats,
        "weak_type": weak_stats,
    }
