"""Seeded open-loop arrival generation + driver for the fleet service.

Real metro traffic is an *open-loop* arrival process: requests land at
times the service does not control, and a round's solution is worthless
after the channel decorrelates.  This module turns the drifting
scenarios into that traffic shape:

* :func:`make_cells` — a metro area as per-cell drifting trajectories;
* :func:`poisson_trace` — memoryless arrivals at a fixed offered rate;
* :func:`bursty_trace` — ON/OFF (Markov-modulated) bursts separated by
  idle gaps, the priority-lane stressor;
* :func:`drive` — the open-loop driver: submits each arrival at its
  trace time (wall clock, or a deterministic virtual clock) and pumps
  :meth:`FleetControlService.poll` between arrivals;
* :func:`measure_capacity` — the service's sustained full-batch solve
  rate, the denominator for "offered load at 0.8x capacity" tests and
  the ``fleet_service_openloop`` bench.

Everything is seeded: the same ``(cells, trace seed)`` pair replays the
identical request stream, and under ``clock="virtual"`` (plus
``ServiceConfig.cost_smoothing=0``) the service's batch compositions and
counters replay identically too — the golden/determinism suites pin
that.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core.problem import WirelessFLProblem
from repro.core.scenarios import make_problem, slice_round
from repro.serve.fleet_service import FleetControlService, SolveResponse


class Arrival(NamedTuple):
    """One scheduled request: cell ``cell_id``'s drift round ``round_k``
    arriving ``t`` seconds after the trace starts."""

    t: float
    cell_id: int
    round_k: int
    problem: WirelessFLProblem
    deadline_s: Optional[float] = None


def make_cells(n_cells: int, *, n_devices: int = 64, n_rounds: int = 8,
               scenario: str = "drifting_metro", seed: int = 0,
               **overrides) -> list[WirelessFLProblem]:
    """A metro area: per-cell drifting trajectories (seeded)."""
    return [make_problem(scenario, seed=seed + c, n_devices=n_devices,
                         n_rounds=n_rounds, **overrides)
            for c in range(n_cells)]


def _slices(cells: Sequence[WirelessFLProblem]) -> list[list]:
    # pre-slice every (cell, round) problem once; traces then reference
    # them without paying slice_round per arrival
    return [[slice_round(c, k) for k in range(c.fading.shape[1])]
            for c in cells]


def poisson_trace(cells: Sequence[WirelessFLProblem], *, rate_hz: float,
                  n_requests: int, seed: int = 0,
                  deadline_s: Optional[float] = None) -> list[Arrival]:
    """Open-loop Poisson arrivals at offered rate ``rate_hz``.

    Inter-arrival gaps are i.i.d. Exponential(rate); each arrival picks
    a uniformly random cell and consumes that cell's *next* drift round
    (per-cell round counters, wrapping at the trajectory length) — the
    stream a warm-started service should track.
    """
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    sl = _slices(cells)
    counters = [0] * len(cells)
    trace = []
    for t in times:
        c = int(rng.integers(len(cells)))
        k = counters[c] % len(sl[c])
        counters[c] += 1
        trace.append(Arrival(t=float(t), cell_id=c, round_k=k,
                             problem=sl[c][k], deadline_s=deadline_s))
    return trace


def bursty_trace(cells: Sequence[WirelessFLProblem], *,
                 burst_rate_hz: float, burst_len: int, n_bursts: int,
                 idle_s: float, seed: int = 0,
                 deadline_s: Optional[float] = None) -> list[Arrival]:
    """ON/OFF bursty arrivals: ``n_bursts`` bursts of ``burst_len``
    Poisson-at-``burst_rate_hz`` requests, separated by ``idle_s`` idle
    gaps.  Within a burst cells are drawn uniformly; each burst advances
    every cell's channel by (at most) one round, so burst *b* mixes
    drifted cells with cells whose channel the cache still covers — the
    priority-lane stressor.
    """
    rng = np.random.default_rng(seed)
    sl = _slices(cells)
    counters = [0] * len(cells)
    trace = []
    t = 0.0
    for _ in range(n_bursts):
        for _ in range(burst_len):
            t += float(rng.exponential(1.0 / burst_rate_hz))
            c = int(rng.integers(len(cells)))
            k = counters[c] % len(sl[c])
            counters[c] += 1
            trace.append(Arrival(t=t, cell_id=c, round_k=k,
                                 problem=sl[c][k], deadline_s=deadline_s))
        t += idle_s
    return trace


@dataclasses.dataclass
class DriveReport:
    """What one open-loop run produced (stats live on ``service.stats``)."""

    responses: list[SolveResponse]
    wall_s: float                 # driver wall time (submit -> drained)
    offered_rate_hz: float        # arrivals / trace span
    sustained_rate_hz: float      # completions / wall time


def drive(service: FleetControlService, trace: Sequence[Arrival], *,
          clock: str = "wall", tick_s: float = 1e-3,
          reset_stats_after: Optional[int] = None) -> DriveReport:
    """Open-loop driver: arrivals fire at their trace times regardless
    of service progress (the queue grows when the service falls behind —
    that is the point), with ``service.poll`` pumped in between.

    * ``clock="wall"`` — trace offsets map onto ``perf_counter`` time:
      the real load test.  Submission stamps use the *scheduled* arrival
      time, so a lagging driver loop cannot hide queueing delay.
    * ``clock="virtual"`` — time advances only through the trace stamps
      plus fixed ``tick_s`` increments while draining; no sleeping, no
      wall-clock dependence: with ``ServiceConfig.cost_smoothing=0`` the
      whole run (batch composition, counters, deadline misses) is a
      deterministic function of the trace.

    ``reset_stats_after`` resets ``service.stats`` once that many
    responses have completed — the "after the first coherence interval"
    steady-state window of the load suite (caches survive the reset).
    Returns a :class:`DriveReport`; the queue is fully drained on exit
    (virtual drain keeps ticking the close policy forward rather than
    force-closing, so deadline/linger semantics stay in force).
    """
    if clock not in ("wall", "virtual"):
        raise ValueError(f"clock must be 'wall' or 'virtual', got {clock!r}")
    virtual = clock == "virtual"
    responses: list[SolveResponse] = []
    did_reset = reset_stats_after is None
    t_wall0 = time.perf_counter()

    def pump(now):
        nonlocal did_reset
        while True:
            out = service.poll(now if virtual else None)
            if not out:
                return
            responses.extend(out)
            if not did_reset and len(responses) >= reset_stats_after:
                service.stats.reset()
                did_reset = True

    i, now = 0, 0.0
    while i < len(trace):
        if virtual:
            now = trace[i].t
        else:
            # busy-wait to the scheduled arrival (sleep granularity on a
            # loaded runner is worse than the solve cost); poll meanwhile
            while time.perf_counter() - t_wall0 < trace[i].t:
                pump(None)
            now = time.perf_counter() - t_wall0
        # submit EVERY arrival that is due before polling again: after a
        # long solve the backlog must enter the queue as one burst, or
        # the close policy would see (and close) the overdue requests
        # one at a time instead of batching them
        while i < len(trace) and trace[i].t <= now:
            arr = trace[i]
            service.submit(arr.cell_id, arr.problem,
                           deadline_s=arr.deadline_s,
                           now=(arr.t if virtual else t_wall0 + arr.t))
            i += 1
        pump(now)
    # drain: keep advancing the clock so deadline/linger closes fire
    while service.pending:
        if virtual:
            now += tick_s
        pump(now)
    wall_s = time.perf_counter() - t_wall0
    span = max(trace[-1].t, 1e-9) if trace else 1e-9
    return DriveReport(
        responses=responses, wall_s=wall_s,
        offered_rate_hz=len(trace) / span,
        sustained_rate_hz=len(responses) / max(wall_s, 1e-9))


def measure_capacity(service: FleetControlService,
                     problems: Sequence[WirelessFLProblem], *,
                     repeats: int = 3) -> float:
    """Sustained full-batch capacity of the (warmed) service in
    solves/sec: best-of-``repeats`` forced full-batch steps over
    ``problems`` (cycled to ``max_batch``).  Pollutes ``service.stats``
    and the warm caches — call before the measured run and
    ``service.stats.reset()`` after (the load suite and the openloop
    bench both do)."""
    bsz = service.config.max_batch
    best = float("inf")
    for r in range(repeats):
        for i in range(bsz):
            service.submit(("capacity", r, i), problems[i % len(problems)])
        t0 = time.perf_counter()
        while service.pending:
            service.step()
        best = min(best, time.perf_counter() - t0)
    return bsz / best
