"""Open-loop fleet control plane: deadlines, continuous batching, warmup.

The serving problem: a control plane serving many base-station cells
receives a *stream* of per-cell solve requests — "here is my cell's
current channel/energy state, give me (a*, P*) for the next round" — as
an open-loop arrival process.  A round's solution is worthless after the
channel decorrelates, so every request carries a latency budget; the
solvers (``repro.core.batch``) are at their best on big padded batches;
and successive requests from the same cell are nearly identical on a
coherent channel (``drifting_metro``), so most of each solve is
recomputation the warm-start path can skip.

:class:`FleetControlService` packs those observations into one loop:

* **arrival queue + deadlines** — ``submit`` stamps each request with an
  arrival time and an absolute deadline (``deadline_s`` budget, else
  ``ServiceConfig.default_deadline_s``, else unbounded);
* **continuous batching** — requests accumulate until the adaptive
  close policy (:func:`batch_close_reason`, the LLM-serving idiom)
  closes the micro-batch: when it is *full*, when the batch's tightest
  remaining *deadline* budget drops below the bucket's measured solve
  cost (EWMA, :class:`BucketCostModel`), or when the oldest request has
  *lingered* past the latency bound for deadline-less traffic.  ``poll``
  is the non-blocking heartbeat that applies the policy; ``step`` forces
  a close (the legacy synchronous mode); ``run`` drains the queue;
* **priority lanes** — a request whose cell has cached state but whose
  quantised feature key no longer matches it (the channel drifted past
  the quantisation step) enters the priority lane and preempts normal
  traffic: its stale cached solution is the one most urgently wrong;
* **AOT warmup** — ``warmup()`` pre-executes every power-of-two device
  bucket's jit program (cold and warm init signatures) at startup, so no
  live request ever eats a trace/compile;
* **micro-batching** — queued requests with compatible static metadata
  are packed into a padded :class:`~repro.core.batch.ProblemBatch` of
  fixed slot shape (``max_batch`` instance slots, device axis padded to
  a power-of-two bucket via :func:`repro.core.batch.pad_batch`), so jit
  compiles one program per bucket instead of one per request shape;
* **warm starts** — each solved request's ``(a*, P*)`` is cached and fed
  back as ``init`` for the cell's next solve (bit-identical solutions,
  collapsed inner iterations — see ``core.alternating``'s warm-start
  notes), keyed both on quantised problem features
  (:func:`quantized_problem_key`) and per cell;
* **accounting** — sustained solves/sec, p50/p99 request latency,
  deadline-miss rate, preemption and close-reason counters, cache hit
  rates and inner-iteration counts (:class:`ServiceStats`; the
  ``fleet_service_throughput`` / ``fleet_service_openloop`` benchmarks
  and CI gate consume these).

The loop stays deliberately synchronous — the unit of work is one
compiled batched solve, and a thread pump around it would only blur the
accounting.  ``repro.serve.load_gen`` provides the seeded Poisson/bursty
open-loop arrival generator and the driver that calls ``poll``.

Clock domains: with no ``now`` argument everything runs on
``time.perf_counter()`` wall time.  Passing explicit ``now`` stamps to
``submit``/``poll``/``step`` runs the service on a caller-supplied
(virtual) clock — batch composition, deadline misses, and every
non-latency counter then become deterministic functions of the arrival
trace (the golden/determinism suites pin this).  Use one domain
consistently per service instance.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Hashable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alternating import JointSolution, WarmStart
from repro.core.batch import (
    _PAD_VALUES,
    _STATIC_FIELDS,
    pad_batch,
    solve_joint_batch,
    stack_problems,
)
from repro.core.multicell import (
    CoupledDuals,
    MultiCellProblem,
    MultiCellSolution,
    pad_metro,
)
from repro.core.multicell import solve_coupled as solve_coupled_core
from repro.core.problem import WirelessFLProblem

_INF = float("inf")

# close reasons reported by the batch-close policy / ServiceStats
CLOSE_FULL = "full"          # the bucket's instance slots are exhausted
CLOSE_DEADLINE = "deadline"  # tightest budget ~ the bucket's solve cost
CLOSE_LINGER = "linger"      # oldest request hit the linger latency bound
CLOSE_FORCED = "forced"      # explicit step()/run() drain


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the fleet control plane."""

    max_batch: int = 16           # micro-batch instance slots
    min_device_bucket: int = 8    # smallest padded device-axis bucket
    method: str = "fused"         # "fused" | "alternating"
    power_solver: Optional[str] = None   # None => the method's default
    eps: float = 1e-7
    max_iters: int = 50
    warm_start: bool = True       # feed cached solutions back as init
    cache_size: int = 4096        # LRU entries (feature-keyed + per-cell)
    quant_decimals: int = 2       # log10 rounding of the cache key
    latency_window: int = 8192    # latencies kept for the percentiles
    # ---- open-loop control (continuous batching) -----------------------
    default_deadline_s: Optional[float] = None  # per-request budget; None
    #                                            = unbounded (linger rules)
    close_safety: float = 1.5     # close when budget <= safety * est cost
    max_linger_s: float = 5e-3    # universal max wait of the oldest request
    prior_solve_s: float = 5e-3   # cost-model prior before measurements
    cost_smoothing: float = 0.3   # EWMA weight of new measurements; 0
    #                               freezes the prior (deterministic
    #                               close decisions under a virtual clock)
    record_batches: bool = False  # keep a BatchRecord log (golden tests)
    # ---- fault tolerance (docs/robustness.md) --------------------------
    sanitize: bool = True         # map non-finite/non-positive device
    #                               features to self-deselecting no-ops at
    #                               submit (WirelessFLProblem.sanitize)
    retry_unconverged: bool = True  # re-solve an unconverged batch once
    #                                 through the reference path
    retry_max_iters: int = 200    # outer-iteration budget of the retry
    retry_backoff_s: float = 1e-3  # base of the exponential backoff
    #                                *accounted* per consecutive failure
    #                                (no sleeping — determinism)
    breaker_threshold: int = 3    # consecutive failed batches per bucket
    #                               before the circuit breaker opens
    breaker_cooldown: int = 8     # batches shed while the breaker is open


class SolveRequest(NamedTuple):
    cell_id: Hashable
    problem: WirelessFLProblem
    t_submit: float
    t_deadline: float = _INF      # absolute, same clock domain as t_submit
    priority: bool = False        # routed through the priority lane
    fkey: Optional[bytes] = None  # quantised feature key (warm_start only)
    ckey: Optional[tuple] = None  # static-compatibility key (micro-batching)
    seq: int = 0                  # submission order, unique per service
    n_unhealthy: int = 0          # devices degraded to no-ops at submit


class SolveResponse(NamedTuple):
    cell_id: Hashable
    # padding stripped.  NOTE: with the fused method the solver reports
    # one inner-iteration count for the whole flattened element set, so
    # ``solution.inner_iters`` is the *micro-batch total* shared by every
    # response of the batch (per-request attribution does not exist on
    # that path); the alternating method attributes it per instance.
    solution: JointSolution
    warm_started: bool            # solve was seeded from cached state
    cache_hit: bool               # the feature-keyed LRU supplied the seed
    latency_s: float              # submit -> response time (request clock)
    deadline_missed: bool = False  # completed after the request's deadline
    seq: int = 0                  # the request's submission sequence number
    # ---- health/degradation surface (docs/robustness.md) ---------------
    converged: bool = True        # the solver reported convergence for
    #                               this instance (after any retry)
    n_iters: int = 0              # outer iterations attributed to it
    n_unhealthy: int = 0          # devices sanitised to no-ops at submit
    retried: bool = False         # batch was re-solved via the reference
    #                               path after an unconverged first pass
    shed: bool = False            # served degraded (cached-or-zero) by an
    #                               open circuit breaker, not solved


class CoupledResponse(NamedTuple):
    """One served metro tick (:meth:`FleetControlService.solve_coupled`).

    ``solution`` keeps the bucket-padded shapes (padded cells/devices are
    masked out and carry ``a = 0``); ``n_cells`` is the metro's true cell
    count — extract per-cell answers with ``solution.batch.instance(c)``
    for ``c < n_cells``.
    """

    metro_id: Hashable
    solution: MultiCellSolution
    n_cells: int                  # true (unpadded) cell count
    warm_started: bool            # duals seeded from the previous tick
    latency_s: float              # submit -> response time


class BatchRecord(NamedTuple):
    """One served micro-batch (``ServiceConfig.record_batches``): enough
    to replay the exact solve offline — the golden suites rebuild the
    same padded batch from ``seqs`` and compare bitwise."""

    seqs: tuple[int, ...]         # request seqs, slot order
    cell_ids: tuple               # matching cell ids
    n_bucket: int                 # padded device-axis bucket
    reason: str                   # CLOSE_* that closed the batch
    priority: bool                # served from the priority lane


class ServiceStats:
    """Steady-state throughput/latency counters (host-side, cheap)."""

    def __init__(self, latency_window: int = 8192):
        self._window = latency_window
        self.reset()

    def reset(self) -> None:
        """Zero every counter — call after warm-up so compile time does
        not pollute the steady-state figures."""
        self.n_requests = 0
        self.n_solved = 0
        self.n_batches = 0
        self.n_warm = 0
        self.n_cache_hits = 0
        self.n_priority = 0
        self.n_deadline_misses = 0
        self.n_preemptions = 0
        self.closes = collections.Counter()
        self.solve_seconds = 0.0
        self.outer_iters = 0
        self.inner_iters = 0
        self.n_metro_ticks = 0        # coupled multi-cell ticks served
        self.metro_outer_iters = 0    # dual-decomposition iterations
        self.n_metro_warm = 0         # ticks seeded from cached duals
        self.n_metro_caps = 0         # ticks returning best-so-far at cap
        # ---- fault tolerance (docs/robustness.md) -----------------------
        self.n_unconverged = 0        # responses delivered unconverged
        self.n_retries = 0            # batches re-solved via reference path
        self.n_shed = 0               # responses shed by an open breaker
        self.n_unhealthy_devices = 0  # devices sanitised to no-ops
        self.breaker_opens = 0        # circuit-breaker open transitions
        self.retry_backoff_s = 0.0    # accounted (not slept) backoff
        self.latencies = collections.deque(maxlen=self._window)

    # ---- recording (service-internal) ----------------------------------
    def record_batch(self, responses, solve_s: float, outer: int,
                     inner: int, reason: str = CLOSE_FORCED,
                     preempted: bool = False,
                     retried: bool = False) -> None:
        self.n_batches += 1
        self.n_solved += len(responses)
        self.solve_seconds += solve_s
        self.outer_iters += outer
        self.inner_iters += inner
        self.closes[reason] += 1
        self.n_preemptions += bool(preempted)
        self.n_retries += bool(retried)
        for r in responses:
            self.n_warm += bool(r.warm_started)
            self.n_cache_hits += bool(r.cache_hit)
            self.n_deadline_misses += bool(r.deadline_missed)
            self.n_unconverged += not r.converged
            self.n_shed += bool(r.shed)
            self.n_unhealthy_devices += int(r.n_unhealthy)
            self.latencies.append(r.latency_s)

    def record_metro(self, solve_s: float, outer: int,
                     warm: bool, hit_cap: bool = False) -> None:
        """Account one coupled metro tick (no per-request latency — a
        tick is a single synchronous call, not queued traffic)."""
        self.n_metro_ticks += 1
        self.metro_outer_iters += outer
        self.n_metro_warm += bool(warm)
        self.n_metro_caps += bool(hit_cap)
        self.solve_seconds += solve_s

    # ---- derived figures ------------------------------------------------
    @property
    def solves_per_sec(self) -> float:
        return self.n_solved / self.solve_seconds if self.solve_seconds else 0.0

    def latency_percentile(self, q: float) -> float:
        """Latency percentile (seconds) over the sliding sample window.

        Semantics, pinned by ``tests/test_fleet_service.py``:

        * empty window -> ``nan`` — never ``0.0``, which would read as
          "infinitely fast" in dashboards and bench gates;
        * one sample -> that sample, for every ``q``;
        * otherwise numpy's default linear interpolation between order
          statistics (the p50 of two samples is their midpoint);
        * the window keeps the newest ``latency_window`` samples — older
          requests fall off the edge and stop influencing percentiles.
        """
        if not self.latencies:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies), q))

    @property
    def warm_fraction(self) -> float:
        return self.n_warm / self.n_solved if self.n_solved else 0.0

    @property
    def deadline_miss_rate(self) -> float:
        return self.n_deadline_misses / self.n_solved if self.n_solved else 0.0

    @property
    def mean_inner_iters(self) -> float:
        """Mean inner (Algorithm-1) iterations per micro-batch solve —
        the figure warm starts collapse (0.0 in analytic mode)."""
        return self.inner_iters / self.n_batches if self.n_batches else 0.0

    def counter_summary(self) -> dict:
        """The integer counters only — no wall-clock-derived field.

        Under a virtual clock (explicit ``now`` stamps) every entry is a
        deterministic function of the arrival trace; the golden suites
        compare this dict across runs and processes."""
        return {
            "requests": self.n_requests,
            "solved": self.n_solved,
            "batches": self.n_batches,
            "warm": self.n_warm,
            "cache_hits": self.n_cache_hits,
            "priority": self.n_priority,
            "deadline_misses": self.n_deadline_misses,
            "preemptions": self.n_preemptions,
            "closes": dict(self.closes),
            "outer_iters": self.outer_iters,
            "inner_iters": self.inner_iters,
            "metro_ticks": self.n_metro_ticks,
            "metro_outer_iters": self.metro_outer_iters,
            "metro_warm": self.n_metro_warm,
            "metro_caps": self.n_metro_caps,
            "unconverged": self.n_unconverged,
            "retries": self.n_retries,
            "shed": self.n_shed,
            "unhealthy_devices": self.n_unhealthy_devices,
            "breaker_opens": self.breaker_opens,
        }

    def summary(self) -> dict:
        return {
            "requests": self.n_requests,
            "solved": self.n_solved,
            "batches": self.n_batches,
            "solves_per_sec": self.solves_per_sec,
            "p50_latency_s": self.latency_percentile(50),
            "p99_latency_s": self.latency_percentile(99),
            "warm_fraction": self.warm_fraction,
            "cache_hit_fraction": (self.n_cache_hits / self.n_solved
                                   if self.n_solved else 0.0),
            "deadline_miss_rate": self.deadline_miss_rate,
            "preemptions": self.n_preemptions,
            "priority_fraction": (self.n_priority / self.n_requests
                                  if self.n_requests else 0.0),
            "closes": dict(self.closes),
            "mean_outer_iters": (self.outer_iters / self.n_batches
                                 if self.n_batches else 0.0),
            "mean_inner_iters": self.mean_inner_iters,
            "metro_ticks": self.n_metro_ticks,
            "mean_metro_outer_iters": (self.metro_outer_iters
                                       / self.n_metro_ticks
                                       if self.n_metro_ticks else 0.0),
            "metro_warm_fraction": (self.n_metro_warm / self.n_metro_ticks
                                    if self.n_metro_ticks else 0.0),
            "metro_caps": self.n_metro_caps,
            "unconverged": self.n_unconverged,
            "retries": self.n_retries,
            "shed": self.n_shed,
            "unhealthy_devices": self.n_unhealthy_devices,
            "breaker_opens": self.breaker_opens,
            "retry_backoff_s": self.retry_backoff_s,
        }


# the per-device leaves that discriminate problems; fading is appended
# when present.  Raw leaves rather than derived path gain / compute
# energy: same information, no recomputation on the request path.
_KEY_FIELDS = ("distance_m", "bandwidth_hz", "energy_budget_j",
               "dataset_size", "cycles_per_sample", "cpu_hz", "weights")


def _quantize(arr: np.ndarray, decimals: int) -> np.ndarray:
    return np.round(np.log10(np.maximum(np.abs(arr), 1e-300)), decimals)


def quantized_problem_key(problem: WirelessFLProblem,
                          decimals: int = 2) -> bytes:
    """Cache key: the problem's constraint data, log-quantised.

    Two problems map to the same key iff every per-device feature
    (distances, bandwidths, energy budgets, compute parameters, weights,
    fading) rounds to the same ``decimals`` digits in log10 and the
    static metadata matches exactly.  On a drifting channel this buckets
    "the same cell a moment later" together while separating genuinely
    different problems; the log domain makes the tolerance relative
    (energy budgets span 1e-4..1e2 J).
    """
    h = hashlib.sha1()
    h.update(repr([(f, getattr(problem, f))
                   for f in _STATIC_FIELDS]).encode())
    feats = [getattr(problem, f) for f in _KEY_FIELDS]
    if problem.fading is not None:
        feats.append(problem.fading)
    if problem.interference is not None:
        # the noise floor shifts the solution like any other feature;
        # offset by sigma^2 so log-quantisation stays relative to the
        # total noise (a zero-interference leaf keys like None modulo
        # the shape marker below)
        feats.append(np.asarray(problem.interference, np.float64)
                     + problem.noise_power)
        h.update(repr(problem.interference.shape).encode())
    if problem.bits is not None:
        # the payload scale changes tx time / P^min like bandwidth does;
        # shape marker separates an all-32 leaf from a bits=None problem
        # (their solutions coincide but their compiled programs differ)
        feats.append(np.asarray(problem.bits, np.float64))
        h.update(repr(problem.bits.shape).encode())
    for x in feats:
        q = _quantize(np.asarray(x, np.float64), decimals)
        h.update(repr(q.shape).encode())
        h.update(np.ascontiguousarray(q).tobytes())
    return h.digest()


def _compat_key(problem: WirelessFLProblem) -> tuple:
    """Requests sharing this key can be stacked into one ProblemBatch."""
    return (tuple(getattr(problem, f) for f in _STATIC_FIELDS),
            problem.fading is not None,
            None if problem.fading is None else problem.fading.shape[1],
            None if problem.interference is None
            else problem.interference.ndim,
            None if problem.bits is None else problem.bits.ndim)


def _next_pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two >= ``max(n, floor, 1)``.

    The floor itself is rounded *up* to a power of two (``floor=12``
    yields 16, never 12), so every bucket the service registers — and
    ``warmup`` pre-compiles — is a true power of two.  Pinned by unit
    tests in ``tests/test_fleet_service.py``.
    """
    return 1 << (max(n, floor, 1) - 1).bit_length()


def batch_close_reason(batch: Sequence[SolveRequest], now: float,
                       est_cost_s: float,
                       config: ServiceConfig) -> Optional[str]:
    """The adaptive batch-close policy (continuous-batching idiom).

    Given the candidate micro-batch ``batch`` (the FIFO head-compatible
    prefix of one lane), decide whether it must close *now* rather than
    keep accumulating arrivals:

    * :data:`CLOSE_FULL` — all ``max_batch`` instance slots are taken;
      waiting longer cannot improve amortisation.
    * :data:`CLOSE_DEADLINE` — the tightest remaining budget
      ``min(deadline) - now`` has dropped to ``close_safety`` times the
      bucket's estimated solve cost: closing any later would make that
      request infeasible even with a perfect solve.  With continuous
      polling and an accurate estimate, a request whose budget covered
      the solve cost at submission is therefore *never* closed after its
      deadline (property-tested).
    * :data:`CLOSE_LINGER` — the oldest request has waited
      ``max_linger_s``, the universal wait bound: sparse traffic (and
      deadline-less traffic in particular) gets predictable latency
      instead of waiting forever for a full bucket.  Under load this
      rule stops firing on its own — the backlog reaches ``max_batch``
      between solves and the *full* rule takes over, which is exactly
      the continuous-batching degradation curve (small batches / low
      latency when idle, full buckets at saturation).

    Pure host-side function of (batch, clock, cost estimate, config) —
    the hypothesis suite drives it directly.  Returns the close reason,
    or ``None`` to keep accumulating.
    """
    if not batch:
        return None
    if len(batch) >= config.max_batch:
        return CLOSE_FULL
    budget = min(r.t_deadline for r in batch) - now
    if budget <= est_cost_s * config.close_safety:
        return CLOSE_DEADLINE
    if now - batch[0].t_submit >= config.max_linger_s:
        return CLOSE_LINGER
    return None


class BucketCostModel:
    """EWMA of measured per-bucket solve wall time (seconds).

    The close policy needs "how long will this bucket's solve take" to
    spend a request's remaining budget accumulating arrivals instead of
    closing too early.  Estimates start at ``prior_s`` and track
    measurements with weight ``alpha``; ``alpha=0`` freezes the prior,
    making close decisions a deterministic function of the arrival trace
    (the golden/determinism suites run in that mode).
    """

    def __init__(self, prior_s: float, alpha: float):
        self.prior_s = float(prior_s)
        self.alpha = float(alpha)
        self._est: dict[int, float] = {}

    def estimate(self, bucket: int) -> float:
        return self._est.get(bucket, self.prior_s)

    def observe(self, bucket: int, seconds: float) -> None:
        if self.alpha <= 0.0:
            return
        prev = self._est.get(bucket)
        self._est[bucket] = seconds if prev is None else \
            (1.0 - self.alpha) * prev + self.alpha * seconds

    def scale(self, factor: float) -> None:
        """Multiply the prior and every estimate by ``factor`` — the
        chaos harness's cost-spike hook (``repro.serve.faults``): an
        inflated estimate makes the close policy fire CLOSE_DEADLINE
        early, which is exactly how a real cost-model excursion degrades
        batching.  Measurements pull the estimates back (EWMA)."""
        self.prior_s *= float(factor)
        for bucket in self._est:
            self._est[bucket] *= float(factor)


class _LRU:
    """Tiny ordered-dict LRU (host-side; values are small jnp arrays)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: collections.OrderedDict = collections.OrderedDict()

    def get(self, key):
        if key not in self._d:
            return None
        self._d.move_to_end(key)
        return self._d[key]

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def __len__(self) -> int:
        return len(self._d)


def _resize_problem(problem: WirelessFLProblem,
                    n: int) -> WirelessFLProblem:
    """A copy of ``problem`` with exactly ``n`` devices (leaves truncated
    or cyclically tiled).  ``warmup``'s dummy-instance builder: the
    values only pin jit input shapes/dtypes, never answers."""
    kw = {}
    for f in _PAD_VALUES:
        v = np.asarray(getattr(problem, f))
        kw[f] = jnp.asarray(np.resize(v, (n,) + v.shape[1:]))
    fad = problem.fading
    if fad is not None:
        fad = np.asarray(fad)
        fad = jnp.asarray(np.resize(fad, (n,) + fad.shape[1:]))
    itf = problem.interference
    if itf is not None:
        itf = np.asarray(itf)
        itf = jnp.asarray(np.resize(itf, (n,) + itf.shape[1:]))
    bits = problem.bits
    if bits is not None:
        bits = np.asarray(bits)
        bits = jnp.asarray(np.resize(bits, (n,) + bits.shape[1:]))
    return dataclasses.replace(problem, fading=fad, interference=itf,
                               bits=bits, **kw)


class FleetControlService:
    """The open-loop, continuously-batching, warm-starting control plane."""

    def __init__(self, config: Optional[ServiceConfig] = None):
        self.config = config = config if config is not None else ServiceConfig()
        self.stats = ServiceStats(config.latency_window)
        # two arrival lanes; the priority lane preempts the normal one
        self._queue: collections.deque[SolveRequest] = collections.deque()
        self._prio: collections.deque[SolveRequest] = collections.deque()
        # feature-keyed LRU: quantised problem -> WarmStart (unpadded)
        self._feature_cache = _LRU(config.cache_size)
        # per-cell last solution: the fallback seed when the channel
        # drifted past the quantisation step (new feature key)
        self._cell_cache = _LRU(config.cache_size)
        # per-cell last feature key — the drift detector feeding the
        # priority lane (cached state exists but its key went stale)
        self._cell_fkey = _LRU(config.cache_size)
        self._cost = BucketCostModel(config.prior_solve_s,
                                     config.cost_smoothing)
        # per-metro dual/warm state: metro_id -> CoupledDuals of the last
        # tick (padded bucket shapes; shape-checked on reuse)
        self._metro_duals = _LRU(config.cache_size)
        self.warmed_buckets: set[int] = set()   # AOT-precompiled buckets
        self.buckets_used: set[int] = set()     # buckets served so far
        self.batch_log: list[BatchRecord] = []  # when record_batches
        self._seq = 0
        # per-bucket circuit breaker: consecutive unconverged batches,
        # and remaining shed-batches while the breaker is open
        self._fail_streak: dict[int, int] = {}
        self._breaker_open: dict[int, int] = {}

    # ------------------------------------------------------------- warmup
    def warmup(self, template: WirelessFLProblem, *,
               max_devices: Optional[int] = None,
               warm: Optional[bool] = None) -> dict[int, float]:
        """AOT-precompile every power-of-two device bucket up front.

        Executes one dummy padded solve per (bucket, cold/warm-init)
        jit signature — ``template`` pins the request leaf dtypes and
        fading shape (pass a ``slice_round`` problem when serving sliced
        rounds), buckets run from ``min_device_bucket`` up to
        ``_next_pow2(max_devices)`` (default: the template's fleet
        size).  After warmup no live request pays a trace/compile: the
        first request's latency sits within the steady-state band
        (asserted by the warmup test and the openloop bench gate).

        ``stats`` are untouched; the caches are untouched (the dummy
        solves bypass the request path).  Returns ``{bucket: seconds}``
        (compile + execute wall time per bucket).
        """
        cfg = self.config
        hi = _next_pow2(max(max_devices or 0, template.n_devices),
                        cfg.min_device_bucket)
        warm = cfg.warm_start if warm is None else warm
        timings: dict[int, float] = {}
        b = _next_pow2(1, cfg.min_device_bucket)
        while b <= hi:
            prob = _resize_problem(template, b)
            batch = pad_batch(stack_problems([prob]),
                              batch_size=cfg.max_batch, n_max=b)
            t0 = time.perf_counter()
            jax.block_until_ready(self._solve(batch, init=None).a)
            if warm:
                z = jnp.zeros(self._sol_shape(batch), jnp.float32)
                jax.block_until_ready(
                    self._solve(batch, init=WarmStart(a=z, power=z)).a)
            timings[b] = time.perf_counter() - t0
            self.warmed_buckets.add(b)
            b *= 2
        return timings

    # ------------------------------------------------------------- intake
    def submit(self, cell_id: Hashable, problem: WirelessFLProblem, *,
               deadline_s: Optional[float] = None,
               priority: Optional[bool] = None,
               now: Optional[float] = None) -> SolveRequest:
        """Queue one per-cell solve request.

        ``deadline_s`` is the request's latency budget (defaults to
        ``ServiceConfig.default_deadline_s``; ``None`` = unbounded).
        ``priority=None`` auto-routes: a cell whose cached solution's
        feature key no longer matches the incoming problem has drifted
        past the quantisation step and jumps the priority lane (its
        cached answer is the most urgently wrong one).  ``now`` pins the
        arrival stamp for virtual-clock runs.

        With ``ServiceConfig.sanitize`` (the default), devices whose
        features are non-finite or non-positive — a corrupted channel, a
        deep fade to zero gain — are degraded to self-deselecting no-ops
        (``a = 0``, zero power) *before* the request enters the queue,
        so one poisoned device cannot NaN a whole micro-batch.  The
        count lands on ``SolveRequest.n_unhealthy`` and the response;
        a fully healthy problem takes this path untouched (bitwise).
        """
        now = time.perf_counter() if now is None else now
        cfg = self.config
        n_unhealthy = 0
        if cfg.sanitize:
            # host-side health check first: the all-healthy hot path
            # never allocates a sanitised copy
            health = problem.health_mask(xp=np)
            if not health.all():
                n_unhealthy = int(health.size) - int(health.sum())
                problem, _ = problem.sanitize(health=jnp.asarray(health))
        fkey = quantized_problem_key(problem, cfg.quant_decimals) \
            if cfg.warm_start else None
        if priority is None:
            last = self._cell_fkey.get(cell_id) if fkey is not None else None
            priority = last is not None and last != fkey
        if deadline_s is None:
            deadline_s = cfg.default_deadline_s
        self._seq += 1
        req = SolveRequest(
            cell_id=cell_id, problem=problem, t_submit=now,
            t_deadline=_INF if deadline_s is None else now + deadline_s,
            priority=bool(priority), fkey=fkey,
            ckey=_compat_key(problem), seq=self._seq,
            n_unhealthy=n_unhealthy)
        self.stats.n_requests += 1
        self.stats.n_priority += bool(req.priority)
        (self._prio if req.priority else self._queue).append(req)
        return req

    @property
    def pending(self) -> int:
        return len(self._prio) + len(self._queue)

    # ------------------------------------------------------------ serving
    def _eligible(self, lane) -> list[SolveRequest]:
        """The micro-batch that *would* close: the first ``max_batch``
        requests of ``lane`` stackable with its head (same static
        metadata / fading-ness), in FIFO order, without popping."""
        if not lane:
            return []
        key = lane[0].ckey
        out = []
        for req in lane:
            if req.ckey == key:
                out.append(req)
                if len(out) >= self.config.max_batch:
                    break
        return out

    def _take_micro_batch(self, lane) -> list[SolveRequest]:
        """Pop the ``_eligible`` requests; later incompatible requests
        keep their lane order."""
        if not lane:
            return []
        key = lane[0].ckey
        taken: list[SolveRequest] = []
        kept: collections.deque = collections.deque()
        while lane and len(taken) < self.config.max_batch:
            req = lane.popleft()
            (taken if req.ckey == key else kept).append(req)
        kept.extend(lane)
        lane.clear()
        lane.extend(kept)
        return taken

    def poll(self, now: Optional[float] = None) -> list[SolveResponse]:
        """The open-loop heartbeat: serve at most one micro-batch *iff*
        a lane's close condition holds (:func:`batch_close_reason`;
        priority lane checked first), else return ``[]`` immediately.

        Call it from the arrival driver between submissions.  ``now``
        runs the check (and stamps completions) on a virtual clock;
        omitted, wall ``perf_counter`` time is used throughout.
        """
        t = time.perf_counter() if now is None else now
        for lane, is_prio in ((self._prio, True), (self._queue, False)):
            elig = self._eligible(lane)
            if not elig:
                continue
            bucket = _next_pow2(max(r.problem.n_devices for r in elig),
                                self.config.min_device_bucket)
            reason = batch_close_reason(elig, t, self._cost.estimate(bucket),
                                        self.config)
            if reason is not None:
                return self._serve(self._take_micro_batch(lane), reason,
                                   priority_lane=is_prio, now=now)
        return []

    def step(self, now: Optional[float] = None) -> list[SolveResponse]:
        """Force-close one micro-batch (priority lane first) regardless
        of the close policy — the legacy synchronous mode, and the drain
        path (:data:`CLOSE_FORCED`)."""
        lane, is_prio = (self._prio, True) if self._prio \
            else (self._queue, False)
        reqs = self._take_micro_batch(lane)
        if not reqs:
            return []
        return self._serve(reqs, CLOSE_FORCED, priority_lane=is_prio,
                           now=now)

    def run(self, requests=None) -> list[SolveResponse]:
        """Submit ``requests`` (``(cell_id, problem)`` pairs, optional)
        and drain the queue with forced closes; responses in completion
        order (priority lane first)."""
        for cell_id, problem in (requests or []):
            self.submit(cell_id, problem)
        out = []
        while self.pending:
            out.extend(self.step())
        return out

    # ------------------------------------------------------------ resume
    def seed_cell(self, cell_id: Hashable, problem: WirelessFLProblem,
                  solution) -> None:
        """Re-seed the warm caches from an externally held solution.

        The crash-recovery hook (``fl.closed_loop`` checkpoint resume):
        a fresh service re-seeded with round k's checkpointed problem
        and solution warm-starts round k+1 exactly as the uninterrupted
        service would have — same seeds, same warm/cache-hit counters.
        ``solution`` is anything with ``.a`` / ``.power`` (a
        :class:`~repro.core.alternating.JointSolution` or ``WarmStart``).
        No-op when warm starts are disabled.
        """
        if not self.config.warm_start:
            return
        if self.config.sanitize:
            # mirror submit(): the caches are keyed on the sanitised
            # problem, so the seed must be too
            health = problem.health_mask(xp=np)
            if not health.all():
                problem, _ = problem.sanitize(health=jnp.asarray(health))
        fkey = quantized_problem_key(problem, self.config.quant_decimals)
        state = WarmStart(a=jnp.asarray(solution.a),
                          power=jnp.asarray(solution.power))
        self._feature_cache.put(fkey, state)
        self._cell_cache.put(cell_id, state)
        self._cell_fkey.put(cell_id, fkey)

    # ---------------------------------------------------- coupled metros
    def solve_coupled(self, metro_id: Hashable, metro: MultiCellProblem, *,
                      outer_iters: int = 25, outer_tol: float = 1e-3,
                      damping: float = 0.5) -> CoupledResponse:
        """Serve one coupled metro tick (``core.multicell.solve_coupled``).

        A metro tick is one synchronous unit of work — C cells coupled by
        interference and/or a shared backhaul budget cannot be answered
        per-cell, so it bypasses the per-request queue and runs the
        dual-decomposition loop directly, reusing the service machinery:

        * **buckets** — the metro is padded to power-of-two (cell,
          device) slot shapes via :func:`repro.core.multicell.pad_metro`,
          so jit compiles once per bucket across metros of drifting size;
        * **warm duals** — the converged ``(I, mu)`` prices and element
          iterates are cached per ``metro_id`` and seed the next tick
          (``CoupledDuals``); on a coherent channel the outer loop then
          collapses to one or two iterations (shape-mismatched state is
          dropped, so metro reconfigurations just run cold);
        * **accounting** — ``stats`` gains ``metro_ticks`` /
          ``metro_outer_iters`` / ``metro_warm`` counters.

        Uses the service's configured method/power solver/warm-start
        policy; ``outer_*`` and ``damping`` are per-call because the
        coupling strength is a property of the metro, not the service.
        """
        cfg = self.config
        t0 = time.perf_counter()
        n_cells = metro.n_cells
        bucket_c = _next_pow2(n_cells)
        bucket_n = _next_pow2(metro.cells.n_max, cfg.min_device_bucket)
        padded = pad_metro(metro, n_cells=bucket_c, n_max=bucket_n)
        per_round = padded.cells.problem.fading is not None
        i_shape = (bucket_c, padded.cells.problem.fading.shape[-1]) \
            if per_round else (bucket_c,)
        init: Optional[CoupledDuals] = \
            self._metro_duals.get(metro_id) if cfg.warm_start else None
        if init is not None and np.shape(init.interference) != i_shape:
            init = None               # metro resized: run cold
        sol = solve_coupled_core(
            padded, outer_iters=outer_iters, outer_tol=outer_tol,
            damping=damping, method=cfg.method,
            power_solver=cfg.power_solver, eps=cfg.eps,
            max_iters=cfg.max_iters, warm_start=cfg.warm_start, init=init,
            sanitize=cfg.sanitize)
        jax.block_until_ready(sol.batch.a)
        t1 = time.perf_counter()
        if cfg.warm_start:
            self._metro_duals.put(metro_id, sol.resume)
        self.buckets_used.add(bucket_n)
        self.stats.record_metro(t1 - t0, sol.outer_iters,
                                warm=init is not None,
                                hit_cap=sol.hit_iter_cap)
        return CoupledResponse(metro_id=metro_id, solution=sol,
                               n_cells=n_cells,
                               warm_started=init is not None,
                               latency_s=t1 - t0)

    # ------------------------------------------------------------- solve
    def _sol_shape(self, batch) -> tuple:
        return batch.mask.shape if batch.problem.fading is None \
            else batch.mask.shape + (batch.problem.fading.shape[-1],)

    def _solve(self, batch, init):
        cfg = self.config
        return solve_joint_batch(batch, method=cfg.method,
                                 power_solver=cfg.power_solver,
                                 eps=cfg.eps, max_iters=cfg.max_iters,
                                 init=init)

    def _lookup_seed(self, cell_id, fkey: bytes,
                     shape) -> tuple[Optional[WarmStart], bool]:
        """(seed, from_feature_cache) for one request, shape-checked."""
        seed = self._feature_cache.get(fkey)
        if seed is not None and seed.a.shape == shape:
            return seed, True
        seed = self._cell_cache.get(cell_id)
        if seed is not None and seed.a.shape == shape:
            return seed, False
        return None, False

    def _shed(self, reqs: list[SolveRequest], reason: str, bucket: int, *,
              priority_lane: bool,
              now: Optional[float] = None) -> list[SolveResponse]:
        """Degraded service while the bucket's circuit breaker is open:
        answer from the per-cell cache where a shape-matched solution
        exists, zeros (total self-deselection) otherwise — never a solve.
        Every response carries ``shed=True`` and ``converged=False``; the
        drain loops keep their liveness (requests always complete)."""
        t_done = time.perf_counter() if now is None else now
        responses = []
        for req in reqs:
            n = req.problem.n_devices
            shape = (n,) if req.problem.fading is None \
                else (n, req.problem.fading.shape[1])
            seed = self._cell_cache.get(req.cell_id)
            cached = seed is not None and seed.a.shape == shape
            a = np.asarray(seed.a) if cached else np.zeros(shape, np.float32)
            p = np.asarray(seed.power) if cached \
                else np.zeros(shape, np.float32)
            inst = JointSolution(
                a=jnp.asarray(a), power=jnp.asarray(p),
                objective=jnp.float32(0.0), n_iters=jnp.int32(0),
                converged=jnp.asarray(False), inner_iters=jnp.int32(0))
            responses.append(SolveResponse(
                cell_id=req.cell_id, solution=inst, warm_started=cached,
                cache_hit=False, latency_s=t_done - req.t_submit,
                deadline_missed=t_done > req.t_deadline, seq=req.seq,
                converged=False, n_iters=0, n_unhealthy=req.n_unhealthy,
                retried=False, shed=True))
        if self.config.record_batches:
            self.batch_log.append(BatchRecord(
                seqs=tuple(r.seq for r in reqs),
                cell_ids=tuple(r.cell_id for r in reqs),
                n_bucket=bucket, reason=reason, priority=priority_lane))
        self.stats.record_batch(responses, 0.0, 0, 0, reason=reason,
                                preempted=False)
        return responses

    def _serve(self, reqs: list[SolveRequest], reason: str, *,
               priority_lane: bool,
               now: Optional[float] = None) -> list[SolveResponse]:
        """Pack one micro-batch, warm-start, solve, account."""
        cfg = self.config
        virtual = now is not None
        # a priority batch preempts whenever normal traffic is left waiting
        preempted = priority_lane and bool(self._queue)
        bucket = _next_pow2(max(r.problem.n_devices for r in reqs),
                            cfg.min_device_bucket)
        # open circuit breaker: shed this batch, burn one cooldown tick;
        # at zero the next batch is the half-open probe (a real solve)
        if self._breaker_open.get(bucket, 0) > 0:
            self._breaker_open[bucket] -= 1
            return self._shed(reqs, reason, bucket,
                              priority_lane=priority_lane, now=now)
        t0 = time.perf_counter()

        batch = stack_problems([r.problem for r in reqs])
        batch = pad_batch(batch, batch_size=cfg.max_batch, n_max=bucket)
        sizes = [r.problem.n_devices for r in reqs]

        # per-request warm seeds, packed to the padded slot shape (zero
        # rows = "no previous state" = cold, element_warm_lambda's
        # fallback)
        sol_shape = self._sol_shape(batch)
        per_round = (len(sol_shape) == 3)
        init = None
        warm_flags = [False] * len(reqs)
        hit_flags = [False] * len(reqs)
        if cfg.warm_start:
            a0 = np.zeros(sol_shape, np.float32)
            p0 = np.zeros(sol_shape, np.float32)
            for i, req in enumerate(reqs):
                shape = (sizes[i], sol_shape[-1]) if per_round \
                    else (sizes[i],)
                seed, hit = self._lookup_seed(req.cell_id, req.fkey, shape)
                if seed is None:
                    continue
                warm_flags[i], hit_flags[i] = True, hit
                a0[i, :shape[0]] = seed.a
                p0[i, :shape[0]] = seed.power
            if any(warm_flags):
                init = WarmStart(a=jnp.asarray(a0), power=jnp.asarray(p0))

        sol = self._solve(batch, init=init)
        jax.block_until_ready(sol.a)

        # graceful degradation: an unconverged batch gets ONE retry
        # through the reference path (alternating + Dinkelbach) with a
        # larger iteration budget; its result is taken wholesale.  The
        # fast path stays bitwise untouched for converged batches.
        retried = False
        conv_real = np.asarray(sol.converged)[:len(reqs)]
        if cfg.retry_unconverged and not conv_real.all():
            retried = True
            sol = solve_joint_batch(batch, method="alternating",
                                    power_solver="dinkelbach",
                                    eps=cfg.eps,
                                    max_iters=cfg.retry_max_iters,
                                    init=init)
            jax.block_until_ready(sol.a)
            conv_real = np.asarray(sol.converged)[:len(reqs)]

        # per-bucket circuit breaker: consecutive still-unconverged
        # batches accumulate exponential backoff (accounted, never
        # slept — determinism) and eventually open the breaker
        if conv_real.all():
            self._fail_streak[bucket] = 0
        else:
            streak = self._fail_streak.get(bucket, 0) + 1
            self._fail_streak[bucket] = streak
            self.stats.retry_backoff_s += \
                cfg.retry_backoff_s * (2.0 ** (min(streak, 24) - 1))
            if streak >= cfg.breaker_threshold:
                self._breaker_open[bucket] = cfg.breaker_cooldown
                self.stats.breaker_opens += 1

        t1 = time.perf_counter()
        self._cost.observe(bucket, t1 - t0)
        self.buckets_used.add(bucket)
        t_done = now if virtual else t1

        # one transfer per field for the whole batch, then numpy slicing
        a_np = np.asarray(sol.a)
        p_np = np.asarray(sol.power)
        obj_np = np.asarray(sol.objective)
        conv_np = np.asarray(sol.converged)
        outer_np = np.asarray(sol.n_iters)
        inner_np = np.asarray(sol.inner_iters)

        responses = []
        outer = int(np.max(outer_np))
        inner = int(np.sum(inner_np))
        for i, req in enumerate(reqs):
            n = sizes[i]
            inst = JointSolution(
                a=a_np[i, :n], power=p_np[i, :n], objective=obj_np[i],
                n_iters=outer_np[i] if outer_np.ndim else outer_np,
                converged=conv_np[i],
                inner_iters=inner_np[i] if inner_np.ndim else inner_np)
            if cfg.warm_start:
                state = inst.resume
                self._feature_cache.put(req.fkey, state)
                self._cell_cache.put(req.cell_id, state)
                self._cell_fkey.put(req.cell_id, req.fkey)
            responses.append(SolveResponse(
                cell_id=req.cell_id, solution=inst,
                warm_started=warm_flags[i], cache_hit=hit_flags[i],
                latency_s=t_done - req.t_submit,
                deadline_missed=t_done > req.t_deadline, seq=req.seq,
                converged=bool(conv_np[i]),
                n_iters=int(outer_np[i] if outer_np.ndim else outer_np),
                n_unhealthy=req.n_unhealthy, retried=retried))
        if cfg.record_batches:
            self.batch_log.append(BatchRecord(
                seqs=tuple(r.seq for r in reqs),
                cell_ids=tuple(r.cell_id for r in reqs),
                n_bucket=bucket, reason=reason, priority=priority_lane))
        self.stats.record_batch(responses, t1 - t0, outer, inner,
                                reason=reason, preempted=preempted,
                                retried=retried)
        return responses
