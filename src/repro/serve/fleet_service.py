"""Micro-batching fleet-control-plane service with warm-started solves.

The serving problem: a base station (or a control plane serving many base
stations) receives a stream of per-cell solve requests — "here is my
cell's current channel/energy state, give me (a*, P*) for the next round"
— and must answer them at high throughput and bounded latency.  Requests
arrive one cell at a time, but the solvers (``repro.core.batch``) are at
their best on big padded batches; and successive requests from the same
cell are nearly identical on a coherent channel (``drifting_metro``), so
most of each solve is recomputation the warm-start path can skip.

:class:`FleetControlService` packs both observations into one loop:

* **micro-batching** — queued requests with compatible static metadata
  are packed into a padded :class:`~repro.core.batch.ProblemBatch` of
  fixed slot shape (``max_batch`` instance slots, device axis padded to a
  power-of-two bucket via :func:`repro.core.batch.pad_batch`), so jit
  compiles one program per bucket instead of one per request shape;
* **warm starts** — each solved request's ``(a*, P*)`` is cached and fed
  back as ``init`` for the cell's next solve (bit-identical solutions,
  collapsed inner iterations — see ``core.alternating``'s warm-start
  notes);
* **solution cache** — an LRU keyed on *quantised* problem features
  (log-domain rounding, :func:`quantized_problem_key`), so a request
  whose channel drifted less than the quantisation step reuses the state
  of any equivalent earlier problem, not just its own cell's;
* **accounting** — steady-state solves/sec, p50/p99 request latency,
  cache hit rates and inner-iteration counts
  (:class:`ServiceStats`; the ``fleet_service_throughput`` benchmark and
  CI gate consume these).

The loop is deliberately synchronous (``submit`` + ``step``): the unit of
work is one compiled batched solve, and a thread pump around it would
only blur the accounting.  ``run`` drains the queue for script use.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Hashable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alternating import JointSolution, WarmStart
from repro.core.batch import (
    _STATIC_FIELDS,
    pad_batch,
    solve_joint_batch,
    stack_problems,
)
from repro.core.problem import WirelessFLProblem


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the fleet control plane."""

    max_batch: int = 16           # micro-batch instance slots
    min_device_bucket: int = 8    # smallest padded device-axis size
    method: str = "fused"         # "fused" | "alternating"
    power_solver: Optional[str] = None   # None => the method's default
    eps: float = 1e-7
    max_iters: int = 50
    warm_start: bool = True       # feed cached solutions back as init
    cache_size: int = 4096        # LRU entries (feature-keyed + per-cell)
    quant_decimals: int = 2       # log10 rounding of the cache key
    latency_window: int = 8192    # latencies kept for the percentiles


class SolveRequest(NamedTuple):
    cell_id: Hashable
    problem: WirelessFLProblem
    t_submit: float


class SolveResponse(NamedTuple):
    cell_id: Hashable
    # padding stripped.  NOTE: with the fused method the solver reports
    # one inner-iteration count for the whole flattened element set, so
    # ``solution.inner_iters`` is the *micro-batch total* shared by every
    # response of the batch (per-request attribution does not exist on
    # that path); the alternating method attributes it per instance.
    solution: JointSolution
    warm_started: bool            # solve was seeded from cached state
    cache_hit: bool               # the feature-keyed LRU supplied the seed
    latency_s: float              # submit -> response wall time


class ServiceStats:
    """Steady-state throughput/latency counters (host-side, cheap)."""

    def __init__(self, latency_window: int = 8192):
        self._window = latency_window
        self.reset()

    def reset(self) -> None:
        """Zero every counter — call after warm-up so compile time does
        not pollute the steady-state figures."""
        self.n_requests = 0
        self.n_solved = 0
        self.n_batches = 0
        self.n_warm = 0
        self.n_cache_hits = 0
        self.solve_seconds = 0.0
        self.outer_iters = 0
        self.inner_iters = 0
        self.latencies = collections.deque(maxlen=self._window)

    # ---- recording (service-internal) ----------------------------------
    def record_batch(self, responses, solve_s: float, outer: int,
                     inner: int) -> None:
        self.n_batches += 1
        self.n_solved += len(responses)
        self.solve_seconds += solve_s
        self.outer_iters += outer
        self.inner_iters += inner
        for r in responses:
            self.n_warm += bool(r.warm_started)
            self.n_cache_hits += bool(r.cache_hit)
            self.latencies.append(r.latency_s)

    # ---- derived figures ------------------------------------------------
    @property
    def solves_per_sec(self) -> float:
        return self.n_solved / self.solve_seconds if self.solve_seconds else 0.0

    def latency_percentile(self, q: float) -> float:
        return float(np.percentile(np.asarray(self.latencies), q)) \
            if self.latencies else 0.0

    @property
    def warm_fraction(self) -> float:
        return self.n_warm / self.n_solved if self.n_solved else 0.0

    @property
    def mean_inner_iters(self) -> float:
        """Mean inner (Algorithm-1) iterations per micro-batch solve —
        the figure warm starts collapse (0.0 in analytic mode)."""
        return self.inner_iters / self.n_batches if self.n_batches else 0.0

    def summary(self) -> dict:
        return {
            "requests": self.n_requests,
            "solved": self.n_solved,
            "batches": self.n_batches,
            "solves_per_sec": self.solves_per_sec,
            "p50_latency_s": self.latency_percentile(50),
            "p99_latency_s": self.latency_percentile(99),
            "warm_fraction": self.warm_fraction,
            "cache_hit_fraction": (self.n_cache_hits / self.n_solved
                                   if self.n_solved else 0.0),
            "mean_outer_iters": (self.outer_iters / self.n_batches
                                 if self.n_batches else 0.0),
            "mean_inner_iters": self.mean_inner_iters,
        }


# the per-device leaves that discriminate problems; fading is appended
# when present.  Raw leaves rather than derived path gain / compute
# energy: same information, no recomputation on the request path.
_KEY_FIELDS = ("distance_m", "bandwidth_hz", "energy_budget_j",
               "dataset_size", "cycles_per_sample", "cpu_hz", "weights")


def _quantize(arr: np.ndarray, decimals: int) -> np.ndarray:
    return np.round(np.log10(np.maximum(np.abs(arr), 1e-300)), decimals)


def quantized_problem_key(problem: WirelessFLProblem,
                          decimals: int = 2) -> bytes:
    """Cache key: the problem's constraint data, log-quantised.

    Two problems map to the same key iff every per-device feature
    (distances, bandwidths, energy budgets, compute parameters, weights,
    fading) rounds to the same ``decimals`` digits in log10 and the
    static metadata matches exactly.  On a drifting channel this buckets
    "the same cell a moment later" together while separating genuinely
    different problems; the log domain makes the tolerance relative
    (energy budgets span 1e-4..1e2 J).
    """
    h = hashlib.sha1()
    h.update(repr([(f, getattr(problem, f))
                   for f in _STATIC_FIELDS]).encode())
    feats = [getattr(problem, f) for f in _KEY_FIELDS]
    if problem.fading is not None:
        feats.append(problem.fading)
    for x in feats:
        q = _quantize(np.asarray(x, np.float64), decimals)
        h.update(repr(q.shape).encode())
        h.update(np.ascontiguousarray(q).tobytes())
    return h.digest()


def _compat_key(problem: WirelessFLProblem) -> tuple:
    """Requests sharing this key can be stacked into one ProblemBatch."""
    return (tuple(getattr(problem, f) for f in _STATIC_FIELDS),
            problem.fading is not None,
            None if problem.fading is None else problem.fading.shape[1])


def _next_pow2(n: int, floor: int) -> int:
    b = max(floor, 1)
    while b < n:
        b *= 2
    return b


class _LRU:
    """Tiny ordered-dict LRU (host-side; values are small jnp arrays)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: collections.OrderedDict = collections.OrderedDict()

    def get(self, key):
        if key not in self._d:
            return None
        self._d.move_to_end(key)
        return self._d[key]

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def __len__(self) -> int:
        return len(self._d)


class FleetControlService:
    """The micro-batching, warm-starting fleet control plane."""

    def __init__(self, config: ServiceConfig = ServiceConfig()):
        self.config = config
        self.stats = ServiceStats(config.latency_window)
        self._queue: collections.deque[SolveRequest] = collections.deque()
        # feature-keyed LRU: quantised problem -> WarmStart (unpadded)
        self._feature_cache = _LRU(config.cache_size)
        # per-cell last solution: the fallback seed when the channel
        # drifted past the quantisation step (new feature key)
        self._cell_cache = _LRU(config.cache_size)

    # ------------------------------------------------------------- intake
    def submit(self, cell_id: Hashable,
               problem: WirelessFLProblem) -> None:
        """Queue one per-cell solve request."""
        self.stats.n_requests += 1
        self._queue.append(SolveRequest(cell_id=cell_id, problem=problem,
                                        t_submit=time.perf_counter()))

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ serving
    def _take_micro_batch(self) -> list[SolveRequest]:
        """Pop up to ``max_batch`` queued requests stackable with the
        oldest one (same static metadata / fading-ness); later
        incompatible requests keep their queue order."""
        if not self._queue:
            return []
        key = _compat_key(self._queue[0].problem)
        taken, kept = [], collections.deque()
        while self._queue and len(taken) < self.config.max_batch:
            req = self._queue.popleft()
            if _compat_key(req.problem) == key:
                taken.append(req)
            else:
                kept.append(req)
        kept.extend(self._queue)
        self._queue = kept
        return taken

    def _row_keys(self, batch, sizes) -> list[bytes]:
        """Per-request quantised feature keys from the *stacked* batch.

        One device->host transfer per leaf for the whole micro-batch
        (the per-request ``quantized_problem_key`` would pay ~10 tiny
        transfers per request); digests match the per-problem function
        exactly because the padded rows are sliced back to each
        request's true fleet size before hashing.
        """
        cfg = self.config
        statics = repr([(f, getattr(batch.problem, f))
                        for f in _STATIC_FIELDS]).encode()
        leaves = [_quantize(np.asarray(getattr(batch.problem, f),
                                       np.float64), cfg.quant_decimals)
                  for f in _KEY_FIELDS]
        if batch.problem.fading is not None:
            leaves.append(_quantize(np.asarray(batch.problem.fading,
                                               np.float64),
                                    cfg.quant_decimals))
        keys = []
        for i, n in enumerate(sizes):
            h = hashlib.sha1()
            h.update(statics)
            for leaf in leaves:
                row = np.ascontiguousarray(leaf[i, :n])
                h.update(repr(row.shape).encode())
                h.update(row.tobytes())
            keys.append(h.digest())
        return keys

    def _lookup_seed(self, cell_id, fkey: bytes,
                     shape) -> tuple[Optional[WarmStart], bool]:
        """(seed, from_feature_cache) for one request, shape-checked."""
        seed = self._feature_cache.get(fkey)
        if seed is not None and seed.a.shape == shape:
            return seed, True
        seed = self._cell_cache.get(cell_id)
        if seed is not None and seed.a.shape == shape:
            return seed, False
        return None, False

    def step(self) -> list[SolveResponse]:
        """Drain one micro-batch: pack, warm-start, solve, account."""
        reqs = self._take_micro_batch()
        if not reqs:
            return []
        cfg = self.config
        t0 = time.perf_counter()

        batch = stack_problems([r.problem for r in reqs])
        bucket = _next_pow2(batch.n_max, cfg.min_device_bucket)
        batch = pad_batch(batch, batch_size=cfg.max_batch, n_max=bucket)
        sizes = [r.problem.n_devices for r in reqs]
        # keying/caching is warm-start machinery: a cold-configured
        # service skips the quantise+hash work and keeps its LRUs empty
        fkeys = self._row_keys(batch, sizes) if cfg.warm_start else None

        # per-request warm seeds, packed to the padded slot shape (zero
        # rows = "no previous state" = cold, element_warm_lambda's
        # fallback)
        sol_shape = batch.mask.shape if batch.problem.fading is None \
            else batch.mask.shape + (batch.problem.fading.shape[-1],)
        per_round = (len(sol_shape) == 3)
        init = None
        warm_flags = [False] * len(reqs)
        hit_flags = [False] * len(reqs)
        if cfg.warm_start:
            a0 = np.zeros(sol_shape, np.float32)
            p0 = np.zeros(sol_shape, np.float32)
            for i, req in enumerate(reqs):
                shape = (sizes[i], sol_shape[-1]) if per_round \
                    else (sizes[i],)
                seed, hit = self._lookup_seed(req.cell_id, fkeys[i], shape)
                if seed is None:
                    continue
                warm_flags[i], hit_flags[i] = True, hit
                a0[i, :shape[0]] = seed.a
                p0[i, :shape[0]] = seed.power
            if any(warm_flags):
                init = WarmStart(a=jnp.asarray(a0), power=jnp.asarray(p0))

        sol = solve_joint_batch(batch, method=cfg.method,
                                power_solver=cfg.power_solver,
                                eps=cfg.eps, max_iters=cfg.max_iters,
                                init=init)
        jax.block_until_ready(sol.a)
        t1 = time.perf_counter()

        # one transfer per field for the whole batch, then numpy slicing
        a_np = np.asarray(sol.a)
        p_np = np.asarray(sol.power)
        obj_np = np.asarray(sol.objective)
        conv_np = np.asarray(sol.converged)
        outer_np = np.asarray(sol.n_iters)
        inner_np = np.asarray(sol.inner_iters)

        responses = []
        outer = int(np.max(outer_np))
        inner = int(np.sum(inner_np))
        for i, req in enumerate(reqs):
            n = sizes[i]
            inst = JointSolution(
                a=a_np[i, :n], power=p_np[i, :n], objective=obj_np[i],
                n_iters=outer_np[i] if outer_np.ndim else outer_np,
                converged=conv_np[i],
                inner_iters=inner_np[i] if inner_np.ndim else inner_np)
            if cfg.warm_start:
                state = inst.resume
                self._feature_cache.put(fkeys[i], state)
                self._cell_cache.put(req.cell_id, state)
            responses.append(SolveResponse(
                cell_id=req.cell_id, solution=inst,
                warm_started=warm_flags[i], cache_hit=hit_flags[i],
                latency_s=t1 - req.t_submit))
        self.stats.record_batch(responses, t1 - t0, outer, inner)
        return responses

    def run(self, requests=None) -> list[SolveResponse]:
        """Submit ``requests`` (``(cell_id, problem)`` pairs, optional)
        and drain the queue; responses in completion order."""
        for cell_id, problem in (requests or []):
            self.submit(cell_id, problem)
        out = []
        while self._queue:
            out.extend(self.step())
        return out
