"""Seeded deterministic chaos harness for the fleet control plane.

Production control planes fail in boring, recurring ways: a channel
estimator emits NaN/Inf gains, a device deep-fades to zero gain or
drops mid-round, the cost model's estimate excursions, a burst of
arrivals lands at once.  This module injects exactly those faults into
the *existing* traffic machinery (``repro.serve.load_gen``) so the
degraded-mode behaviour the service promises (``docs/robustness.md``)
is testable, benchmarkable, and reproducible:

* :class:`FaultPlan` — a frozen, seeded description of which fault
  kinds fire, how often, and how hard.  The same plan replays the same
  corruption byte-for-byte.
* :func:`corrupt_problem` — one problem, one fault kind: NaN/Inf gains,
  zero-gain fades, finite deep fades, whole-device outages.
* :func:`corrupt_trace` — a seeded pass over a ``load_gen`` trace that
  corrupts a ``fault_rate`` fraction of arrivals; the output is a plain
  ``Arrival`` list, so it composes with :func:`repro.serve.load_gen.drive`
  unchanged.
* :func:`dropout_mask` — the FL-side fault: a seeded ``[K, N]`` mask of
  devices whose round-k upload never arrives
  (``repro.fl.scan_engine``'s degraded aggregation consumes it).
* :func:`chaos_drive` — drive a service through a corrupted trace and
  report what leaked: non-finite solutions (must be zero), shed and
  unconverged responses, sanitised devices.

Faults are *injected* host-side, before ``submit``; what the harness
checks is that nothing downstream of the boundary ever sees them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.problem import WirelessFLProblem
from repro.serve.fleet_service import FleetControlService
from repro.serve.load_gen import Arrival, DriveReport, drive

# fault kinds understood by corrupt_problem / FaultPlan.kinds
NAN_CHANNEL = "nan_channel"      # estimator emits NaN gains
INF_CHANNEL = "inf_channel"      # estimator emits +inf gains
ZERO_GAIN = "zero_gain"          # deep fade all the way to zero
DEEP_FADE = "deep_fade"          # finite fade: gain * 10^(-db/10)
DEVICE_DROPOUT = "device_dropout"  # device unreachable (all rounds)
COST_SPIKE = "cost_spike"        # BucketCostModel estimate excursion

#: the channel-corruption kinds (appliable per problem)
CHANNEL_KINDS = (NAN_CHANNEL, INF_CHANNEL, ZERO_GAIN, DEEP_FADE,
                 DEVICE_DROPOUT)
FAULT_KINDS = CHANNEL_KINDS + (COST_SPIKE,)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded chaos scenario: which faults, how often, how hard.

    ``kinds`` are drawn uniformly per faulted arrival from the plan's
    channel kinds; ``cost_spike`` (if listed) fires once at drive start
    (:func:`chaos_drive`).  Identical plans replay identical faults.
    """

    kinds: tuple = CHANNEL_KINDS
    seed: int = 0
    fault_rate: float = 0.1       # fraction of arrivals corrupted
    device_rate: float = 0.1      # fraction of devices hit per fault
    deep_fade_db: float = 80.0    # power-domain fade depth
    cost_spike_factor: float = 50.0  # BucketCostModel.scale argument
    drop_rate: float = 0.1        # FL upload-dropout rate (dropout_mask)

    @property
    def channel_kinds(self) -> tuple:
        return tuple(k for k in self.kinds if k in CHANNEL_KINDS)


def corrupt_problem(problem: WirelessFLProblem, kind: str, *,
                    rng: np.random.Generator,
                    device_rate: float = 0.1,
                    deep_fade_db: float = 80.0) -> WirelessFLProblem:
    """One corrupted copy of ``problem`` (the input is untouched).

    Faults land on the fading table when the problem carries one
    (random (device, round) entries; ``device_dropout`` zeroes whole
    device rows), else on ``distance_m`` (NaN/Inf distance, or the
    distance blow-up equivalent of the fade).  Draws consume ``rng``
    state — thread one seeded generator through a trace for
    reproducibility.
    """
    if kind not in CHANNEL_KINDS:
        raise ValueError(f"unknown channel fault kind {kind!r}; "
                         f"choose from {CHANNEL_KINDS}")
    n = problem.n_devices
    k = max(1, int(round(device_rate * n)))
    idx = rng.choice(n, size=k, replace=False)
    if problem.fading is not None:
        arr = np.array(problem.fading, np.float32)
        col = rng.integers(arr.shape[1], size=k)
        if kind == NAN_CHANNEL:
            arr[idx, col] = np.nan
        elif kind == INF_CHANNEL:
            arr[idx, col] = np.inf
        elif kind == ZERO_GAIN:
            arr[idx, col] = 0.0
        elif kind == DEEP_FADE:
            arr[idx, col] *= np.float32(10.0 ** (-deep_fade_db / 10.0))
        else:                                   # DEVICE_DROPOUT
            arr[idx, :] = 0.0
        return dataclasses.replace(problem, fading=jnp.asarray(arr))
    arr = np.array(problem.distance_m, np.float64)
    if kind == NAN_CHANNEL:
        arr[idx] = np.nan
    elif kind == INF_CHANNEL:
        arr[idx] = np.inf
    elif kind == DEEP_FADE:
        # path gain ~ d^-2: d * 10^(db/20) fades the gain by 10^(-db/10)
        arr[idx] *= 10.0 ** (deep_fade_db / 20.0)
    else:                                       # ZERO_GAIN / DEVICE_DROPOUT
        arr[idx] = np.inf
    return dataclasses.replace(problem, distance_m=jnp.asarray(arr))


def corrupt_trace(trace: Sequence[Arrival],
                  plan: FaultPlan) -> tuple[list[Arrival], int]:
    """A seeded corrupted copy of a ``load_gen`` trace.

    Each arrival is faulted with probability ``plan.fault_rate`` by one
    uniformly drawn channel kind.  Returns ``(trace, n_faulted)``; the
    output is a plain ``Arrival`` list — feed it to
    :func:`repro.serve.load_gen.drive` like any other trace.
    """
    kinds = plan.channel_kinds
    if not kinds:
        return list(trace), 0
    rng = np.random.default_rng(plan.seed)
    out, n_faulted = [], 0
    for arr in trace:
        if rng.random() < plan.fault_rate:
            kind = kinds[int(rng.integers(len(kinds)))]
            out.append(arr._replace(problem=corrupt_problem(
                arr.problem, kind, rng=rng,
                device_rate=plan.device_rate,
                deep_fade_db=plan.deep_fade_db)))
            n_faulted += 1
        else:
            out.append(arr)
    return out, n_faulted


def dropout_mask(seed: int, n_rounds: int, n_devices: int,
                 rate: float) -> np.ndarray:
    """Seeded ``[K, N]`` bool mask, True = device i's round-k upload is
    lost (``repro.fl.scan_engine`` masks it out of the aggregation;
    the tx energy is still spent — the attempt happened)."""
    rng = np.random.default_rng(seed)
    return rng.random((n_rounds, n_devices)) < rate


@dataclasses.dataclass
class ChaosReport:
    """What leaked through one chaos drive (``nan_escapes`` must be 0)."""

    report: DriveReport
    n_faulted: int                # arrivals corrupted by the plan
    nan_escapes: int              # responses with non-finite a / power
    n_unconverged: int
    n_shed: int
    n_unhealthy_devices: int
    counters: dict                # service counter snapshot


def count_nonfinite(responses) -> int:
    """Responses whose solution carries any non-finite a or power — the
    chaos suite's canary; the boundary guarantees make this 0."""
    bad = 0
    for r in responses:
        a = np.asarray(r.solution.a)
        p = np.asarray(r.solution.power)
        bad += not (np.isfinite(a).all() and np.isfinite(p).all())
    return bad


def chaos_drive(service: FleetControlService, trace: Sequence[Arrival],
                plan: FaultPlan, *, clock: str = "virtual",
                tick_s: float = 1e-3,
                reset_stats_after: Optional[int] = None) -> ChaosReport:
    """Drive ``service`` through a ``plan``-corrupted copy of ``trace``.

    ``cost_spike`` (if planned) scales the service's cost model once
    before the first arrival — the EWMA then walks the estimates back,
    which is the recovery path under test.  Everything else reuses
    :func:`repro.serve.load_gen.drive` verbatim; stats are read off
    ``service.stats`` after the drain.
    """
    faulted, n_faulted = corrupt_trace(trace, plan)
    if COST_SPIKE in plan.kinds:
        service._cost.scale(plan.cost_spike_factor)
    report = drive(service, faulted, clock=clock, tick_s=tick_s,
                   reset_stats_after=reset_stats_after)
    stats = service.stats
    return ChaosReport(
        report=report, n_faulted=n_faulted,
        nan_escapes=count_nonfinite(report.responses),
        n_unconverged=stats.n_unconverged, n_shed=stats.n_shed,
        n_unhealthy_devices=stats.n_unhealthy_devices,
        counters=stats.counter_summary())
