"""Online fleet control plane: the request-driven serving path.

The paper's base station re-solves the joint selection/power problem
(Algorithm 2) every round for every cell it serves; ``repro.serve`` turns
the offline solvers into that online service — micro-batched, padded to
quantised slot shapes, and warm-started from cached previous solutions on
drifting channels.  See ``docs/serving.md``.
"""
from repro.serve.fleet_service import (
    FleetControlService,
    ServiceConfig,
    ServiceStats,
    SolveRequest,
    SolveResponse,
    quantized_problem_key,
)

__all__ = [
    "FleetControlService", "ServiceConfig", "ServiceStats",
    "SolveRequest", "SolveResponse", "quantized_problem_key",
]
