"""Online fleet control plane: the request-driven serving path.

The paper's base station re-solves the joint selection/power problem
(Algorithm 2) every round for every cell it serves; ``repro.serve`` turns
the offline solvers into that online service — an open-loop arrival
queue with per-request deadlines, continuous batching (adaptive
batch-close policy), priority lanes for drifted cells, AOT-warmed jit
buckets, and warm-started solves from cached previous solutions on
drifting channels.  ``repro.serve.load_gen`` generates the seeded
Poisson/bursty traffic and drives the loop; ``repro.serve.faults`` is
the seeded chaos harness that corrupts it (``docs/robustness.md``).
See ``docs/serving.md``.
"""
from repro.serve.faults import (
    CHANNEL_KINDS,
    FAULT_KINDS,
    ChaosReport,
    FaultPlan,
    chaos_drive,
    corrupt_problem,
    corrupt_trace,
    count_nonfinite,
    dropout_mask,
)
from repro.serve.fleet_service import (
    CLOSE_DEADLINE,
    CLOSE_FORCED,
    CLOSE_FULL,
    CLOSE_LINGER,
    BatchRecord,
    BucketCostModel,
    CoupledResponse,
    FleetControlService,
    ServiceConfig,
    ServiceStats,
    SolveRequest,
    SolveResponse,
    batch_close_reason,
    quantized_problem_key,
)
from repro.serve.load_gen import (
    Arrival,
    DriveReport,
    bursty_trace,
    drive,
    make_cells,
    measure_capacity,
    poisson_trace,
)

__all__ = [
    "FleetControlService", "ServiceConfig", "ServiceStats",
    "SolveRequest", "SolveResponse", "BatchRecord", "BucketCostModel",
    "CoupledResponse",
    "batch_close_reason", "quantized_problem_key",
    "CLOSE_FULL", "CLOSE_DEADLINE", "CLOSE_LINGER", "CLOSE_FORCED",
    "Arrival", "DriveReport", "make_cells", "poisson_trace",
    "bursty_trace", "drive", "measure_capacity",
    "FaultPlan", "ChaosReport", "FAULT_KINDS", "CHANNEL_KINDS",
    "chaos_drive", "corrupt_problem", "corrupt_trace", "count_nonfinite",
    "dropout_mask",
]
