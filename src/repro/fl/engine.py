"""Federated learning round engine — Algorithm 3 of the paper.

One communication round k:
  1. server broadcasts theta^k (free: downlink neglected, Sec. II-C),
  2. the scheduler samples the participation mask m ~ Bernoulli(a*_k)
     and supplies transmit powers P*_k,
  3. every participating client computes its local stochastic gradient,
  4. server updates  theta^{k+1} = theta^k - eta * sum_i alpha_i m_i g_i
     (eq. 4),
  5. wall-clock advances by the straggler's transmission time
     max_{i in S} T_ik and energy by sum_{i in S} (E^c_i + P_ik T_ik).

Two mathematically identical aggregation paths are provided:

* ``fused``   — alpha_i m_i enters as per-example loss weights, so a single
  backward pass over the concatenated cohort batch computes the aggregated
  gradient directly.  This is the formulation that scales to the big
  architectures (the mask rides the data-parallel axis; see train_step in
  launch/).
* ``stacked`` — per-client gradients via vmap, then an explicit
  mask-weighted reduction (the ``masked_aggregate`` Pallas kernel's host
  path).  Used to cross-check and to exercise the kernel.

This python-loop engine is the *reference path*: one round per host
iteration, easy to instrument, easy to extend.  For sweeps (many seeds x
strategies x scenarios) use ``repro.fl.scan_engine``, which compiles the
whole trajectory as a ``lax.scan`` and vmaps it across the grid — it is
validated round-for-round against this engine in ``tests/test_fl_scan.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import WirelessFLProblem
from repro.core.schedulers import ParticipationDraw
from repro.data.synthetic import Dataset
from repro.models import cnn


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_rounds: int = 300
    batch_per_client: int = 16
    lr: float = 0.05
    eval_every: int = 10
    aggregate: str = "fused"            # "fused" | "stacked"
    include_compute_time: bool = False  # paper: round time = straggler tx time
    # eq. (4) verbatim keeps fixed alpha_i, so the update magnitude scales
    # with the (tiny) expected participation mass sum_i alpha_i a_i ~ 0.02.
    # renormalize=True divides by sum_i alpha_i m_i (standard FedAvg
    # weighting) which only rescales the step; the paper's selection
    # dynamics are unchanged.  Faithful mode: renormalize=False.
    renormalize: bool = True
    # Beyond-paper: quantise each client's uplink gradient to this many
    # bits (stochastic rounding, per-tensor max scaling) before server
    # aggregation — models the compressed payload whose smaller S raises
    # the feasible selection probabilities (EXPERIMENTS.md §Perf/It-3).
    # None = fp32 uplink (paper).  Requires aggregate="stacked".
    uplink_bits: Optional[int] = None
    seed: int = 0


class FLHistory(NamedTuple):
    rounds: np.ndarray
    sim_time: np.ndarray        # cumulative simulated seconds
    energy: np.ndarray          # cumulative Joules
    participants: np.ndarray    # per-round participant count
    eval_rounds: np.ndarray
    eval_time: np.ndarray
    eval_acc: np.ndarray

    def time_to_accuracy(self, target: float) -> float:
        hit = np.where(self.eval_acc >= target)[0]
        return float(self.eval_time[hit[0]]) if len(hit) else float("nan")

    def energy_to_accuracy(self, target: float) -> float:
        hit = np.where(self.eval_acc >= target)[0]
        if not len(hit):
            return float("nan")
        r = self.eval_rounds[hit[0]]
        return float(self.energy[np.searchsorted(self.rounds, r)])


class FLResult(NamedTuple):
    params: Any
    history: FLHistory


# --------------------------------------------------------------------- steps

@functools.lru_cache(maxsize=16)
def _make_fused_step(lr: float):
    """Cached per-lr so repeated ``run_fl`` calls reuse one compilation."""
    @jax.jit
    def step(params, images, labels, sample_weights):
        grads = jax.grad(cnn.loss_fn)(params, images, labels, sample_weights)
        return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return step


def quantize_levels(bits) -> float | jax.Array:
    """Symmetric quantiser level count for a ``bits``-wide payload.

    ``bits=1`` would make the textbook ``2^(b-1) - 1`` zero (scale = inf,
    NaN output); the floor of one level turns it into ternary
    sign-quantisation {-1, 0, +1} instead — still unbiased, still
    clipped.  ``bits`` may be a python int (compile-time constant, the
    ``FLConfig.uplink_bits`` path) or a traced scalar/array (the
    per-device ``TrajectoryPlan.bits`` path).
    """
    if isinstance(bits, (int, float)):
        if bits < 1:
            raise ValueError(f"uplink quantisation needs bits >= 1, got {bits}")
        return max(2.0 ** (bits - 1) - 1.0, 1.0)
    return jnp.maximum(2.0 ** (bits - 1.0) - 1.0, 1.0)


def quantize_with_noise(g: jax.Array, noise: jax.Array, bits) -> jax.Array:
    """Deterministic quantiser core given precomputed uniform(0,1) noise.

    The single source of truth for the stochastic-rounding math: the
    keyed wrapper :func:`quantize_stochastic`, the scan engine's
    per-device path and the quantized-aggregate Pallas kernel's reference
    all call (or mirror) this with explicit noise, so kernel-vs-reference
    agreement is exact rather than distributional.
    """
    levels = quantize_levels(bits)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / levels
    scaled = g / scale
    low = jnp.floor(scaled)
    p_up = scaled - low
    q = low + (noise < p_up)
    return jnp.clip(q, -levels, levels) * scale


def quantize_stochastic(g: jax.Array, key: jax.Array, bits) -> jax.Array:
    """Per-tensor max-scaled b-bit stochastic-rounding quantiser (uplink
    payload model: b bits/param instead of 32)."""
    return quantize_with_noise(g, jax.random.uniform(key, g.shape), bits)


def _quantize_tree(gstack, key: jax.Array, bits):
    """Quantise stacked per-client gradients leaf-by-leaf.

    ``bits`` is a python int (every client alike) or a per-client ``[N]``
    array (the scan engine's per-device plan tables); the key stream —
    split over leaves, then over clients — is identical either way, so
    the two engines reproduce each other's noise exactly.
    """
    leaves, treedef = jax.tree_util.tree_flatten(gstack)
    keys = jax.random.split(key, len(leaves))
    per_client = not isinstance(bits, (int, float)) and jnp.ndim(bits) == 1
    out = []
    for leaf, k in zip(leaves, keys):
        n = leaf.shape[0]
        ks = jax.random.split(k, n)
        if per_client:
            qs = jax.vmap(quantize_stochastic)(leaf, ks, bits)
        else:
            qs = jax.vmap(lambda g, kk: quantize_stochastic(g, kk, bits))(
                leaf, ks)
        out.append(qs)
    return jax.tree_util.tree_unflatten(treedef, out)


def _make_stacked_step(lr: float, aggregate_fn: Callable | None = None,
                       uplink_bits: Optional[int] = None):
    if aggregate_fn is None:
        return _default_stacked_step(lr, uplink_bits)
    return _build_stacked_step(lr, aggregate_fn, uplink_bits)


@functools.lru_cache(maxsize=16)
def _default_stacked_step(lr: float, uplink_bits: Optional[int]):
    def aggregate_fn(gstack, coef):   # [N, ...] x [N] -> [...]
        return jax.tree_util.tree_map(
            lambda g: jnp.tensordot(coef, g, axes=((0,), (0,))), gstack)
    return _build_stacked_step(lr, aggregate_fn, uplink_bits)


def _build_stacked_step(lr: float, aggregate_fn: Callable,
                        uplink_bits: Optional[int]):
    @jax.jit
    def step(params, images, labels, coef, key):
        # images [N, b, ...] -> per-client mean-loss gradients
        def client_grad(img, lab):
            return jax.grad(cnn.loss_fn)(params, img, lab)
        gstack = jax.vmap(client_grad)(images, labels)
        if uplink_bits is not None:
            gstack = _quantize_tree(gstack, key, uplink_bits)
        agg = aggregate_fn(gstack, coef)
        return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, agg)
    return step


# -------------------------------------------------------------------- engine

def run_fl(problem: WirelessFLProblem,
           scheduler,
           train: Dataset,
           parts: Sequence[np.ndarray],
           test: Dataset,
           config: FLConfig,
           aggregate_fn: Callable | None = None,
           init_params: Any | None = None) -> FLResult:
    """Simulate Algorithm 3 with exact paper time/energy accounting."""
    n = problem.n_devices
    assert len(parts) == n
    rng = np.random.default_rng(config.seed)
    key = jax.random.PRNGKey(config.seed)

    params = cnn.init(jax.random.PRNGKey(config.seed + 17)) if init_params is None else init_params
    state = scheduler.precompute(problem)
    ec = np.asarray(problem.compute_energy())
    # tx-time table at the scheduler's planned powers, computed once — [N],
    # or [N, K] under per-round fading (draw.power is then the k-th column).
    # The ParticipationDraw contract allows a scheduler to emit per-round
    # powers that differ from its precomputed plan; the loop below falls
    # back to an exact per-round tx_time whenever that happens.
    state_power = np.asarray(state.power)
    t_table = np.asarray(problem.tx_time(state.power))

    fused = config.aggregate == "fused"
    if config.uplink_bits is not None and fused:
        raise ValueError("uplink_bits requires aggregate='stacked' "
                         "(per-client gradients must exist to quantise)")
    step = (_make_fused_step(config.lr) if fused
            else _make_stacked_step(config.lr, aggregate_fn,
                                    config.uplink_bits))

    b = config.batch_per_client
    hist_rounds, hist_time, hist_energy, hist_parts = [], [], [], []
    eval_rounds, eval_time, eval_acc = [], [], []
    cum_time = 0.0
    cum_energy = 0.0

    for k in range(config.n_rounds):
        key, sub = jax.random.split(key)
        draw: ParticipationDraw = scheduler.sample(state, sub, k)
        mask = np.asarray(draw.mask)
        power = np.asarray(draw.power)
        alpha = np.asarray(draw.agg_weights)

        # ---- accounting (paper Sec. V-B) --------------------------------
        if mask.any():
            planned = state_power if state_power.ndim == 1 else state_power[:, k]
            if np.array_equal(power, planned):
                t_all = t_table if t_table.ndim == 1 else t_table[:, k]
            else:
                t_all = np.asarray(problem.tx_time(jnp.asarray(power)))
                if t_all.ndim > 1:      # [N] power on a fading problem
                    t_all = t_all[:, k]
            sel_t = t_all[mask]
            round_time = float(np.max(sel_t))
            if config.include_compute_time:
                comp = np.asarray(problem.cycles_per_sample * problem.dataset_size
                                  / problem.cpu_hz)
                round_time = float(np.max(sel_t + comp[mask]))
            round_energy = float(np.sum(power[mask] * sel_t + ec[mask]))
        else:
            round_time, round_energy = 0.0, 0.0

        cum_time += round_time
        cum_energy += round_energy
        hist_rounds.append(k)
        hist_time.append(cum_time)
        hist_energy.append(cum_energy)
        hist_parts.append(int(mask.sum()))

        # ---- learning step (eq. 4) --------------------------------------
        if mask.any():
            batch_idx = np.stack([
                rng.choice(parts[i], size=b, replace=len(parts[i]) < b)
                for i in range(n)])
            images = jnp.asarray(train.images[batch_idx])   # [N, b, 28, 28, 1]
            labels = jnp.asarray(train.labels[batch_idx])
            coef = jnp.asarray(alpha * mask, jnp.float32)
            if config.renormalize:
                coef = coef / jnp.maximum(coef.sum(), 1e-12)
            if fused:
                sw = (jnp.repeat(coef, b) / b).astype(jnp.float32)
                params = step(params, images.reshape(n * b, 28, 28, 1),
                              labels.reshape(n * b), sw)
            else:
                # fold_in (not split): keeps the scheduler key stream
                # identical across aggregation modes
                qkey = jax.random.fold_in(sub, 1)
                params = step(params, images, labels, coef, qkey)

        if (k + 1) % config.eval_every == 0 or k == config.n_rounds - 1:
            acc = cnn.accuracy(params, jnp.asarray(test.images),
                               jnp.asarray(test.labels))
            eval_rounds.append(k)
            eval_time.append(cum_time)
            eval_acc.append(acc)

    history = FLHistory(
        rounds=np.asarray(hist_rounds), sim_time=np.asarray(hist_time),
        energy=np.asarray(hist_energy), participants=np.asarray(hist_parts),
        eval_rounds=np.asarray(eval_rounds), eval_time=np.asarray(eval_time),
        eval_acc=np.asarray(eval_acc))
    return FLResult(params=params, history=history)
