"""Drift-aware closed-loop FL: online control plane -> scan-fused training.

Until now the repo ran federated training (``repro.fl.scan_engine``) and
the online control plane (``repro.serve.fleet_service``) as two
disconnected systems: training consumed a one-shot scheduler precompute,
the service answered per-round solve requests nobody trained on.  This
module closes the loop, reproducing the paper's Sec. V comparison under
Gauss-Markov channel drift:

1. **per-round control** — round k's channel is ``slice_round(problem, k)``
   of a drifting ([N, K] Gauss-Markov) trajectory.  Each round's selection
   probabilities and powers come from a warm-started
   :class:`~repro.serve.FleetControlService` solve on the *current*
   channel — the service's cell cache seeds round k's solve from round
   k-1's solution, so inner (Dinkelbach) iterations collapse as the
   channel drifts coherently (``docs/serving.md``).  The controller never
   sees future rounds: this is the online regime the paper's base station
   lives in, not a one-shot precompute over a known trajectory.
2. **strategy layer** — the per-round solutions (plus the raw channel)
   feed a benchmark-strategy suite in the spirit of the paper's Sec. V
   comparison: the proposed probabilistic scheme, per-round deterministic
   top-k, uniform-at-P^max, channel-aware greedy, and the Lyapunov
   virtual-queue scheduler (``repro.core.schedulers``).
3. **training + accounting** — every strategy's per-round plan becomes a
   :class:`~repro.fl.scan_engine.TrajectoryPlan` and the whole
   (strategy x seed) grid runs as ONE scan-fused, vmapped sweep call,
   with Sec. II-C accounting per round: completion time = max over
   selected devices of (tx time + local compute), energy = sum of
   E^c_i + P_ik T_ik over participants, accuracy on the eval schedule.

Because problem (7) is separable per (i, k), the stream of per-round
service solves lands on exactly the trajectory-wide solution a one-shot
solve would produce (tested bit-for-bit up to solver tolerance in
``tests/test_closed_loop.py``) — what the online loop adds is *tracking*:
warm-start reuse between rounds, measured control-plane latency, and the
ability to extend to channels revealed one round at a time.

Typical use::

    from repro.fl.closed_loop import ClosedLoopConfig, run_closed_loop_grid
    out = run_closed_loop_grid(ClosedLoopConfig(n_devices=32, n_rounds=10))
    print(format_closed_loop_table(out))
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint
from repro.core.alternating import WarmStart, solve_joint_fused
from repro.core.problem import WirelessFLProblem
from repro.core.scenarios import make_problem, slice_round
from repro.core.schedulers import (
    DeterministicScheduler,
    GreedyChannelScheduler,
    LyapunovScheduler,
    ProbabilisticScheduler,
    SchedulerState,
    UniformScheduler,
    _data_weights,
    _round_preserving_count,
)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_mnist_like
from repro.fl.engine import FLConfig, FLHistory
from repro.fl.scan_engine import (
    init_sweep_params,
    plan_trajectory,
    run_fl_sweep,
    stack_plans,
)
from repro.serve.faults import FaultPlan, corrupt_problem, dropout_mask
from repro.serve.fleet_service import FleetControlService, ServiceConfig

#: the paper-style comparison suite (Sec. V benchmarks + the two
#: stochastic-scheduling baselines from the wider wireless-FL literature,
#: plus the joint bit/power/selection scheme of docs/compression.md)
CLOSED_LOOP_STRATEGIES = ("probabilistic", "deterministic", "uniform",
                          "greedy_channel", "lyapunov", "joint_bits")

#: strategies whose plans carry an uplink bit-width table — they train in
#: a separate quantized (stacked-aggregation) sweep so the classic
#: full-precision strategies keep their bit-identical compiled program
QUANTIZED_STRATEGIES = ("joint_bits",)


@dataclasses.dataclass(frozen=True)
class ClosedLoopConfig:
    """One closed-loop experiment: scenario, control plane, training."""

    scenario: str = "drifting_metro"
    n_devices: int = 32
    n_rounds: int = 10
    coherence: float = 0.9
    seed: int = 0
    n_seeds: int = 1              # FL seeds per strategy (shared control)
    # --- control plane ---------------------------------------------------
    service: ServiceConfig = ServiceConfig()
    uniform_m: Optional[int] = None   # None => expected count of a*
    greedy_m: Optional[int] = None    # None => expected count of a*
    lyapunov_v: float = 1e-4
    # discrete uplink bit-width menu for the "joint_bits" strategy (the
    # bit-allocation step of the alternating solver; docs/compression.md)
    bit_menu: tuple = (8, 16, 32)
    # --- training --------------------------------------------------------
    n_train: int = 2048
    n_test: int = 512
    beta: float = 0.3             # Dirichlet label-skew
    lr: float = 0.1
    batch_per_client: int = 8
    eval_every: int = 5
    # Sec. II-C completion time: straggler tx time + local compute
    include_compute_time: bool = True
    tau_th: float = 0.5
    # --- fault tolerance (docs/robustness.md) ---------------------------
    # chaos injection: channel corruption before the control pass (the
    # service sanitises it) plus per-trajectory upload dropouts in the
    # scan engine.  None = the pristine paper experiment, bit-identical
    # to the pre-fault-tolerance pipeline.
    fault_plan: Optional[FaultPlan] = None
    # round-granular crash safety: every solved control round is
    # checkpointed here, and a restart resumes from the last round with
    # a bitwise-identical final table.  None = no checkpointing.
    checkpoint_dir: Optional[str] = None


class ControlTrace:
    """Per-round control-plane outcome of one closed-loop run."""

    def __init__(self, a: np.ndarray, power: np.ndarray,
                 warm_rounds: int, inner_iters: int, outer_iters: int,
                 solve_seconds: float, service: FleetControlService):
        self.a = a                      # [N, K] solved probabilities
        self.power = power              # [N, K] solved powers
        self.warm_rounds = warm_rounds  # rounds whose solve was warm-started
        self.inner_iters = inner_iters
        self.outer_iters = outer_iters
        self.solve_seconds = solve_seconds
        self.service = service

    @property
    def n_rounds(self) -> int:
        return self.a.shape[1]


def solve_rounds(problem: WirelessFLProblem,
                 service: Optional[FleetControlService] = None,
                 *,
                 cell_id="cell-0",
                 checkpoint_dir: Optional[str] = None) -> ControlTrace:
    """Drive the online control plane over a drifting trajectory.

    Submits ``slice_round(problem, k)`` for k = 0..K-1 one round at a
    time — the service only ever sees the current channel — and stitches
    the per-round ``[N, 1]`` solutions into ``[N, K]`` tables.  Round
    k > 0 warm-starts from round k-1's cached solution (the service's
    cell/feature LRUs), which is where the drift-tracking win lives.

    ``checkpoint_dir`` makes the loop crash-safe at round granularity:
    every solved round is persisted (``repro.checkpoint.checkpoint``),
    and a rerun against a non-empty directory restores the completed
    columns, re-seeds the (fresh) service's warm caches from the last
    round's solution via :meth:`FleetControlService.seed_cell`, and
    continues at the next round — warm starts are solution-invariant
    (they only shorten the iteration), so the resumed table is bitwise
    identical to the uninterrupted one (``tests/test_closed_loop_faults``).
    """
    if problem.fading is None:
        raise ValueError("solve_rounds needs a fading ([N, K]) problem; "
                         "use a drifting scenario (e.g. 'drifting_metro')")
    if service is None:
        service = FleetControlService(ServiceConfig())
    k_rounds = problem.fading.shape[1]
    n = problem.n_devices
    a_cols, p_cols = [], []
    warm_rounds = inner = outer = 0
    t_solve = 0.0
    start_k = 0
    if checkpoint_dir is not None:
        step = checkpoint.latest_step(checkpoint_dir)
        if step is not None:
            tmpl = np.zeros((n, step + 1), np.float32)
            _, trees, _, extra = checkpoint.restore(
                checkpoint_dir, step,
                params_template={"a": tmpl, "power": tmpl})
            a_np = np.asarray(trees["a"])
            p_np = np.asarray(trees["power"])
            a_cols = [a_np[:, k] for k in range(step + 1)]
            p_cols = [p_np[:, k] for k in range(step + 1)]
            warm_rounds = int(extra["warm_rounds"])
            inner = int(extra["inner_iters"])
            outer = int(extra["outer_iters"])
            t_solve = float(extra["solve_seconds"])
            # re-seed the warm caches exactly as round ``step``'s solve
            # left them, so round step+1 warm-starts as if never killed
            service.seed_cell(cell_id, slice_round(problem, step),
                              WarmStart(a=jnp.asarray(a_np[:, step:]),
                                        power=jnp.asarray(p_np[:, step:])))
            start_k = step + 1
    for k in range(start_k, k_rounds):
        resp, = service.run([(cell_id, slice_round(problem, k))])
        a_cols.append(np.asarray(resp.solution.a)[:, 0])
        p_cols.append(np.asarray(resp.solution.power)[:, 0])
        warm_rounds += bool(resp.warm_started)
        inner += int(resp.solution.inner_iters)
        outer += int(resp.solution.n_iters)
        t_solve += resp.latency_s
        if checkpoint_dir is not None:
            checkpoint.save(
                checkpoint_dir, k,
                {"a": np.stack(a_cols, axis=1).astype(np.float32),
                 "power": np.stack(p_cols, axis=1).astype(np.float32)},
                extra={"warm_rounds": warm_rounds, "inner_iters": inner,
                       "outer_iters": outer, "solve_seconds": t_solve})
    return ControlTrace(a=np.stack(a_cols, axis=1),
                        power=np.stack(p_cols, axis=1),
                        warm_rounds=warm_rounds, inner_iters=inner,
                        outer_iters=outer, solve_seconds=t_solve,
                        service=service)


def _expected_count(a: np.ndarray) -> int:
    """round(mean over rounds of sum_i a_ik), >= 1 — the M that makes the
    count-matched baselines (uniform / greedy) comparable to a*."""
    return max(1, int(round(float(a.sum(axis=0).mean()))))


def joint_bits_state(problem: WirelessFLProblem, config: ClosedLoopConfig
                     ) -> tuple[object, SchedulerState, np.ndarray]:
    """(scheduler, state, bits [N, K]) for the joint bit/power/selection
    scheme: one fused solve with the bit-allocation step over
    ``config.bit_menu``.

    Problem (7) stays separable per (i, k) with the bits variable, so
    the one-shot trajectory solve equals the per-round online stream the
    other strategies consume (see ``solve_rounds``); what it adds is the
    per-device payload choice b_ik that the quantized sweep trains with.
    """
    sol = solve_joint_fused(problem, bit_menu=tuple(config.bit_menu))
    state = SchedulerState(a=sol.a, power=sol.power,
                           agg_weights=_data_weights(problem))
    return ProbabilisticScheduler(), state, np.asarray(sol.bits, np.float32)


def strategy_state(name: str, problem: WirelessFLProblem,
                   control: ControlTrace, config: ClosedLoopConfig
                   ) -> tuple[object, SchedulerState]:
    """(scheduler, per-round SchedulerState) for one benchmark strategy.

    The proposed scheme and its deterministic rounding consume the
    control plane's per-round solutions; the baselines are count-matched
    (uniform, greedy) or budget-matched (Lyapunov) but ignore the solve,
    exactly as the paper's Sec. V benchmarks ignore Algorithm 2.
    ``joint_bits`` re-solves with the discrete bit-width menu (use
    :func:`joint_bits_state` directly when the bits table is needed too).
    """
    a = jnp.asarray(control.a, jnp.float32)          # [N, K]
    power = jnp.asarray(control.power, jnp.float32)
    alpha = _data_weights(problem)
    if name == "probabilistic":
        return (ProbabilisticScheduler(),
                SchedulerState(a=a, power=power, agg_weights=alpha))
    if name == "deterministic":
        a_bin = _round_preserving_count(a, per_round=True)
        return (DeterministicScheduler(per_round=True),
                SchedulerState(a=a_bin, power=power, agg_weights=alpha))
    if name == "uniform":
        m = config.uniform_m if config.uniform_m is not None \
            else _expected_count(control.a)
        sch = UniformScheduler(m=m)
        return sch, sch.precompute(problem)
    if name == "greedy_channel":
        m = config.greedy_m if config.greedy_m is not None \
            else _expected_count(control.a)
        sch = GreedyChannelScheduler(m=m)
        return sch, sch.precompute(problem)
    if name == "lyapunov":
        sch = LyapunovScheduler(v=config.lyapunov_v)
        return sch, sch.precompute(problem)
    if name == "joint_bits":
        sch, state, _ = joint_bits_state(problem, config)
        return sch, state
    raise KeyError(f"unknown closed-loop strategy {name!r}; "
                   f"choose from {CLOSED_LOOP_STRATEGIES}")


# ------------------------------------------------------------------ driver

def _fl_config(config: ClosedLoopConfig, run: int) -> FLConfig:
    return FLConfig(n_rounds=config.n_rounds, lr=config.lr,
                    batch_per_client=config.batch_per_client,
                    eval_every=config.eval_every,
                    include_compute_time=config.include_compute_time,
                    seed=config.seed + 101 * run)


def _summarise(history: FLHistory, state: SchedulerState,
               bits: Optional[np.ndarray] = None) -> dict:
    a = np.asarray(state.a)
    exp_parts = float(a.sum(axis=0).mean()) if a.ndim == 2 \
        else float(a.sum())
    return {
        "expected_participants": exp_parts,
        "mean_participants": float(history.participants.mean()),
        # fleet-mean uplink payload width (32 = full-precision fp32)
        "mean_bits": 32.0 if bits is None else float(np.mean(bits)),
        "total_energy_j": float(history.energy[-1]),
        "completion_time_s": float(history.sim_time[-1]),
        "final_acc": float(history.eval_acc[-1]),
    }


def run_closed_loop_grid(config: Optional[ClosedLoopConfig] = None,
                         strategies: Sequence[str] = CLOSED_LOOP_STRATEGIES,
                         service: Optional[FleetControlService] = None,
                         **sweep_kw) -> dict:
    """The full closed-loop comparison on one drifting scenario.

    One warm-started control-plane pass over the trajectory (shared by
    the strategies that consume the solve), then every
    (strategy x seed) trajectory runs as one scan-fused sweep call.
    Returns ``{"control": {...}, "strategies": {name: {...}}}`` — feed it
    to :func:`format_closed_loop_table` for the paper-style table.
    """
    config = config if config is not None else ClosedLoopConfig()
    problem = make_problem(config.scenario, seed=config.seed,
                           n_devices=config.n_devices,
                           n_rounds=config.n_rounds,
                           coherence=config.coherence,
                           tau_th=config.tau_th)
    plan = config.fault_plan
    if plan is not None:
        # seeded channel corruption, one pass per planned channel kind;
        # the service's submit-time sanitiser is what is under test
        rng = np.random.default_rng(plan.seed)
        for kind in plan.channel_kinds:
            problem = corrupt_problem(problem, kind, rng=rng,
                                      device_rate=plan.device_rate,
                                      deep_fade_db=plan.deep_fade_db)
    train, test = make_mnist_like(config.n_train, config.n_test,
                                  seed=config.seed)
    parts = dirichlet_partition(train, config.n_devices, config.beta,
                                seed=config.seed + 1)

    if service is None:
        service = FleetControlService(config.service)
    control = solve_rounds(problem, service,
                           checkpoint_dir=config.checkpoint_dir)

    # the training/planning layer needs finite tx/energy tables even for
    # corrupted devices (health-blind baselines may still select them),
    # so it consumes the sanitised problem; identity when fault-free
    plan_problem = problem if plan is None else problem.sanitize()[0]

    # classic full-precision plans and quantized (bits-table) plans train
    # in separate sweeps: the bits leaf changes the compiled program and
    # needs stacked aggregation, and splitting keeps the classic
    # strategies' program bit-identical to the pre-compression pipeline
    plans, labels, configs = [], [], []
    qplans, qlabels, qconfigs = [], [], []
    states: dict[str, SchedulerState] = {}
    bits_tables: dict[str, np.ndarray] = {}
    n_plans = 0
    for name in strategies:
        quantized = name in QUANTIZED_STRATEGIES
        if quantized:
            sch, state, bits = joint_bits_state(plan_problem, config)
            bits_tables[name] = bits
            # the plan problem carries the solved bits leaf so the
            # tx-time/energy tables reflect the reduced payload (eq. 1)
            qprob = dataclasses.replace(plan_problem,
                                        bits=jnp.asarray(bits, jnp.float32))
        else:
            sch, state = strategy_state(name, plan_problem, control, config)
        states[name] = state
        for run in range(max(config.n_seeds, 1)):
            cfg = _fl_config(config, run)
            drops = None if plan is None else dropout_mask(
                plan.seed + 31 * n_plans, config.n_rounds,
                config.n_devices, plan.drop_rate)
            n_plans += 1
            if quantized:
                cfg = dataclasses.replace(cfg, aggregate="stacked")
                qplans.append(plan_trajectory(qprob, sch, parts, cfg,
                                              state=state, drops=drops,
                                              bits=bits))
                qlabels.append(name)
                qconfigs.append(cfg)
            else:
                plans.append(plan_trajectory(plan_problem, sch, parts, cfg,
                                             state=state, drops=drops))
                labels.append(name)
                configs.append(cfg)

    histories: dict[str, list[FLHistory]] = {name: [] for name in strategies}
    for g_plans, g_labels, g_cfgs in ((plans, labels, configs),
                                      (qplans, qlabels, qconfigs)):
        if not g_plans:
            continue
        sweep = run_fl_sweep(stack_plans(g_plans), train, test, g_cfgs[0],
                             init_sweep_params(g_cfgs), **sweep_kw)
        for h, lbl in zip(sweep.histories, g_labels):
            histories[lbl].append(h)

    # provenance: report the service configuration actually used (an
    # explicit ``service`` argument overrides ``config.service``)
    cfg_dict = dataclasses.asdict(config)
    cfg_dict["service"] = dataclasses.asdict(service.config)
    out: dict = {
        "config": cfg_dict,
        "control": {
            "warm_rounds": control.warm_rounds,
            "n_rounds": control.n_rounds,
            "inner_iters": control.inner_iters,
            "outer_iters": control.outer_iters,
            "solve_seconds": control.solve_seconds,
            "service": control.service.stats.summary(),
        },
        "strategies": {},
    }
    if plan is not None:
        health = problem.health_mask(xp=np)
        out["faults"] = {
            "plan": dataclasses.asdict(plan),
            "n_unhealthy_devices": int(health.size) - int(health.sum()),
            "drop_rate": plan.drop_rate,
        }
    for name in strategies:
        runs = [_summarise(h, states[name], bits=bits_tables.get(name))
                for h in histories[name]]
        agg = {k: float(np.mean([r[k] for r in runs])) for k in runs[0]}
        out["strategies"][name] = agg
    return out


_COLUMNS = (("expected_participants", "E[|S|]", "{:8.2f}"),
            ("mean_participants", "mean|S|", "{:8.2f}"),
            ("mean_bits", "bits", "{:6.1f}"),
            ("total_energy_j", "energy(J)", "{:10.2f}"),
            ("completion_time_s", "time(s)", "{:9.2f}"),
            ("final_acc", "acc", "{:6.3f}"))


def format_closed_loop_table(result: dict) -> str:
    """The Sec.-V-style comparison table (cf. paper Tables I-IV)."""
    ctrl = result["control"]
    lines = [
        f"closed loop on {result['config']['scenario']} "
        f"(N={result['config']['n_devices']}, K={ctrl['n_rounds']}): "
        f"{ctrl['warm_rounds']}/{ctrl['n_rounds']} rounds warm-started, "
        f"{ctrl['inner_iters']} inner iters, "
        f"{ctrl['solve_seconds'] * 1e3:.1f} ms control plane",
        "strategy          " + " ".join(f"{h:>10}" for _, h, _ in _COLUMNS),
    ]
    for name, row in result["strategies"].items():
        cells = " ".join(f"{fmt.format(row[key]):>10}"
                         for key, _, fmt in _COLUMNS)
        lines.append(f"{name:<18}{cells}")
    return "\n".join(lines)
