"""Scan-fused FL engine: whole training trajectories as one compiled program.

``repro.fl.engine.run_fl`` (the reference path) drives Algorithm 3 with a
Python ``for`` over rounds — one jit dispatch, several eager jnp calls and
a handful of host/device syncs per round.  That is fine for a single run
but dominates wall-clock for the paper's strategy-comparison grids
(probabilistic vs deterministic vs uniform vs equally-weighted, averaged
over seeds — Figures 1-2 / Tables I-IV).

This module compiles the *entire trajectory* instead:

* the round loop is a single :func:`jax.lax.scan` whose carry
  ``(params, key, cum_time, cum_energy)`` is donated by XLA between
  iterations — the scheduler's per-round Bernoulli participation draw and
  the power/tx-time lookup are fused into the scan body, and the server
  update (eq. 4) runs as either the fused weighted-loss backward pass or
  the stacked per-client path whose reduction is the ``masked_aggregate``
  Pallas kernel (on-device on TPU, interpret mode elsewhere);
* a whole sweep — (seed x strategy x scenario) — is ``jax.vmap`` of that
  scanned trajectory over a stacked :class:`TrajectoryPlan`, jitted once
  and optionally sharded over the local device mesh along the trajectory
  axis (``repro.core.batch.batch_sharding``).

Everything the scan body needs is precomputed into the plan: selection
probabilities per round, the tx-time/energy tables at the planned powers
(Sec. II-C), and the minibatch index schedule.  The plan mirrors the
reference engine's RNG streams exactly — the same jax key-split sequence
for participation and the same numpy ``Generator`` consumption for
minibatch choice — so a scanned trajectory reproduces ``run_fl`` to
floating-point tolerance (see ``tests/test_fl_scan.py``).

Strategy sampling is encoded as data so one compiled program serves every
scheduler: ``mode`` selects Bernoulli (probabilistic), fixed-mask
(deterministic / equally-weighted) or exact-M uniform sampling inside the
scan body via ``lax.switch``.

Typical use::

    plans = [plan_trajectory(problem, sch, parts, cfg) for sch, cfg in grid]
    sweep = run_fl_sweep(stack_plans(plans), train, test, cfg_static)
    res0  = sweep.result(0)        # FLResult, same layout as run_fl's
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import ProblemBatch, batch_sharding
from repro.core.problem import WirelessFLProblem
from repro.core.schedulers import (
    DeterministicScheduler,
    EquallyWeightedScheduler,
    GreedyChannelScheduler,
    LyapunovScheduler,
    ProbabilisticScheduler,
    SchedulerState,
    UniformScheduler,
)
from repro.data.synthetic import Dataset
from repro.fl.engine import FLConfig, FLHistory, FLResult, _quantize_tree
from repro.kernels.masked_aggregate.ops import (masked_aggregate_pytree,
                                                quantized_aggregate_pytree)
from repro.models import cnn

# participation-sampling modes fused into the scan body (lax.switch index)
MODE_BERNOULLI = 0   # probabilistic: m_i ~ Bernoulli(a_ik)
MODE_FIXED = 1       # deterministic / equally-weighted: m_i = [a_ik > 0]
MODE_UNIFORM = 2     # uniform: exactly M clients via a random permutation


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrajectoryPlan:
    """Everything one scanned trajectory needs, precomputed to tables.

    Per-round tables are ``[K, N]`` (round-major so the scan consumes them
    as xs); ``stack_plans`` adds a leading trajectory axis to every leaf.
    The tx-time/energy tables are evaluated at the scheduler's planned
    powers, so the scan body never touches the wireless problem — the
    power lookup reduces to reading the k-th row.
    """

    probs: jax.Array        # [K, N] selection probabilities a_ik
    tx_time: jax.Array      # [K, N] T_ik at the planned power P*_ik (eq. 1)
    round_energy: jax.Array  # [K, N] E^c_i + P*_ik T_ik per participant (eq. 6)
    comp_time: jax.Array    # [N] local computation time (include_compute_time)
    agg_weights: jax.Array  # [N] alpha_i for the server update (eq. 4)
    batch_idx: jax.Array    # [K, N, b] int32 planned client minibatches
    key: jax.Array          # PRNG key driving the in-scan participation draws
    lr: jax.Array           # scalar f32 learning rate
    mode: jax.Array         # scalar i32 sampling mode (MODE_*)
    m: jax.Array            # scalar i32 participant count (MODE_UNIFORM)
    unbiased: jax.Array     # scalar bool: alpha_i / a_ik correction
    dataset_id: jax.Array   # scalar i32 row into the stacked train/test sets
    # [K, N] bool, True = device i's round-k upload is LOST (chaos
    # injection, ``repro.serve.faults.dropout_mask``): the device is
    # masked out of the eq.-4 aggregation but its tx/compute energy is
    # still charged and the round still waits on it — the attempt
    # happened.  ``None`` (the default) keeps the fault-free compiled
    # program byte-identical; see docs/robustness.md.
    drops: Optional[jax.Array] = None
    # [K, N] f32 per-device per-round uplink bit widths b_ik: each
    # client's round-k gradient is stochastically rounded to b_ik bits
    # before the eq.-4 aggregation (``engine.quantize_stochastic``'s
    # stream, fused into the masked-sum kernel when ``use_kernel``).
    # ``None`` (the default) keeps the full-precision compiled program
    # byte-identical; see docs/compression.md.
    bits: Optional[jax.Array] = None

    @property
    def n_rounds(self) -> int:
        return int(self.probs.shape[-2])

    @property
    def n_devices(self) -> int:
        return int(self.probs.shape[-1])


class SweepResult(NamedTuple):
    """Stacked output of ``run_fl_sweep`` (leading trajectory axis)."""

    params: Any                  # pytree, every leaf [T, ...]
    histories: list[FLHistory]   # per-trajectory, same layout as run_fl's

    def result(self, t: int) -> FLResult:
        params = jax.tree_util.tree_map(lambda x: x[t], self.params)
        return FLResult(params=params, history=self.histories[t])


# ------------------------------------------------------------- sampling

def _draw_mask(sub: jax.Array, a_k: jax.Array, mode: jax.Array,
               m: jax.Array) -> jax.Array:
    """One round's participation mask; bit-identical to the schedulers'
    ``sample`` for the same subkey (the key stream is ``split`` per round
    exactly as in ``run_fl``)."""
    n = a_k.shape[0]

    def bernoulli(_):
        return jax.random.bernoulli(sub, a_k)

    def fixed(_):
        return a_k > 0

    def uniform(_):
        # UniformScheduler sets mask[perm[:m]]; equivalently rank(i) < m.
        perm = jax.random.permutation(sub, n)
        return jnp.argsort(perm) < m

    return jax.lax.switch(mode, (bernoulli, fixed, uniform), None)


def _subkey_stream(key0: jax.Array, n_rounds: int) -> jax.Array:
    """The reference engine's per-round subkeys: key, sub = split(key)."""
    def body(key, _):
        key, sub = jax.random.split(key)
        return key, sub

    _, subs = jax.lax.scan(body, key0, None, length=n_rounds)
    return subs


@jax.jit
def _mask_stream(key0: jax.Array, probs: jax.Array, mode: jax.Array,
                 m: jax.Array) -> jax.Array:
    """All rounds' participation masks [K, N] — the planner's preview of
    the draws the scan body will re-derive from the same key."""
    subs = _subkey_stream(key0, probs.shape[0])
    return jax.vmap(_draw_mask, in_axes=(0, 0, None, None))(subs, probs,
                                                            mode, m)


# ------------------------------------------------------------- planning

def _scheduler_mode(scheduler) -> tuple[int, int, bool]:
    """(mode, m, unbiased) encoding of a scheduler's sampling behaviour."""
    if isinstance(scheduler, ProbabilisticScheduler):
        return MODE_BERNOULLI, 0, bool(scheduler.unbiased_aggregation)
    if isinstance(scheduler, (DeterministicScheduler, EquallyWeightedScheduler,
                              GreedyChannelScheduler, LyapunovScheduler)):
        return MODE_FIXED, 0, False
    if isinstance(scheduler, UniformScheduler):
        return MODE_UNIFORM, int(scheduler.m), False
    raise TypeError(
        f"cannot fuse scheduler {type(scheduler).__name__}; expected one of "
        "Probabilistic/Deterministic/Uniform/EquallyWeighted/"
        "GreedyChannel/Lyapunov")


def _per_round(x: np.ndarray, n_rounds: int, name: str) -> np.ndarray:
    """[N] or [N, K_sol] -> round-major [K, N]."""
    x = np.asarray(x, np.float32)
    if x.ndim == 1:
        return np.broadcast_to(x, (n_rounds, x.shape[0]))
    if x.shape[1] < n_rounds:
        raise ValueError(
            f"{name} covers {x.shape[1]} fading rounds but the config asks "
            f"for {n_rounds}; regenerate the scenario with n_rounds >= that")
    return np.ascontiguousarray(x[:, :n_rounds].T)


def plan_trajectory(problem: WirelessFLProblem,
                    scheduler,
                    parts: Sequence[np.ndarray],
                    config: FLConfig,
                    *,
                    state: Optional[SchedulerState] = None,
                    dataset_id: int = 0,
                    drops: Optional[np.ndarray] = None,
                    bits: Optional[np.ndarray] = None) -> TrajectoryPlan:
    """Build one trajectory's plan, mirroring ``run_fl``'s RNG streams.

    ``state`` lets callers reuse one (possibly batched) ``precompute``
    across many seeds — the solve is by far the most expensive part of
    planning.  The minibatch schedule consumes a
    ``np.random.default_rng(config.seed)`` exactly as the reference
    engine does (draws happen only on rounds with at least one
    participant), so the scanned trajectory is reproducible against it.

    ``drops`` is an optional ``[K, N]`` bool upload-loss table (True =
    the round-k upload from device i never arrives); it rides on the
    plan and switches the sweep into degraded-aggregation mode.

    ``bits`` is an optional ``[N]`` or ``[K, N]`` uplink bit-width table
    (e.g. ``solve_joint_fused(..., bit_menu=...)``'s per-device choice);
    ``config.uplink_bits`` is shorthand for a uniform table.  Either
    switches the sweep into quantized-aggregation mode, which needs
    ``aggregate='stacked'`` (per-client gradients must exist to
    quantise) and mirrors ``run_fl``'s quantiser key stream exactly.
    """
    if config.uplink_bits is not None:
        if bits is not None:
            raise ValueError(
                "pass either config.uplink_bits (uniform) or a per-device "
                "bits table, not both")
        bits = np.full(problem.n_devices, float(config.uplink_bits),
                       np.float32)
    if bits is not None and config.aggregate != "stacked":
        raise ValueError("uplink quantisation requires aggregate='stacked' "
                         "(per-client gradients must exist to quantise)")
    n = problem.n_devices
    assert len(parts) == n
    k_rounds = config.n_rounds
    b = config.batch_per_client
    state = scheduler.precompute(problem) if state is None else state
    mode, m, unbiased = _scheduler_mode(scheduler)

    probs = _per_round(np.asarray(state.a), k_rounds, "selection probabilities")
    t_table = _per_round(np.asarray(problem.tx_time(state.power)), k_rounds,
                         "tx-time table")
    ec = np.asarray(problem.compute_energy(), np.float32)
    e_up = _per_round(np.asarray(problem.upload_energy(state.power)),
                      k_rounds, "upload-energy table")
    comp = np.asarray(problem.cycles_per_sample * problem.dataset_size
                      / problem.cpu_hz, np.float32)

    key0 = jax.random.PRNGKey(config.seed)
    masks = np.asarray(_mask_stream(key0, jnp.asarray(probs),
                                    jnp.int32(mode), jnp.int32(m)))

    # minibatch schedule: same generator, same consumption order as run_fl
    rng = np.random.default_rng(config.seed)
    batch_idx = np.zeros((k_rounds, n, b), np.int32)
    for k in range(k_rounds):
        if masks[k].any():
            batch_idx[k] = np.stack([
                rng.choice(parts[i], size=b, replace=len(parts[i]) < b)
                for i in range(n)])

    return TrajectoryPlan(
        probs=jnp.asarray(probs),
        tx_time=jnp.asarray(t_table),
        round_energy=jnp.asarray(e_up + ec[None, :]),
        comp_time=jnp.asarray(comp),
        agg_weights=jnp.asarray(state.agg_weights, jnp.float32),
        batch_idx=jnp.asarray(batch_idx),
        key=key0,
        lr=jnp.float32(config.lr),
        mode=jnp.int32(mode),
        m=jnp.int32(m),
        unbiased=jnp.asarray(unbiased),
        dataset_id=jnp.int32(dataset_id),
        drops=None if drops is None else jnp.asarray(drops, bool),
        bits=None if bits is None else jnp.asarray(
            _per_round(np.asarray(bits), k_rounds, "bit-width table")),
    )


def plans_from_batch(batch: ProblemBatch,
                     scheduler: ProbabilisticScheduler,
                     parts_list: Sequence[Sequence[np.ndarray]],
                     configs: Sequence[FLConfig],
                     dataset_ids: Optional[Sequence[int]] = None,
                     **solve_kw) -> list[TrajectoryPlan]:
    """One batched solve (PR 1's ``precompute_batch``) -> per-instance plans.

    All instances must share a fleet size (ragged batches pad device
    slots, and the sweep's uniform sampler draws over the padded axis, so
    padding would change the Uniform strategy's stream).  Use this to
    drive a registry-scenario ensemble through the sweep engine with a
    single device-sharded solve.
    """
    sizes = np.asarray(batch.fleet_sizes)
    if not (sizes == sizes[0]).all():
        raise ValueError(
            f"plans_from_batch needs a uniform fleet size, got {sizes}; "
            "stack equal-N instances (no padding) for the FL sweep")
    state = scheduler.precompute_batch(batch, **solve_kw)
    problems = batch.unstack()
    if dataset_ids is None:
        dataset_ids = range(len(problems))
    plans = []
    for i, (problem, parts, cfg, ds) in enumerate(
            zip(problems, parts_list, configs, dataset_ids)):
        st = SchedulerState(a=state.a[i], power=state.power[i],
                            agg_weights=state.agg_weights[i])
        plans.append(plan_trajectory(problem, scheduler, parts, cfg,
                                     state=st, dataset_id=int(ds)))
    return plans


def stack_plans(plans: Sequence[TrajectoryPlan]) -> TrajectoryPlan:
    """Stack per-trajectory plans along a new leading sweep axis."""
    if not plans:
        raise ValueError("stack_plans needs at least one plan")
    with_drops = sum(p.drops is not None for p in plans)
    if 0 < with_drops < len(plans):
        raise ValueError(
            "cannot stack plans with and without drop tables; give the "
            "fault-free plans an all-False [K, N] drops array")
    with_bits = sum(p.bits is not None for p in plans)
    if 0 < with_bits < len(plans):
        raise ValueError(
            "cannot stack plans with and without bit-width tables; give "
            "the full-precision plans an all-32 [K, N] bits array")
    ref = plans[0]
    for p in plans[1:]:
        if (p.n_rounds, p.n_devices, p.batch_idx.shape) != (
                ref.n_rounds, ref.n_devices, ref.batch_idx.shape):
            raise ValueError(
                "all plans in a sweep must share (n_rounds, n_devices, "
                f"batch_per_client); got {p.probs.shape} vs {ref.probs.shape}")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *plans)


# ----------------------------------------------------------- compiled core

class _Static(NamedTuple):
    """Hashable compile-time configuration of the sweep program."""

    n_rounds: int
    batch_per_client: int
    aggregate: str              # "fused" | "stacked"
    renormalize: bool
    include_compute_time: bool
    eval_rounds: tuple[int, ...]
    use_kernel: bool            # stacked path: masked_aggregate Pallas kernel
    kernel_interpret: bool
    donate: bool
    faulted: bool               # plan carries a drops table (degraded mode)
    quantized: bool             # plan carries a bits table (uplink quantise)


def _eval_rounds(config: FLConfig) -> tuple[int, ...]:
    """The reference engine's eval schedule: every eval_every-th round plus
    the final one."""
    ks = [k for k in range(config.n_rounds)
          if (k + 1) % config.eval_every == 0 or k == config.n_rounds - 1]
    return tuple(dict.fromkeys(ks))


@functools.lru_cache(maxsize=32)
def _sweep_fn(static: _Static):
    """Build (and cache) the jitted vmapped whole-sweep program."""
    b = static.batch_per_client
    fused = static.aggregate == "fused"

    def aggregate(gstack, coef):
        if static.use_kernel:
            return masked_aggregate_pytree(gstack, coef,
                                           interpret=static.kernel_interpret)
        return jax.tree_util.tree_map(
            lambda g: jnp.tensordot(coef, g, axes=((0,), (0,))), gstack)

    def trajectory(plan: TrajectoryPlan, params0,
                   train_x, train_y, test_x, test_y):
        n = plan.n_devices
        images = train_x[plan.dataset_id]      # [n_train, 28, 28, 1]
        labels = train_y[plan.dataset_id]

        def round_body(carry, xs):
            params, key, cum_t, cum_e = carry
            a_k, t_k, e_k, idx = xs[:4]
            rest = list(xs[4:])
            drop_k = rest.pop(0) if static.faulted else None
            bits_k = rest.pop(0) if static.quantized else None
            key, sub = jax.random.split(key)
            mask = _draw_mask(sub, a_k, plan.mode, plan.m)
            fmask = mask.astype(jnp.float32)
            any_part = jnp.any(mask)

            # -- accounting (paper Sec. V-B): straggler tx time, summed E --
            # charged over the *attempted* mask even in degraded mode: a
            # lost upload still spent its tx/compute energy and the round
            # still waited on the straggler (docs/robustness.md)
            t_eff = t_k + plan.comp_time if static.include_compute_time else t_k
            round_time = jnp.where(
                any_part, jnp.max(jnp.where(mask, t_eff, -jnp.inf)), 0.0)
            round_energy = jnp.sum(jnp.where(mask, e_k, 0.0))

            # -- server update (eq. 4) --------------------------------------
            # degraded mode: survivors = attempted minus lost uploads; only
            # they enter the aggregation (renormalize redistributes their
            # weight, else the update is simply smaller)
            if drop_k is not None:
                mask = mask & ~drop_k
                fmask = mask.astype(jnp.float32)
            alpha = plan.agg_weights
            alpha = jnp.where(plan.unbiased,
                              alpha / jnp.maximum(a_k, 1e-6), alpha)
            coef = alpha * fmask
            if static.renormalize:
                coef = coef / jnp.maximum(coef.sum(), 1e-12)
            img = images[idx]                  # [N, b, 28, 28, 1]
            lab = labels[idx]
            if fused:
                sw = (jnp.repeat(coef, b) / b).astype(jnp.float32)
                grads = jax.grad(cnn.loss_fn)(
                    params, img.reshape(n * b, 28, 28, 1),
                    lab.reshape(n * b), sw)
            else:
                def client_grad(ci, cl):
                    return jax.grad(cnn.loss_fn)(params, ci, cl)
                gstack = jax.vmap(client_grad)(img, lab)
                if bits_k is not None:
                    # fold_in (not split): same quantiser key stream as
                    # the reference engine's stacked path
                    qkey = jax.random.fold_in(sub, 1)
                    if static.use_kernel:
                        grads = quantized_aggregate_pytree(
                            gstack, coef, qkey, bits_k,
                            interpret=static.kernel_interpret)
                    else:
                        grads = aggregate(
                            _quantize_tree(gstack, qkey, bits_k), coef)
                else:
                    grads = aggregate(gstack, coef)
            # an all-zero coef (empty round) makes grads exactly zero, so
            # the update is a no-op — same outcome as the reference's skip
            params = jax.tree_util.tree_map(
                lambda p, g: p - plan.lr * g, params, grads)

            carry = (params, key, cum_t + round_time, cum_e + round_energy)
            return carry, (round_time, round_energy,
                           jnp.sum(mask).astype(jnp.int32))

        xs = (plan.probs, plan.tx_time, plan.round_energy, plan.batch_idx)
        if static.faulted:
            xs = xs + (plan.drops,)
        if static.quantized:
            xs = xs + (plan.bits,)
        carry = (params0, plan.key, jnp.float32(0.0), jnp.float32(0.0))
        ys_parts, accs = [], []
        start = 0
        for end in static.eval_rounds:         # static segment boundaries
            seg = jax.tree_util.tree_map(
                lambda x, s=start, e=end: x[s:e + 1], xs)
            carry, ys = jax.lax.scan(round_body, carry, seg)
            ys_parts.append(ys)
            logits = cnn.apply(carry[0], test_x[plan.dataset_id])
            accs.append(jnp.mean(
                (jnp.argmax(logits, -1) == test_y[plan.dataset_id]
                 ).astype(jnp.float32)))
            start = end + 1

        ys = jax.tree_util.tree_map(
            lambda *parts: jnp.concatenate(parts), *ys_parts)
        return carry[0], ys, jnp.stack(accs)

    def sweep(plans, params0, train_x, train_y, test_x, test_y):
        return jax.vmap(trajectory, in_axes=(0, 0, None, None, None, None))(
            plans, params0, train_x, train_y, test_x, test_y)

    donate = (1,) if static.donate else ()
    return jax.jit(sweep, donate_argnums=donate)


# ------------------------------------------------------------- public API

def _stack_datasets(data: Dataset | Sequence[Dataset]):
    if isinstance(data, Dataset):
        data = [data]
    x = jnp.asarray(np.stack([d.images for d in data]))
    y = jnp.asarray(np.stack([d.labels for d in data]))
    return x, y


def run_fl_sweep(plans: TrajectoryPlan,
                 train: Dataset | Sequence[Dataset],
                 test: Dataset | Sequence[Dataset],
                 config: FLConfig,
                 init_params: Any,
                 *,
                 use_kernel: bool = False,
                 kernel_interpret: Optional[bool] = None,
                 shard: bool = True,
                 donate_params: Optional[bool] = None) -> SweepResult:
    """Run every trajectory of a stacked plan as one jitted, sharded call.

    ``plans`` is a ``stack_plans`` output ([T, ...] leaves);
    ``init_params`` a per-trajectory stacked params pytree (the reference
    engine inits from ``PRNGKey(seed + 17)`` — see ``init_sweep_params``).
    ``train``/``test`` may be a single shared dataset or one per
    ``dataset_id``.  ``use_kernel`` routes the stacked aggregation through
    the ``masked_aggregate`` Pallas kernel (compiled on TPU; interpret
    mode elsewhere unless ``kernel_interpret`` overrides).  ``shard``
    splits the trajectory axis over the local devices.  ``donate_params``
    donates the init-params buffers to the call (default: on accelerators
    only — donation invalidates the caller's copy).
    """
    n_traj = int(plans.probs.shape[0])
    if plans.n_rounds != config.n_rounds:
        raise ValueError(f"plan has {plans.n_rounds} rounds, "
                         f"config.n_rounds={config.n_rounds}")
    backend = jax.default_backend()
    if kernel_interpret is None:
        kernel_interpret = backend != "tpu"
    if donate_params is None:
        donate_params = backend not in ("cpu",)
    static = _Static(
        n_rounds=config.n_rounds, batch_per_client=config.batch_per_client,
        aggregate=config.aggregate, renormalize=config.renormalize,
        include_compute_time=config.include_compute_time,
        eval_rounds=_eval_rounds(config), use_kernel=use_kernel,
        kernel_interpret=kernel_interpret, donate=donate_params,
        faulted=plans.drops is not None,
        quantized=plans.bits is not None)
    if config.aggregate not in ("fused", "stacked"):
        raise ValueError(f"unknown aggregate mode {config.aggregate!r}")
    if use_kernel and config.aggregate != "stacked":
        raise ValueError("use_kernel requires aggregate='stacked'")
    if plans.bits is not None and config.aggregate != "stacked":
        raise ValueError("quantized plans (bits tables) require "
                         "aggregate='stacked'")
    if config.uplink_bits is not None and plans.bits is None:
        raise ValueError("config.uplink_bits is set but the stacked plans "
                         "carry no bits table; build them with "
                         "plan_trajectory(..., config) so the table exists")

    train_x, train_y = _stack_datasets(train)
    test_x, test_y = _stack_datasets(test)

    sharding = batch_sharding(n_traj) if shard else None
    if sharding is not None:
        plans = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sharding), plans)
        init_params = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sharding), init_params)

    params, ys, accs = _sweep_fn(static)(
        plans, init_params, train_x, train_y, test_x, test_y)
    round_time, round_energy, participants = jax.device_get(ys)
    accs = np.asarray(jax.device_get(accs))

    eval_rounds = np.asarray(static.eval_rounds)
    histories = []
    for t in range(n_traj):
        # float64 cumulation matches the reference engine's python-float
        # accumulation of per-round float32 increments
        sim_time = np.cumsum(round_time[t], dtype=np.float64)
        energy = np.cumsum(round_energy[t], dtype=np.float64)
        histories.append(FLHistory(
            rounds=np.arange(config.n_rounds),
            sim_time=sim_time, energy=energy,
            participants=np.asarray(participants[t]),
            eval_rounds=eval_rounds,
            eval_time=sim_time[eval_rounds],
            eval_acc=accs[t]))
    return SweepResult(params=params, histories=histories)


def init_sweep_params(configs: Sequence[FLConfig]) -> Any:
    """Per-trajectory model inits, stacked — the reference engine's
    ``cnn.init(PRNGKey(seed + 17))`` per config."""
    inits = [cnn.init(jax.random.PRNGKey(c.seed + 17)) for c in configs]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *inits)


def run_fl_scan(problem: WirelessFLProblem,
                scheduler,
                train: Dataset,
                parts: Sequence[np.ndarray],
                test: Dataset,
                config: FLConfig,
                init_params: Any | None = None,
                **sweep_kw) -> FLResult:
    """Drop-in scan-fused replacement for ``run_fl`` (one trajectory).

    Same signature and history layout as the reference engine; the
    trajectory agrees with it to float tolerance (same participation and
    minibatch streams, same eq.-4 update, same accounting).
    """
    plan = plan_trajectory(problem, scheduler, parts, config)
    plans = jax.tree_util.tree_map(lambda x: x[None], plan)
    if init_params is None:
        params0 = init_sweep_params([config])
    else:
        params0 = jax.tree_util.tree_map(lambda x: x[None], init_params)
    sweep = run_fl_sweep(plans, train, test, config, params0, **sweep_kw)
    return sweep.result(0)
