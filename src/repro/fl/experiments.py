"""Paper experiment reproduction: Figures 1-2 and Tables I-IV.

Two scenarios (Sec. V-A):
  * highly biased:  Dirichlet beta = 0.1, tau^th = 0.08 s
  * mildly biased:  Dirichlet beta = 0.3, tau^th = 0.5 s

Four strategies; probabilistic/uniform results averaged over ``n_runs``
seeds (paper: 10).  Accuracy targets are re-anchored to the synthetic
dataset (DESIGN.md §7): we report time/energy to reach the two targets
(low/high) analogous to the paper's 59/80% (scenario 1) and 70/86%
(scenario 2).

Two engines drive the grid (``engine=`` on :func:`run_scenario` /
:func:`run_grid`):

* ``"loop"`` — the reference python-loop engine, one ``run_fl`` per
  (strategy, seed);
* ``"scan"`` — the scan-fused sweep engine: every (strategy x seed)
  trajectory of the scenario is compiled into ONE jitted, optionally
  device-sharded call (``repro.fl.scan_engine``), with one scheduler
  solve per strategy shared across its seeds.  ``run_grid`` additionally
  fuses *scenarios* into the same call when their shapes agree, realising
  the full (seed x strategy x scenario) vmap.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import make_scheduler, ProbabilisticScheduler
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_mnist_like
from repro.fl.engine import FLConfig, FLHistory, run_fl
from repro.core.problem import sample_problem

STRATEGIES = ("probabilistic", "deterministic", "uniform", "equally_weighted")
_STOCHASTIC = ("probabilistic", "uniform")


@dataclasses.dataclass
class ScenarioSpec:
    name: str
    beta: float
    tau_th: float
    targets: tuple[float, float]
    n_devices: int = 100
    n_train: int = 12_000
    n_test: int = 2_000
    n_rounds: int = 400
    n_runs: int = 3
    lr: float = 0.1
    batch_per_client: int = 8
    eval_every: int = 10
    solver: str = "alternating"     # paper Algorithm 2; "optimal" = ours


HIGH_BIAS = ScenarioSpec("highly_biased", beta=0.1, tau_th=0.08,
                         targets=(0.50, 0.75))
MILD_BIAS = ScenarioSpec("mildly_biased", beta=0.3, tau_th=0.5,
                         targets=(0.60, 0.85))


def _make_problem_and_data(spec: ScenarioSpec, seed: int):
    train, test = make_mnist_like(spec.n_train, spec.n_test, seed=seed)
    parts = dirichlet_partition(train, spec.n_devices, spec.beta, seed=seed + 1)
    sizes = np.array([len(p) for p in parts])
    problem = sample_problem(seed + 2, spec.n_devices, tau_th=spec.tau_th,
                             dirichlet_sizes=sizes)
    return problem, train, parts, test


def _scheduler(name: str, problem, spec: ScenarioSpec):
    if name == "uniform":
        st = ProbabilisticScheduler(solver=spec.solver).precompute(problem)
        m = max(1, int(round(float(np.asarray(st.a).sum()))))
        return make_scheduler("uniform", m=m)
    if name == "probabilistic":
        return make_scheduler(name, solver=spec.solver)
    return make_scheduler(name)


def _run_config(spec: ScenarioSpec, seed0: int, run: int) -> FLConfig:
    return FLConfig(n_rounds=spec.n_rounds, lr=spec.lr,
                    batch_per_client=spec.batch_per_client,
                    eval_every=spec.eval_every, seed=seed0 + 101 * run)


def run_scenario(spec: ScenarioSpec, seed0: int = 0,
                 strategies=STRATEGIES, verbose: bool = True,
                 engine: str = "loop") -> dict:
    """Returns {strategy: {"curves": [...], "table": {...}}}.

    ``engine="scan"`` runs the whole (strategy x seed) grid as one
    compiled sweep call; ``engine="loop"`` is the per-run reference path.
    """
    if engine == "scan":
        return _run_scenario_scan(spec, seed0, strategies, verbose)
    if engine != "loop":
        raise ValueError(f"unknown engine {engine!r}; use 'loop' or 'scan'")
    out: dict = {"spec": dataclasses.asdict(spec), "strategies": {}}
    for strat in strategies:
        runs = []
        stochastic = strat in _STOCHASTIC
        n_runs = spec.n_runs if stochastic else 1
        for r in range(n_runs):
            problem, train, parts, test = _make_problem_and_data(spec, seed0)
            sch = _scheduler(strat, problem, spec)
            cfg = _run_config(spec, seed0, r)
            res = run_fl(problem, sch, train, parts, test, cfg)
            runs.append(res.history)
            if verbose:
                h = res.history
                print(f"  {spec.name}/{strat} run{r}: "
                      f"final_acc={h.eval_acc[-1]:.3f} "
                      f"time={h.sim_time[-1]:.0f}s "
                      f"energy={h.energy[-1]:.0f}J", flush=True)
        out["strategies"][strat] = _summarise(runs, spec.targets)
    return out


# ------------------------------------------------- scan-fused sweep engine

def build_scenario_plans(spec: ScenarioSpec, seed0: int = 0,
                         strategies=STRATEGIES, dataset_id: int = 0):
    """The scenario's full (strategy x seed) grid as trajectory plans.

    One scheduler solve per strategy, shared across its seeds.  Returns
    ``(plans, labels, configs, train, test)`` where ``labels[t]`` names
    trajectory ``t``'s strategy.
    """
    from repro.fl.scan_engine import plan_trajectory

    problem, train, parts, test = _make_problem_and_data(spec, seed0)
    plans, labels, configs = [], [], []
    for strat in strategies:
        sch = _scheduler(strat, problem, spec)
        state = sch.precompute(problem)
        n_runs = spec.n_runs if strat in _STOCHASTIC else 1
        for r in range(n_runs):
            cfg = _run_config(spec, seed0, r)
            plans.append(plan_trajectory(problem, sch, parts, cfg,
                                         state=state, dataset_id=dataset_id))
            labels.append(strat)
            configs.append(cfg)
    return plans, labels, configs, train, test


def _group_summaries(histories, labels, targets, spec_name, verbose) -> dict:
    out: dict = {}
    for strat in dict.fromkeys(labels):
        runs = [h for h, s in zip(histories, labels) if s == strat]
        if verbose:
            for r, h in enumerate(runs):
                print(f"  {spec_name}/{strat} run{r}: "
                      f"final_acc={h.eval_acc[-1]:.3f} "
                      f"time={h.sim_time[-1]:.0f}s "
                      f"energy={h.energy[-1]:.0f}J", flush=True)
        out[strat] = _summarise(runs, targets)
    return out


def _run_scenario_scan(spec: ScenarioSpec, seed0, strategies, verbose) -> dict:
    from repro.fl.scan_engine import (init_sweep_params, run_fl_sweep,
                                      stack_plans)

    plans, labels, configs, train, test = build_scenario_plans(
        spec, seed0, strategies)
    sweep = run_fl_sweep(stack_plans(plans), train, test, configs[0],
                         init_sweep_params(configs))
    out: dict = {"spec": dataclasses.asdict(spec), "engine": "scan",
                 "strategies": _group_summaries(sweep.histories, labels,
                                                spec.targets, spec.name,
                                                verbose)}
    return out


def _scan_compatible(specs) -> bool:
    keys = [(s.n_rounds, s.eval_every, s.batch_per_client, s.n_devices,
             s.n_train, s.n_test) for s in specs]
    return all(k == keys[0] for k in keys)


def run_grid(specs, seed0: int = 0, strategies=STRATEGIES,
             verbose: bool = True, engine: str = "scan") -> dict:
    """The full (seed x strategy x scenario) grid, scenario-keyed results.

    With ``engine="scan"`` and shape-compatible specs (same rounds /
    fleet / dataset sizes — the paper's two scenarios qualify) every
    trajectory of every scenario becomes one row of a single vmapped,
    jitted sweep call; incompatible specs fall back to one call per
    scenario.  ``engine="loop"`` runs the reference engine throughout.
    """
    if engine == "loop" or (engine == "scan" and not _scan_compatible(specs)):
        return {spec.name: run_scenario(spec, seed0, strategies, verbose,
                                        engine=engine)
                for spec in specs}
    if engine != "scan":
        raise ValueError(f"unknown engine {engine!r}; use 'loop' or 'scan'")

    from repro.fl.scan_engine import (init_sweep_params, run_fl_sweep,
                                      stack_plans)

    plans, labels, configs, trains, tests, spans = [], [], [], [], [], []
    for i, spec in enumerate(specs):
        p, lab, cfg, train, test = build_scenario_plans(
            spec, seed0, strategies, dataset_id=i)
        spans.append((len(plans), len(plans) + len(p)))
        plans += p
        labels += lab
        configs += cfg
        trains.append(train)
        tests.append(test)

    sweep = run_fl_sweep(stack_plans(plans), trains, tests, configs[0],
                         init_sweep_params(configs))
    out = {}
    for spec, (lo, hi) in zip(specs, spans):
        out[spec.name] = {
            "spec": dataclasses.asdict(spec), "engine": "scan",
            "strategies": _group_summaries(sweep.histories[lo:hi],
                                           labels[lo:hi], spec.targets,
                                           spec.name, verbose)}
    return out


def _summarise(runs: list[FLHistory], targets) -> dict:
    lo, hi = targets
    t_lo = [h.time_to_accuracy(lo) for h in runs]
    t_hi = [h.time_to_accuracy(hi) for h in runs]
    e_lo = [h.energy_to_accuracy(lo) for h in runs]
    e_hi = [h.energy_to_accuracy(hi) for h in runs]

    def agg(vals):
        vals = np.asarray(vals, float)
        if np.all(np.isnan(vals)):
            return None
        return float(np.nanmean(vals))

    return {
        "curves": [{"time": h.eval_time.tolist(),
                    "acc": h.eval_acc.tolist()} for h in runs],
        "final_acc": float(np.mean([h.eval_acc[-1] for h in runs])),
        "mean_participants": float(np.mean([h.participants.mean() for h in runs])),
        "total_time_s": float(np.mean([h.sim_time[-1] for h in runs])),
        "total_energy_j": float(np.mean([h.energy[-1] for h in runs])),
        "table": {
            "time_to_low": agg(t_lo), "time_to_high": agg(t_hi),
            "energy_to_low": agg(e_lo), "energy_to_high": agg(e_hi),
        },
    }


def format_tables(result: dict, spec: ScenarioSpec) -> str:
    lo, hi = spec.targets
    lines = [f"\n=== {spec.name}: time/energy to accuracy "
             f"({lo:.0%} / {hi:.0%}) — paper Tables "
             f"{'I-II' if spec.beta < 0.2 else 'III-IV'} analogue ==="]
    hdr = f"{'strategy':20s} {'t@lo (s)':>10} {'t@hi (s)':>10} {'E@lo (J)':>10} {'E@hi (J)':>10}"
    lines.append(hdr)
    def fmt(v):
        return "NA".rjust(10) if v is None else f"{v:10.0f}"

    for strat, res in result["strategies"].items():
        t = res["table"]
        lines.append(f"{strat:20s} {fmt(t['time_to_low'])} {fmt(t['time_to_high'])} "
                     f"{fmt(t['energy_to_low'])} {fmt(t['energy_to_high'])}")
    return "\n".join(lines)
