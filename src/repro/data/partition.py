"""Non-IID data partitioning across FL clients.

Implements the Dirichlet label-skew recipe of Li et al. [16] used by the
paper: for every class c, draw p_c ~ Dir_N(beta) and split class-c samples
across the N clients proportionally.  beta=0.1 reproduces the paper's
"highly biased" scenario (most clients miss several labels), beta=0.3 the
"mildly biased" one.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.data.synthetic import Dataset


def dirichlet_partition(dataset: Dataset, n_clients: int, beta: float,
                        seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    """Returns per-client index arrays. Retries until every client has
    at least ``min_size`` samples (standard practice for small beta)."""
    rng = np.random.default_rng(seed)
    labels = dataset.labels
    n_classes = int(labels.max()) + 1
    for _ in range(100):
        idx_per_client: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, beta))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[client].extend(part.tolist())
        sizes = np.array([len(ix) for ix in idx_per_client])
        if sizes.min() >= min_size:
            return [np.asarray(sorted(ix), dtype=np.int64) for ix in idx_per_client]
    raise RuntimeError("could not produce a partition with min_size per client")


def label_distribution(dataset: Dataset, parts: Sequence[np.ndarray]) -> np.ndarray:
    """[n_clients, n_classes] label histogram — used in tests/plots."""
    n_classes = int(dataset.labels.max()) + 1
    out = np.zeros((len(parts), n_classes))
    for i, ix in enumerate(parts):
        binc = np.bincount(dataset.labels[ix], minlength=n_classes)
        out[i] = binc
    return out


def heterogeneity_index(dist: np.ndarray) -> float:
    """Mean total-variation distance of client label dists from global —
    0 = iid, ->1 = one-class clients. Used to verify beta ordering."""
    global_p = dist.sum(0) / max(dist.sum(), 1)
    client_p = dist / np.maximum(dist.sum(1, keepdims=True), 1)
    return float(np.mean(np.abs(client_p - global_p).sum(1) / 2))
