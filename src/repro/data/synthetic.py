"""Synthetic MNIST-stand-in: procedural 28x28 digit renderings.

MNIST itself is not available offline in this container; the paper's claims
concern the *relative ordering of client-selection strategies*, which only
needs a learnable 10-class image problem with the same shape/cardinality
semantics.  We render each digit 0-9 from a 5x7 seed glyph, upsampled to
28x28 with random translation, scale jitter, stroke thickness variation and
pixel noise.  A centrally-trained copy of the paper's CNN exceeds 90% test
accuracy on it, so strategy orderings are meaningful.  The substitution is
documented in DESIGN.md §7 and EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

# 5x7 seed glyphs for digits 0-9 ('#' = ink).
_GLYPHS = [
    [" ### ", "#   #", "#   #", "#   #", "#   #", "#   #", " ### "],  # 0
    ["  #  ", " ##  ", "  #  ", "  #  ", "  #  ", "  #  ", " ### "],  # 1
    [" ### ", "#   #", "    #", "   # ", "  #  ", " #   ", "#####"],  # 2
    [" ### ", "#   #", "    #", "  ## ", "    #", "#   #", " ### "],  # 3
    ["   # ", "  ## ", " # # ", "#  # ", "#####", "   # ", "   # "],  # 4
    ["#####", "#    ", "#### ", "    #", "    #", "#   #", " ### "],  # 5
    [" ### ", "#    ", "#    ", "#### ", "#   #", "#   #", " ### "],  # 6
    ["#####", "    #", "   # ", "  #  ", "  #  ", " #   ", " #   "],  # 7
    [" ### ", "#   #", "#   #", " ### ", "#   #", "#   #", " ### "],  # 8
    [" ### ", "#   #", "#   #", " ####", "    #", "    #", " ### "],  # 9
]

_GLYPH_ARRAYS = np.stack([
    np.array([[1.0 if c == "#" else 0.0 for c in row] for row in glyph])
    for glyph in _GLYPHS
])  # [10, 7, 5]


class Dataset(NamedTuple):
    images: np.ndarray   # [n, 28, 28, 1] float32 in [0, 1]
    labels: np.ndarray   # [n] int32


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    glyph = _GLYPH_ARRAYS[digit]
    # scale jitter: glyph occupies 14..22 pixels of height
    h = rng.integers(14, 23)
    w = max(8, int(h * 5 / 7 * rng.uniform(0.85, 1.15)))
    ys = np.clip((np.arange(h) * 7 / h).astype(int), 0, 6)
    xs = np.clip((np.arange(w) * 5 / w).astype(int), 0, 4)
    up = glyph[np.ix_(ys, xs)]
    # stroke thickness: occasional dilation
    if rng.random() < 0.5:
        pad = np.pad(up, 1)
        up = np.maximum(up, np.maximum.reduce([
            pad[:-2, 1:-1], pad[2:, 1:-1], pad[1:-1, :-2], pad[1:-1, 2:]])) * rng.uniform(0.75, 1.0)
    img = np.zeros((28, 28))
    oy = rng.integers(0, 28 - h + 1)
    ox = rng.integers(0, 28 - w + 1)
    img[oy:oy + h, ox:ox + w] = up
    # intensity jitter + additive noise
    img = img * rng.uniform(0.7, 1.0) + rng.normal(0, 0.08, (28, 28))
    return np.clip(img, 0.0, 1.0)


def make_dataset(n: int, seed: int = 0) -> Dataset:
    """n samples with uniform labels."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    images = np.stack([_render(int(lab), rng)
                       for lab in labels]).astype(np.float32)
    return Dataset(images=images[..., None], labels=labels)


def make_mnist_like(n_train: int = 12_000, n_test: int = 2_000,
                    seed: int = 0) -> tuple[Dataset, Dataset]:
    return make_dataset(n_train, seed), make_dataset(n_test, seed + 1)
