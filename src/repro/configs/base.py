"""Architecture configuration schema for the model zoo.

Every assigned architecture is expressed as an ``ArchConfig`` composed of
per-layer *block kinds* arranged in a repeating ``period`` (so the layer
stack lowers as ``lax.scan`` over stacked period parameters — compile time
stays flat in depth).  Block kinds:

  "attn"    — GQA self-attention (RoPE, optional sliding window / softcap)
  "gattn"   — global (full-context) variant in local/global patterns
  "mla"     — DeepSeek multi-head latent attention
  "mamba"   — Mamba2 SSD block
  "shared_attn" — zamba2-style attention whose params are *shared* across
                  all its occurrences (closure params, not period-stacked)

Each non-mamba layer carries an MLP ("dense" SwiGLU/GeGLU or "moe").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None   # gemma3: 1e6 on global layers
    window: Optional[int] = None                # sliding-window size (local layers)
    logit_softcap: Optional[float] = None       # gemma2: 50.0
    qk_norm: bool = False                       # gemma3
    nope_on_global: bool = False                # llama4 iRoPE: no RoPE on global layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    first_dense: int = 0       # deepseek: first layer uses a dense MLP


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None   # v2-lite: no q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend *stub*: precomputed embeddings enter the backbone.

    kind="vision": `n_prefix` patch embeddings are projected and prepended
    to the text sequence.  kind="audio": `n_frames` frame embeddings feed
    the encoder (whisper).  The conv/ViT producing them is out of scope by
    assignment (DESIGN.md §2)."""
    kind: str                    # "vision" | "audio"
    n_prefix: int = 0            # vision tokens prepended
    n_frames: int = 0            # audio encoder frames
    d_frontend: int = 1024       # raw embedding dim before projection


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    period: tuple[str, ...]      # block kinds, cycled over layers
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[FrontendConfig] = None
    enc_layers: int = 0          # whisper encoder depth (0 = decoder-only)
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    mlp_act: str = "silu"        # "silu" (SwiGLU) | "gelu" (GeGLU)
    citation: str = ""
    # shapes this arch cannot serve (documented skips, DESIGN.md §4)
    skip_shapes: tuple[str, ...] = ()

    # ------------------------------------------------------------- helpers
    def layer_kinds(self) -> list[str]:
        reps = math.ceil(self.n_layers / len(self.period))
        return list((self.period * reps)[: self.n_layers])

    @property
    def d_head(self) -> int:
        return self.attn.d_head if self.attn else 0

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks); used for the
        paper's gradient-size S and for roofline MODEL_FLOPS."""
        from repro.models.zoo import param_count   # lazy: avoids cycle
        return param_count(self)

    def n_active_params(self) -> int:
        from repro.models.zoo import param_count
        return param_count(self, active_only=True)

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ArchConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts — runs a real fwd/train step on CPU."""
        attn = self.attn
        if attn is not None:
            n_h = max(2, min(4, attn.n_heads))
            n_kv = max(1, min(attn.n_kv_heads, n_h))
            attn = dataclasses.replace(
                attn, n_heads=n_h, n_kv_heads=n_kv,
                d_head=d_model // n_h,
                window=min(attn.window, 64) if attn.window else None)
        moe = self.moe
        if moe is not None:
            # capacity_factor 8: smoke tests verify wiring + decode parity,
            # which token dropping would (legitimately) break; dropping
            # behaviour is covered by the dedicated MoE unit tests.
            moe = dataclasses.replace(
                moe, n_experts=min(4, moe.n_experts),
                top_k=min(2, moe.top_k), d_ff_expert=d_model * 2,
                d_ff_shared=d_model * 2 if moe.n_shared else 0,
                first_dense=min(1, moe.first_dense),
                capacity_factor=8.0)
        mla = self.mla
        if mla is not None:
            mla = dataclasses.replace(mla, kv_lora_rank=64, rope_head_dim=16,
                                      nope_head_dim=32, v_head_dim=32)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, d_state=16, head_dim=32, chunk=32)
        fe = self.frontend
        if fe is not None:
            fe = dataclasses.replace(fe, n_prefix=min(fe.n_prefix, 8),
                                     n_frames=min(fe.n_frames, 16),
                                     d_frontend=64)
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=n_layers,
            d_model=d_model, d_ff=d_model * 4, vocab=vocab, attn=attn,
            moe=moe, mla=mla, ssm=ssm, frontend=fe,
            enc_layers=min(self.enc_layers, 2))


# ------------------------------------------------------------ input shapes

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
