"""Gemma-3 1B [hf:google/gemma-3-1b-pt] — dense decoder, 5:1
local(512-window):global layer pattern, MQA (kv=1), qk-norm, dual RoPE
bases (10k local / 1M global), 262k vocab, 128k context."""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    d_ff=6912,
    vocab=262_144,
    period=("attn", "attn", "attn", "attn", "attn", "gattn"),
    attn=AttnConfig(n_heads=4, n_kv_heads=1, d_head=256,
                    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
                    window=512, qk_norm=True),
    mlp_act="gelu",
    citation="hf:google/gemma-3-1b-pt",
    skip_shapes=(),
)
