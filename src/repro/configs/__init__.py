"""Architecture + input-shape registry."""
from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape

from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.gemma2_27b import CONFIG as _gemma2
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.internvl2_2b import CONFIG as _internvl
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.gemma3_1b import CONFIG as _gemma3

from repro.configs.demo_100m import CONFIG as _demo

# the 10 assigned architectures (dry-run / roofline matrix)
ARCHS: dict[str, ArchConfig] = {
    cfg.name: cfg
    for cfg in [_deepseek, _phi3, _gemma2, _danube, _zamba2,
                _internvl, _mamba2, _whisper, _llama4, _gemma3]
}

# + auxiliary configs usable via --arch but outside the assigned matrix
EXTRA_ARCHS: dict[str, ArchConfig] = {_demo.name: _demo}


def get_arch(name: str) -> ArchConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in EXTRA_ARCHS:
        return EXTRA_ARCHS[name]
    raise KeyError(f"unknown arch {name!r}; available: "
                   f"{sorted(ARCHS) + sorted(EXTRA_ARCHS)}")


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


__all__ = ["ARCHS", "INPUT_SHAPES", "ArchConfig", "InputShape",
           "get_arch", "get_shape"]
