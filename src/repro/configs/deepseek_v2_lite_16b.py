"""DeepSeek-V2-Lite 16B [arXiv:2405.04434] — MoE with multi-head latent
attention (MLA).  27L, d_model 2048, 16 heads, MLA kv_lora=512, MoE:
64 routed experts top-6 + 2 shared, expert d_ff 1408; first layer dense
(d_ff 10944 per the model card); vocab 102400."""
from repro.configs.base import ArchConfig, AttnConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    d_ff=10944,                      # dense MLP of layer 0
    vocab=102_400,
    period=("mla",),
    attn=AttnConfig(n_heads=16, n_kv_heads=16, d_head=128,
                    rope_theta=10_000.0),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared=2, d_ff_shared=2816, first_dense=1),
    citation="arXiv:2405.04434",
    # MLA's latent cache is 576 B-elements/token: 500k-token decode is
    # shardable (DESIGN.md §4) => long_500k runs.
    skip_shapes=(),
)
