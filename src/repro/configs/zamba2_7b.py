"""Zamba2 7B [arXiv:2411.15242] — hybrid: Mamba2 backbone with a *shared*
attention+MLP block applied every 6th layer (weights reused across
occurrences; the per-occurrence LoRA of the real model is simplified away,
DESIGN.md §4).  ssm_state=64."""
from repro.configs.base import ArchConfig, AttnConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab=32_000,
    period=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    attn=AttnConfig(n_heads=32, n_kv_heads=32, d_head=112,
                    rope_theta=10_000.0, window=4096),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    citation="arXiv:2411.15242",
    skip_shapes=(),                  # SSM-dominated => long_500k runs
)
