"""Llama-4-Scout 17B-A16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE
(16 experts, top-1, + shared expert every layer) with iRoPE-style
attention: 3 chunked-local RoPE layers then 1 global NoPE layer per
period.  The chunked-local layers bound the KV cache => long_500k runs."""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab=202_048,
    period=("attn", "attn", "attn", "gattn"),
    attn=AttnConfig(n_heads=40, n_kv_heads=8, d_head=128,
                    rope_theta=500_000.0, window=8192, nope_on_global=True),
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  n_shared=1, d_ff_shared=8192),
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    skip_shapes=(),
)
