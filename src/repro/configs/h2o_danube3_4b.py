"""H2O-Danube3 4B [arXiv:2401.16818] — llama/mistral-style dense decoder
with sliding-window attention (all layers, window 4096), GQA kv=8."""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    d_ff=10240,
    vocab=32_000,
    period=("attn",),
    attn=AttnConfig(n_heads=32, n_kv_heads=8, d_head=120,
                    rope_theta=10_000.0, window=4096),
    citation="arXiv:2401.16818",
    skip_shapes=(),                  # SWA everywhere => long_500k decodes
)
