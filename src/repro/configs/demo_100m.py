"""demo-100m — a ~125M-parameter dense decoder used by the end-to-end
training driver (examples / launch.train): small enough to train a few
hundred steps on this CPU container, big enough to exercise the full
production path (scan stack, GQA, SwiGLU, AdamW, FL cohort weighting)."""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="demo-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    d_ff=3072,
    vocab=16_384,
    period=("attn",),
    attn=AttnConfig(n_heads=12, n_kv_heads=4, d_head=64,
                    rope_theta=10_000.0),
    citation="(framework demo config)",
    skip_shapes=("long_500k",),
)
