"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder transformer.
The mel-spectrogram + conv frontend is the sanctioned stub: input_specs()
supplies 1500 precomputed frame embeddings to the 32L encoder; the 32L
decoder cross-attends.  Sinusoidal positions (the learned-table detail of
the original is simplified, DESIGN.md §4).  Full attention decoder =>
long_500k skipped (and 500k decoder tokens have no audio-task meaning)."""
from repro.configs.base import ArchConfig, AttnConfig, FrontendConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                     # decoder depth; enc_layers below
    d_model=1280,
    d_ff=5120,
    vocab=51_866,
    period=("attn",),
    attn=AttnConfig(n_heads=20, n_kv_heads=20, d_head=64,
                    rope_theta=10_000.0),
    frontend=FrontendConfig(kind="audio", n_frames=1500, d_frontend=1280),
    enc_layers=32,
    citation="arXiv:2212.04356",
    skip_shapes=("long_500k",),
)
