"""Gemma-2 27B [arXiv:2408.00118] — dense decoder with alternating
local(4096-window)/global attention, attention- and final-logit softcaps,
GeGLU.  Sliding-window layers make long_500k decode viable."""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    d_ff=36864,
    vocab=256_000,
    period=("attn", "gattn"),        # local, global, local, ...
    attn=AttnConfig(n_heads=32, n_kv_heads=16, d_head=128,
                    rope_theta=10_000.0, window=4096, logit_softcap=50.0),
    final_logit_softcap=30.0,
    mlp_act="gelu",
    citation="arXiv:2408.00118",
    skip_shapes=(),
)
