"""Phi-3-medium 14B [arXiv:2404.14219] — dense decoder, RoPE + SwiGLU +
GQA (40 heads, 10 kv).  Pure full attention => long_500k skipped
(DESIGN.md §4)."""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    d_ff=17920,
    vocab=100_352,
    period=("attn",),
    attn=AttnConfig(n_heads=40, n_kv_heads=10, d_head=128,
                    rope_theta=10_000.0),
    citation="arXiv:2404.14219",
    skip_shapes=("long_500k",),
)
