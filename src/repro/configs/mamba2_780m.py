"""Mamba2-780m [arXiv:2405.21060] — attention-free SSM using the SSD
(state-space duality) chunked algorithm.  48L, d_model 1536, expand 2
(d_inner 3072, 48 heads of 64), d_state 128; O(1) decode state =>
long_500k runs natively."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,                          # attention-free, no MLP blocks
    vocab=50_280,
    period=("mamba",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    citation="arXiv:2405.21060",
    skip_shapes=(),
)
