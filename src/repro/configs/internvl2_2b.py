"""InternVL2-2B [arXiv:2404.16821] — InternLM2-1.8B language backbone
consuming InternViT patch embeddings.  The ViT is the sanctioned stub:
input_specs() supplies 256 precomputed patch embeddings (d=1024) that a
learned projector maps into the text stream.  Full attention =>
long_500k skipped."""
from repro.configs.base import ArchConfig, AttnConfig, FrontendConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab=92_553,
    period=("attn",),
    attn=AttnConfig(n_heads=16, n_kv_heads=8, d_head=128,
                    rope_theta=10_000.0),
    frontend=FrontendConfig(kind="vision", n_prefix=256, d_frontend=1024),
    citation="arXiv:2404.16821",
    skip_shapes=("long_500k",),
)
