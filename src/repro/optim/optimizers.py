"""Functional optimizers (SGD / momentum / AdamW) for the framework.

Minimal optax-free implementations: ``init(params) -> state`` and
``update(grads, state, params) -> (updates, state)``; ``apply_updates``
adds them.  AdamW keeps fp32 moments regardless of param dtype (the
production configuration for the big architectures: bf16 compute,
fp32 state).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), state
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        return jax.tree_util.tree_map(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=jax.tree_util.tree_map(zeros, params),
                         nu=jax.tree_util.tree_map(zeros, params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        count = state.count + 1
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm
