"""Checkpointing: sharding-aware save/restore of param/optimizer pytrees.

Storage is a single .npz per step plus a JSON manifest of the tree
structure (keypath -> array name).  Arrays are gathered to host before
saving (fine at the simulation scales this container runs; on a real
cluster the same manifest format would be written per-shard with a
process-index suffix — the restore path already accepts shard globs).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = leaf
    return flat


def save(path: str | Path, step: int, params, opt_state=None,
         extra: Optional[dict] = None) -> Path:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    blobs: dict[str, np.ndarray] = {}
    manifest: dict = {"step": step, "trees": {}}

    def add(name, tree):
        if tree is None:
            return
        flat = _flatten(tree)
        names = {}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr_name = f"{name}_{i}"
            blobs[arr_name] = np.asarray(leaf)
            names[key] = arr_name
        manifest["trees"][name] = names

    add("params", params)
    add("opt", opt_state)
    if extra:
        manifest["extra"] = extra
    fn = path / f"ckpt_{step:08d}.npz"
    np.savez_compressed(fn, **blobs)
    (path / f"ckpt_{step:08d}.json").write_text(json.dumps(manifest))
    return fn


def latest_step(path: str | Path) -> Optional[int]:
    path = Path(path)
    steps = [int(m.group(1)) for p in path.glob("ckpt_*.json")
             if (m := re.match(r"ckpt_(\d+)\.json", p.name))]
    return max(steps) if steps else None


def restore(path: str | Path, step: Optional[int] = None,
            params_template=None, opt_template=None):
    """Restores (step, params, opt_state, extra); templates (pytrees of the
    target structure) define the output tree shape."""
    path = Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    manifest = json.loads((path / f"ckpt_{step:08d}.json").read_text())
    blobs = np.load(path / f"ckpt_{step:08d}.npz")

    def rebuild(name, template):
        if template is None or name not in manifest["trees"]:
            return None
        names = manifest["trees"][name]
        leaves_by_key = {}
        for key, arr_name in names.items():
            leaves_by_key[key] = blobs[arr_name]
        paths_leaves = jax.tree_util.tree_leaves_with_path(template)
        out_leaves = []
        for p, leaf in paths_leaves:
            key = jax.tree_util.keystr(p)
            if key not in leaves_by_key:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = leaves_by_key[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch at {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            out_leaves.append(jnp.asarray(arr, leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out_leaves)

    return (manifest["step"], rebuild("params", params_template),
            rebuild("opt", opt_template), manifest.get("extra"))
