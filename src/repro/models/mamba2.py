"""Mamba2 block via SSD — state-space duality (arXiv:2405.21060, Alg. 1).

The sequence is split into chunks of length ``cs``; within a chunk the dual
quadratic ("attention-like") form runs on the MXU, across chunks a
sequential ``lax.scan`` carries the [H, P, N] SSM state.  This is the
TPU-native blocking of the paper's CUDA kernel: chunk size is chosen so
the intra-chunk score matrix [cs, cs] and the state tile [P, N] stay
VMEM-resident (see kernels/ssd_scan for the Pallas version; this module
is the XLA reference the kernel is validated against).

Decode is the O(1) recurrent step: state <- exp(dt A) state + dt B x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import Params, rmsnorm, rmsnorm_init


def _dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.d_inner(d_model)
    n_heads = cfg.n_heads(d_model)
    conv_dim = d_inner + 2 * cfg.d_state
    return d_inner, n_heads, conv_dim


def mamba_init(key, d_model: int, cfg: SSMConfig) -> Params:
    """NOTE on parameter layout (perf iteration 1, EXPERIMENTS.md §Perf):
    the reference implementation fuses z|x|B|C|dt into one in_proj; its
    output dim then cannot shard over the 'model' mesh axis because the
    split boundaries (d_inner, d_state, n_heads) don't align with shard
    boundaries, leaving every SSM matmul replicated 16x.  We keep one
    *projection per role* instead — depthwise conv and the SSD math are
    per-channel, so this is numerically identical and each output dim
    shards cleanly (d_inner and H divide the mesh's model axis)."""
    d_inner, n_heads, _ = _dims(d_model, cfg)
    k_z, k_x, k_b, k_c, k_conv, k_out, k_dt = jax.random.split(key, 7)
    s = d_model ** -0.5
    kc = jax.random.split(k_conv, 3)
    return {
        "in_z": jax.random.normal(k_z, (d_model, d_inner), jnp.float32) * s,
        "in_x": jax.random.normal(k_x, (d_model, d_inner), jnp.float32) * s,
        "in_b": jax.random.normal(k_b, (d_model, cfg.d_state), jnp.float32) * s,
        "in_c": jax.random.normal(k_c, (d_model, cfg.d_state), jnp.float32) * s,
        "in_dt": jax.random.normal(k_dt, (d_model, n_heads), jnp.float32) * s,
        "conv_x": jax.random.normal(kc[0], (cfg.d_conv, d_inner), jnp.float32) * 0.2,
        "conv_b_": jax.random.normal(kc[1], (cfg.d_conv, cfg.d_state), jnp.float32) * 0.2,
        "conv_c_": jax.random.normal(kc[2], (cfg.d_conv, cfg.d_state), jnp.float32) * 0.2,
        "conv_bias_x": jnp.zeros((d_inner,), jnp.float32),
        "conv_bias_b": jnp.zeros((cfg.d_state,), jnp.float32),
        "conv_bias_c": jnp.zeros((cfg.d_state,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k_dt, (n_heads,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": rmsnorm_init(d_inner),
        "out_proj": jax.random.normal(k_out, (d_inner, d_model), jnp.float32) * d_inner ** -0.5,
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: xbc [B,S,Cd], w [K,Cd]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., cs] -> [..., cs, cs]: T[i,j] = sum_{j<k<=i} x_k, -inf above diag."""
    cs = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    diff = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, chunk: int,
                init_state=None):
    """SSD dual form.

    x  [B,S,H,P]; dt [B,S,H] (already softplus'd); a [H] (negative);
    b_mat/c_mat [B,S,N]; d_skip [H].
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc, cs = s // chunk, chunk

    xb = x.reshape(bsz, nc, cs, h, p)
    dtb = dt.reshape(bsz, nc, cs, h)
    bb = b_mat.reshape(bsz, nc, cs, n)
    cb = c_mat.reshape(bsz, nc, cs, n)

    da = dtb * a                                   # [B,nc,cs,H]
    da_cum = jnp.cumsum(da, axis=2)                # inclusive
    da_total = da_cum[:, :, -1]                    # [B,nc,H]

    # ---- intra-chunk (quadratic dual form) -------------------------------
    l_mat = jnp.exp(_segsum(da.swapaxes(2, 3)))    # [B,nc,H,cs,cs]
    scores = jnp.einsum("bcln,bcsn->bcls", cb, bb)  # [B,nc,cs,cs]
    m = scores[:, :, None] * l_mat                  # [B,nc,H,l,s]
    y_intra = jnp.einsum("bchls,bcsh,bcshp->bclhp", m, dtb, xb)

    # ---- chunk states -----------------------------------------------------
    decay_states = jnp.exp(da_total[:, :, None] - da_cum)     # [B,nc,cs,H]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        bb, decay_states * dtb, xb)           # [B,nc,H,P,N]

    # ---- inter-chunk recurrence (sequential scan over chunks) ------------
    s0 = jnp.zeros((bsz, h, p, n), x.dtype) if init_state is None else init_state

    def step(carry, inp):
        st, tot = inp                              # states_c, da_total_c
        new = carry * jnp.exp(tot)[:, :, None, None] + st
        return new, carry                          # emit state *entering* chunk c

    final_state, entering = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), da_total.swapaxes(0, 1)))
    entering = entering.swapaxes(0, 1)             # [B,nc,H,P,N]

    decay_out = jnp.exp(da_cum)                    # [B,nc,cs,H]
    y_inter = jnp.einsum("bcln,bchpn->bclhp", cb, entering) \
        * decay_out[..., None]
    y = y_intra + y_inter + d_skip[None, None, :, None] * xb
    return y.reshape(bsz, s, h, p), final_state


def mamba_apply(params: Params, x: jax.Array, cfg: SSMConfig,
                init_state=None, return_state: bool = False):
    """Full-sequence Mamba2 block. x [B,S,d_model]."""
    d_model = x.shape[-1]
    d_inner, n_heads, _ = _dims(d_model, cfg)
    z = x @ params["in_z"]
    xs = _causal_conv(x @ params["in_x"], params["conv_x"], params["conv_bias_x"])
    b_mat = _causal_conv(x @ params["in_b"], params["conv_b_"], params["conv_bias_b"])
    c_mat = _causal_conv(x @ params["in_c"], params["conv_c_"], params["conv_bias_c"])
    dt = jax.nn.softplus(x @ params["in_dt"] + params["dt_bias"])
    a = -jnp.exp(params["A_log"])

    bsz, s = x.shape[:2]
    xs = xs.reshape(bsz, s, n_heads, cfg.head_dim)
    y, state = ssd_chunked(xs, dt, a, b_mat, c_mat, params["D"],
                           cfg.chunk, init_state)
    y = y.reshape(bsz, s, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    if return_state:
        return out, state
    return out


# ------------------------------------------------------------------ decode

class MambaCache(NamedTuple):
    conv: jax.Array    # [B, d_conv-1, d_inner + 2*d_state]
    state: jax.Array   # [B, H, P, N]


def mamba_cache_init(batch: int, d_model: int, cfg: SSMConfig,
                     dtype=jnp.bfloat16) -> MambaCache:
    d_inner, n_heads, conv_dim = _dims(d_model, cfg)
    return MambaCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, n_heads, cfg.head_dim, cfg.d_state), dtype))


def mamba_decode_step(params: Params, x: jax.Array, cache: MambaCache,
                      cfg: SSMConfig) -> tuple[jax.Array, MambaCache]:
    """x [B,1,d_model] -> (y [B,1,d_model], cache)."""
    d_model = x.shape[-1]
    d_inner, n_heads, conv_dim = _dims(d_model, cfg)
    xt = x[:, 0]
    z = xt @ params["in_z"]
    xbc = jnp.concatenate(
        [xt @ params["in_x"], xt @ params["in_b"], xt @ params["in_c"]], -1)
    conv_w = jnp.concatenate(
        [params["conv_x"], params["conv_b_"], params["conv_c_"]], -1)
    conv_bias = jnp.concatenate(
        [params["conv_bias_x"], params["conv_bias_b"], params["conv_bias_c"]])

    window = jnp.concatenate([cache.conv, xbc[:, None].astype(cache.conv.dtype)], axis=1)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), conv_w)
        + conv_bias)
    new_conv = window[:, 1:]

    xs = conv_out[:, :d_inner].reshape(-1, n_heads, cfg.head_dim)
    b_t = conv_out[:, d_inner:d_inner + cfg.d_state]
    c_t = conv_out[:, d_inner + cfg.d_state:]
    dt = jax.nn.softplus(xt @ params["in_dt"] + params["dt_bias"])   # [B,H]
    da = jnp.exp(dt * -jnp.exp(params["A_log"]))              # [B,H]

    state = cache.state.astype(jnp.float32) * da[..., None, None] \
        + jnp.einsum("bh,bn,bhp->bhpn", dt, b_t, xs)
    y = jnp.einsum("bn,bhpn->bhp", c_t, state) + params["D"][None, :, None] * xs
    y = y.reshape(-1, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = (y @ params["out_proj"])[:, None]
    return out.astype(x.dtype), MambaCache(conv=new_conv,
                                           state=state.astype(cache.state.dtype))
