"""Mixture-of-Experts layer with GShard-style capacity dispatch.

Token routing uses top-k gating with cumulative-sum position ranking and a
static per-expert capacity C = ceil(T * k / E * capacity_factor); tokens
beyond capacity are dropped (their gate mass is simply not added — the
residual stream carries them).  Dispatch/combine are expressed as dense
scatters/gathers so the whole layer lowers under pjit with experts sharded
over the 'model' mesh axis (expert parallelism) and tokens over 'data'.

Shared experts (DeepSeek/llama4) run as a plain dense MLP on every token.

Auxiliary outputs: load-balance loss (Switch-style f*P) and router z-loss.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import Params, mlp_apply, mlp_init


class MoEAux(NamedTuple):
    load_balance: jax.Array   # scalar
    z_loss: jax.Array         # scalar
    dropped_frac: jax.Array   # scalar, fraction of (token,slot) pairs dropped


def moe_init(key, d_model: int, cfg: MoEConfig) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    e, ff = cfg.n_experts, cfg.d_ff_expert
    s_in, s_ff = d_model ** -0.5, ff ** -0.5
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": jax.random.normal(kr, (d_model, e), jnp.float32) * s_in,
        "experts": {
            "w1": jax.random.normal(k1, (e, d_model, ff), jnp.float32) * s_in,
            "w3": jax.random.normal(k3, (e, d_model, ff), jnp.float32) * s_in,
            "w2": jax.random.normal(k2, (e, ff, d_model), jnp.float32) * s_ff,
        },
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks, d_model, cfg.d_ff_shared)
    return p


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)   # round up to 8 for tiling friendliness


def moe_apply(params: Params, x: jax.Array, cfg: MoEConfig,
              act: str = "silu") -> tuple[jax.Array, MoEAux]:
    """x [T, d] (tokens flattened) -> (out [T, d], aux losses)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t, cfg)

    logits = (x @ params["router"]).astype(jnp.float32)       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                      # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- position ranking: slot j tokens queue behind slots < j ----------
    buf = jnp.zeros((e, c, d), x.dtype)
    counts = jnp.zeros((e,), jnp.int32)
    token_slot = []                                           # (expert, pos, keep, gate)
    for j in range(k):
        oh = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)    # [T, E]
        pos_in_e = jnp.cumsum(oh, axis=0) - oh                # exclusive cumsum
        pos = (pos_in_e * oh).sum(-1) + counts[idx[:, j]]     # [T]
        counts = counts + oh.sum(0)
        keep = pos < c
        token_slot.append((idx[:, j], pos, keep, gates[:, j]))
        buf = buf.at[idx[:, j], jnp.where(keep, pos, c - 1)].add(
            jnp.where(keep[:, None], x, 0).astype(x.dtype), mode="drop")

    # --- expert FFNs (E sharded over 'model') -----------------------------
    w = params["experts"]
    gate_act = jnp.einsum("ecd,edf->ecf", buf, w["w1"])
    gate_act = jax.nn.silu(gate_act) if act == "silu" else jax.nn.gelu(gate_act)
    up = jnp.einsum("ecd,edf->ecf", buf, w["w3"])
    expert_out = jnp.einsum("ecf,efd->ecd", gate_act * up, w["w2"])

    # --- combine ----------------------------------------------------------
    out = jnp.zeros_like(x)
    dropped = 0.0
    for e_idx, pos, keep, gate in token_slot:
        y = expert_out[e_idx, jnp.clip(pos, 0, c - 1)]        # [T, d]
        out = out + jnp.where(keep[:, None], gate[:, None].astype(x.dtype) * y, 0)
        dropped = dropped + jnp.mean(1.0 - keep.astype(jnp.float32))

    if "shared" in params:
        out = out + mlp_apply(params["shared"], x, act)

    # --- aux losses -------------------------------------------------------
    frac = jnp.zeros((e,), jnp.float32)
    for e_idx, _, _, _ in token_slot:
        frac = frac + jnp.bincount(e_idx, length=e).astype(jnp.float32)
    frac = frac / (t * k)
    mean_prob = probs.mean(0)
    lb = e * jnp.sum(frac * mean_prob)
    z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    return out, MoEAux(load_balance=lb, z_loss=z, dropped_frac=dropped / k)
