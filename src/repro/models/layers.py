"""Shared neural building blocks for the model zoo.

Pure-functional: ``*_init(key, ...) -> params`` and ``*_apply(params, ...)``.
Attention supports:

* GQA (q heads grouped over fewer kv heads; kv repeated to q-head count —
  the repeat is sharding-friendly: the H axis carries the 'model' mesh dim),
* RoPE with per-layer theta (gemma3 dual-base), optional NoPE (llama4
  global layers),
* sliding-window masks (gemma2/3, danube, llama4 chunked-local),
* attention-logit softcapping (gemma2),
* query-chunked computation: sequences longer than ``q_chunk`` are
  processed by a ``lax.scan`` over query blocks so the [Sq, Skv] score
  matrix never materialises for the full sequence (the flash-attention
  memory pattern, expressed at the XLA level; the Pallas decode kernel in
  kernels/ covers the latency-critical single-token path),
* ring-buffer KV caches: local layers keep a window-sized cache written at
  slot ``pos % W``; global layers keep the full-context cache.

Everything lowers under pjit with sharded inputs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig

Params = dict


# ------------------------------------------------------------------- norms

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return (x32 * rms * params["scale"]).astype(dtype)


# -------------------------------------------------------------------- RoPE

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, dh]; positions [S] or [B, S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == 4 and cos.ndim == 2:          # [B,S,H,dh] w/ positions [S]
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif x.ndim == 4:                          # positions [B,S]
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embed(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal absolute position embedding, computed (not tabulated) so
    no O(S*d) constant is baked into the HLO. positions [S] -> [S, d]."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = positions[:, None].astype(jnp.float32) / (10_000 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------- MLP

def mlp_init(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "w1": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
        "w3": jax.random.normal(k3, (d_model, d_ff), jnp.float32) * s_in,
        "w2": jax.random.normal(k2, (d_ff, d_model), jnp.float32) * s_ff,
    }


def mlp_apply(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    gate = x @ params["w1"]
    gate = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return (gate * (x @ params["w3"])) @ params["w2"]


# --------------------------------------------------------------- attention

class AttnLayerSpec(NamedTuple):
    """Static per-layer attention behaviour (derived from AttnConfig +
    whether this layer is 'attn' (local) or 'gattn' (global))."""
    n_heads: int
    n_kv_heads: int
    d_head: int
    theta: float
    window: Optional[int]     # None => full context
    softcap: Optional[float]
    qk_norm: bool
    use_rope: bool
    causal: bool = True


def layer_spec(cfg: AttnConfig, kind: str, causal: bool = True) -> AttnLayerSpec:
    """kind: 'attn' (local if cfg.window set) or 'gattn' (global)."""
    is_global = kind == "gattn"
    theta = cfg.rope_theta_global if (is_global and cfg.rope_theta_global) else cfg.rope_theta
    return AttnLayerSpec(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
        theta=theta,
        window=None if is_global else cfg.window,
        softcap=cfg.logit_softcap,
        qk_norm=cfg.qk_norm,
        use_rope=not (is_global and cfg.nope_on_global),
        causal=causal)


def attn_init(key, d_model: int, spec: AttnLayerSpec) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, dh = spec.n_heads, spec.n_kv_heads, spec.d_head
    s = d_model ** -0.5
    p = {
        "wq": jax.random.normal(kq, (d_model, h * dh), jnp.float32) * s,
        "wk": jax.random.normal(kk, (d_model, kvh * dh), jnp.float32) * s,
        "wv": jax.random.normal(kv, (d_model, kvh * dh), jnp.float32) * s,
        "wo": jax.random.normal(ko, (h * dh, d_model), jnp.float32) * (h * dh) ** -0.5,
    }
    if spec.qk_norm:
        p["q_norm"] = rmsnorm_init(dh)
        p["k_norm"] = rmsnorm_init(dh)
    return p


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B,S,Hkv,dh] -> [B,S,H,dh] by repetition (H % Hkv == 0)."""
    b, s, hkv, dh = k.shape
    rep = n_heads // hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# Perf iteration 4 (EXPERIMENTS.md §Perf): compute GQA attention in
# grouped form — q viewed as [B,Cq,Hkv,G,dh] against un-repeated K/V —
# instead of materialising K/V repeated to the full query-head count.
# Saves (G-1)/G of the KV read/write traffic for small-kv archs
# (gemma3 kv=1, danube/llama4 kv=8).  Flag-gated so measurement sweeps
# stay internally consistent.
GQA_GROUPED = False


def set_gqa_grouped(on: bool):
    global GQA_GROUPED
    GQA_GROUPED = on


def _attend_block_grouped(q, k, v, q_pos, k_pos, spec: AttnLayerSpec):
    """q [B,Cq,H,dh], k/v [B,Skv,Hkv,dh] (no repetition)."""
    b, cq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = spec.d_head ** -0.5
    qg = (q * scale).reshape(b, cq, hkv, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k)
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if spec.causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if spec.window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - spec.window
    mask &= (k_pos >= 0)[None, :]
    if spec.softcap is not None:
        scores = spec.softcap * jnp.tanh(scores / spec.softcap)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, cq, h, dh)


def _masked_softmax(scores: jax.Array, mask: jax.Array,
                    softcap: Optional[float]) -> jax.Array:
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return probs


def _attend_block(q, k, v, q_pos, k_pos, spec: AttnLayerSpec):
    """q [B,Cq,H,dh], k/v [B,Skv,H,dh], *_pos int32 [Cq]/[Skv]."""
    scale = spec.d_head ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if spec.causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if spec.window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - spec.window
    mask &= (k_pos >= 0)[None, :]          # ring-buffer empty slots
    probs = _masked_softmax(scores, mask[None, None], spec.softcap)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def multihead_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_pos: jax.Array, k_pos: jax.Array,
                        spec: AttnLayerSpec, q_chunk: int = 1024) -> jax.Array:
    """Full attention; scans over query chunks when Sq > q_chunk."""
    if GQA_GROUPED:
        attend = _attend_block_grouped
    else:
        attend = _attend_block
        k = _repeat_kv(k, spec.n_heads)
        v = _repeat_kv(v, spec.n_heads)
    b, sq = q.shape[0], q.shape[1]
    if sq <= q_chunk or sq % q_chunk != 0:
        return attend(q, k, v, q_pos, k_pos, spec)
    nc = sq // q_chunk
    qs = q.reshape(b, nc, q_chunk, *q.shape[2:]).swapaxes(0, 1)
    qp = q_pos.reshape(nc, q_chunk)

    def body(_, qc):
        q_i, qp_i = qc
        return None, attend(q_i, k, v, qp_i, k_pos, spec)

    _, out = jax.lax.scan(body, None, (qs, qp))
    return out.swapaxes(0, 1).reshape(b, sq, *out.shape[3:])


def attn_apply(params: Params, x: jax.Array, positions: jax.Array,
               spec: AttnLayerSpec, q_chunk: int = 1024,
               kv_override: Optional[tuple[jax.Array, jax.Array]] = None,
               kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Self-attention (or cross-attention when kv_override supplies the
    encoder sequence). x [B,S,d]."""
    b, s, _ = x.shape
    h, kvh, dh = spec.n_heads, spec.n_kv_heads, spec.d_head
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    if kv_override is None:
        xk = xv = x
    else:
        xk, xv = kv_override
    k = (xk @ params["wk"]).reshape(b, xk.shape[1], kvh, dh)
    v = (xv @ params["wv"]).reshape(b, xv.shape[1], kvh, dh)
    if spec.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    k_pos = kv_positions if kv_positions is not None else positions
    if spec.use_rope:
        q = rope(q, positions, spec.theta)
        k = rope(k, k_pos, spec.theta)
    out = multihead_attention(q, k, v, positions, k_pos, spec, q_chunk)
    return out.reshape(b, s, h * dh) @ params["wo"]


# ----------------------------------------------------------- KV cache path

class KVCache(NamedTuple):
    k: jax.Array      # [B, W, Hkv, dh]
    v: jax.Array      # [B, W, Hkv, dh]
    pos: jax.Array    # [W] int32 absolute positions, -1 = empty


def kv_cache_init(batch: int, cache_len: int, spec: AttnLayerSpec,
                  dtype=jnp.bfloat16) -> KVCache:
    w = spec.window if spec.window is not None else cache_len
    w = min(w, cache_len)
    return KVCache(
        k=jnp.zeros((batch, w, spec.n_kv_heads, spec.d_head), dtype),
        v=jnp.zeros((batch, w, spec.n_kv_heads, spec.d_head), dtype),
        pos=jnp.full((w,), -1, jnp.int32))


def attn_decode_step(params: Params, x: jax.Array, pos: jax.Array,
                     cache: KVCache, spec: AttnLayerSpec) -> tuple[jax.Array, KVCache]:
    """One-token decode: x [B,1,d], pos scalar int32. Ring-buffer write."""
    b = x.shape[0]
    h, kvh, dh = spec.n_heads, spec.n_kv_heads, spec.d_head
    q = (x @ params["wq"]).reshape(b, 1, h, dh)
    k_new = (x @ params["wk"]).reshape(b, 1, kvh, dh)
    v_new = (x @ params["wv"]).reshape(b, 1, kvh, dh)
    if spec.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k_new = rmsnorm(params["k_norm"], k_new)
    pos_vec = pos[None] if pos.ndim == 0 else pos
    if spec.use_rope:
        q = rope(q, pos_vec, spec.theta)
        k_new = rope(k_new, pos_vec, spec.theta)

    w = cache.k.shape[1]
    slot = (pos % w).astype(jnp.int32)
    k_buf = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v_buf = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
    pos_buf = jax.lax.dynamic_update_slice_in_dim(cache.pos, pos_vec.astype(jnp.int32), slot, axis=0)

    if GQA_GROUPED:
        out = _attend_block_grouped(q, k_buf, v_buf, pos_vec, pos_buf, spec)
    else:
        out = _attend_block(q, _repeat_kv(k_buf, h), _repeat_kv(v_buf, h),
                            pos_vec, pos_buf, spec)
    y = out.reshape(b, 1, h * dh) @ params["wo"]
    return y, KVCache(k=k_buf, v=v_buf, pos=pos_buf)


def kv_cache_from_prefill(k: jax.Array, v: jax.Array, spec: AttnLayerSpec,
                          cache_len: int) -> KVCache:
    """Build a ring-consistent cache from prefill K/V ([B,S,Hkv,dh])."""
    s = k.shape[1]
    w = spec.window if spec.window is not None else cache_len
    w = min(w, cache_len)
    positions = jnp.arange(s, dtype=jnp.int32)
    if s >= w:
        k_w, v_w, p_w = k[:, s - w:], v[:, s - w:], positions[s - w:]
        shift = s % w
        k_w = jnp.roll(k_w, shift, axis=1)
        v_w = jnp.roll(v_w, shift, axis=1)
        p_w = jnp.roll(p_w, shift, axis=0)
        return KVCache(k=k_w, v=v_w, pos=p_w)
    pad = w - s
    k_w = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_w = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    p_w = jnp.concatenate([positions, jnp.full((pad,), -1, jnp.int32)])
    return KVCache(k=k_w, v=v_w, pos=p_w)
