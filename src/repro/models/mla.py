"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are compressed into a per-token latent c_kv of rank ``kv_lora_rank``
plus a single shared RoPE key of dim ``rope_head_dim``; queries carry
per-head nope+rope parts.  Two execution paths:

* **train/prefill** — latent is up-projected to per-head K_nope/V and
  attention runs in the standard [nope+rope] space (best for MXU:
  one big matmul per projection).
* **decode (absorbed)** — the up-projection is *absorbed* into the query
  and output projections, so attention runs directly against the latent
  cache: scores = q_lat . c_kv + q_rope . k_rope.  The cache is
  (kv_lora + rope) = 576 elements/token — the paper-card's 93% KV
  reduction — and the per-step FLOPs are O(W * (kv_lora + rope) * H)
  instead of O(W * H * (nope + v)).  This is the TPU-native adaptation of
  DeepSeek's CUDA decode path (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.layers import Params, rmsnorm, rmsnorm_init, rope


def mla_init(key, d_model: int, n_heads: int, cfg: MLAConfig) -> Params:
    kq, kd, ku, ko = jax.random.split(key, 4)
    qk_dim = cfg.nope_head_dim + cfg.rope_head_dim
    s = d_model ** -0.5
    return {
        "wq": jax.random.normal(kq, (d_model, n_heads * qk_dim), jnp.float32) * s,
        "w_dkv": jax.random.normal(kd, (d_model, cfg.kv_lora_rank + cfg.rope_head_dim), jnp.float32) * s,
        "kv_ln": rmsnorm_init(cfg.kv_lora_rank),
        "w_ukv": jax.random.normal(
            ku, (cfg.kv_lora_rank, n_heads * (cfg.nope_head_dim + cfg.v_head_dim)),
            jnp.float32) * cfg.kv_lora_rank ** -0.5,
        "wo": jax.random.normal(ko, (n_heads * cfg.v_head_dim, d_model), jnp.float32)
              * (n_heads * cfg.v_head_dim) ** -0.5,
    }


def _split_q(q, n_heads, cfg: MLAConfig):
    b, s = q.shape[:2]
    q = q.reshape(b, s, n_heads, cfg.nope_head_dim + cfg.rope_head_dim)
    return q[..., :cfg.nope_head_dim], q[..., cfg.nope_head_dim:]


def _latent(params, x, cfg: MLAConfig, theta: float, positions):
    ckr = x @ params["w_dkv"]
    c_kv = rmsnorm(params["kv_ln"], ckr[..., :cfg.kv_lora_rank])
    k_rope = ckr[..., None, cfg.kv_lora_rank:]              # [B,S,1,rope]
    k_rope = rope(k_rope, positions, theta)[:, :, 0]        # shared across heads
    return c_kv, k_rope


def mla_apply(params: Params, x: jax.Array, positions: jax.Array,
              n_heads: int, cfg: MLAConfig, theta: float,
              q_chunk: int = 1024) -> jax.Array:
    """Training/prefill path (decompressed attention). x [B,S,d]."""
    b, s, _ = x.shape
    q_nope, q_rope = _split_q(x @ params["wq"], n_heads, cfg)
    q_rope = rope(q_rope, positions, theta)
    c_kv, k_rope = _latent(params, x, cfg, theta, positions)
    kv = (c_kv @ params["w_ukv"]).reshape(
        b, s, n_heads, cfg.nope_head_dim + cfg.v_head_dim)
    k_nope, v = kv[..., :cfg.nope_head_dim], kv[..., cfg.nope_head_dim:]

    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    nc = max(1, s // q_chunk) if s % q_chunk == 0 else 1

    def block(qn, qr, qp):
        scores = (jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope)
                  + jnp.einsum("bqhd,bkd->bhqk", qr, k_rope)) * scale
        mask = qp[:, None] >= positions[None, :]
        probs = jax.nn.softmax(
            jnp.where(mask[None, None], scores, -1e30).astype(jnp.float32), -1)
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)

    if nc == 1:
        out = block(q_nope, q_rope, positions)
    else:
        cq = s // nc
        qn = q_nope.reshape(b, nc, cq, n_heads, -1).swapaxes(0, 1)
        qr = q_rope.reshape(b, nc, cq, n_heads, -1).swapaxes(0, 1)
        qp = positions.reshape(nc, cq)
        _, out = jax.lax.scan(lambda _, t: (None, block(*t)), None, (qn, qr, qp))
        out = out.swapaxes(0, 1).reshape(b, s, n_heads, cfg.v_head_dim)
    return out.reshape(b, s, n_heads * cfg.v_head_dim) @ params["wo"]


class MLACache(NamedTuple):
    c_kv: jax.Array     # [B, L, kv_lora]
    k_rope: jax.Array   # [B, L, rope_head_dim]
    pos: jax.Array      # [L] int32, -1 empty


def mla_cache_init(batch: int, cache_len: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, cache_len, cfg.rope_head_dim), dtype),
        pos=jnp.full((cache_len,), -1, jnp.int32))


def mla_decode_step(params: Params, x: jax.Array, pos: jax.Array,
                    cache: MLACache, n_heads: int, cfg: MLAConfig,
                    theta: float) -> tuple[jax.Array, MLACache]:
    """Absorbed-latent decode: attention against the latent cache."""
    b = x.shape[0]
    pos_vec = pos[None] if pos.ndim == 0 else pos
    q_nope, q_rope = _split_q(x @ params["wq"], n_heads, cfg)
    q_rope = rope(q_rope, pos_vec, theta)

    c_new, kr_new = _latent(params, x, cfg, theta, pos_vec)   # [B,1,r], [B,1,rope]
    slot = pos.astype(jnp.int32)
    c_buf = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new.astype(cache.c_kv.dtype), slot, 1)
    kr_buf = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new.astype(cache.k_rope.dtype), slot, 1)
    pos_buf = jax.lax.dynamic_update_slice_in_dim(
        cache.pos, pos_vec.astype(jnp.int32), slot, 0)

    # absorb: W_ukv = [W_k_up | W_v_up] per head
    w_ukv = params["w_ukv"].reshape(cfg.kv_lora_rank, n_heads,
                                    cfg.nope_head_dim + cfg.v_head_dim)
    w_k_up = w_ukv[..., :cfg.nope_head_dim]       # [r, H, nope]
    w_v_up = w_ukv[..., cfg.nope_head_dim:]       # [r, H, v]

    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k_up)       # into latent space
    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat, c_buf)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, kr_buf)) * scale
    mask = (pos_buf >= 0) & (pos_buf <= pos)
    probs = jax.nn.softmax(
        jnp.where(mask[None, None, None], scores, -1e30).astype(jnp.float32), -1)
    out_lat = jnp.einsum("bhqk,bkr->bqhr", probs.astype(c_buf.dtype), c_buf)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_v_up)
    y = out.reshape(b, 1, n_heads * cfg.v_head_dim) @ params["wo"]
    return y, MLACache(c_kv=c_buf, k_rope=kr_buf, pos=pos_buf)
