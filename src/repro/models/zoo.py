"""Model-zoo public API: parameter counting, batch specs, losses, and the
train/serve step functions used by the launcher and the dry-run.

The FL integration (DESIGN.md §3): ``train_step`` consumes per-example
``loss_weights`` that encode alpha_i * m_i of the paper's eq. (4) — the
participation mask sampled by the scheduler rides the data axis, so the
FedSGD server sum *is* the data-parallel gradient reduction.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import transformer as T


# ------------------------------------------------------------- param count

def param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    """Analytic parameter count via eval_shape (no allocation).

    active_only: MoE routed experts counted at top_k/n_experts (the
    standard "activated params" figure; shared experts fully counted)."""
    shapes = jax.eval_shape(partial(T.init_params, cfg), jax.random.PRNGKey(0))
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        n = int(np.prod(leaf.shape))
        if active_only and cfg.moe is not None and _is_routed_expert(path):
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total


def _is_routed_expert(path) -> bool:
    return any(getattr(p, "key", None) == "experts" for p in path)


def grad_size_bits(cfg: ArchConfig, bits_per_param: int = 32) -> float:
    """Uplink payload S for the paper's problem (7): the gradient of the
    trainable parameters."""
    return float(param_count(cfg)) * bits_per_param


# ------------------------------------------------------------------- loss

def lm_loss(cfg: ArchConfig, params, batch: dict,
            q_chunk: int = 1024, remat: bool = True,
            aux_coef: tuple[float, float] = (1e-2, 1e-3)) -> tuple[jax.Array, dict]:
    """Next-token CE with optional per-example FL weights.

    batch: tokens [B,S], labels [B,S] (-100 = masked), optional
    loss_weights [B] (alpha_i * m_i, possibly renormalised)."""
    logits, aux = T.forward(cfg, params, batch, q_chunk=q_chunk, remat=remat)
    labels = batch["labels"]
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        # logits cover [prefix + text]; labels only text: pad with -100
        pad = jnp.full(labels.shape[:1] + (cfg.frontend.n_prefix,), -100,
                       labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    per_example = nll.sum(-1) / jnp.maximum(valid.sum(-1), 1)     # [B]
    w = batch.get("loss_weights")
    if w is None:
        loss = per_example.mean()
    else:
        loss = jnp.sum(per_example * w)
    lb, z, dropped = aux[0], aux[1], aux[2]
    total = loss + aux_coef[0] * lb + aux_coef[1] * z
    return total, {"ce": loss, "load_balance": lb, "z_loss": z,
                   "moe_dropped": dropped}


# ------------------------------------------------------------- batch specs

def make_batch(cfg: ArchConfig, shape: InputShape, rng: np.random.Generator,
               with_weights: bool = True) -> dict:
    """Concrete random batch (smoke tests / examples)."""
    b, s = shape.global_batch, shape.seq_len
    batch: dict = {}
    text = s
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        text = s - cfg.frontend.n_prefix
        batch["vision"] = rng.normal(size=(b, cfg.frontend.n_prefix,
                                           cfg.frontend.d_frontend)).astype(np.float32)
    if cfg.frontend is not None and cfg.frontend.kind == "audio":
        batch["audio"] = rng.normal(size=(b, cfg.frontend.n_frames,
                                          cfg.frontend.d_frontend)).astype(np.float32)
    batch["tokens"] = rng.integers(0, cfg.vocab, (b, text)).astype(np.int32)
    batch["labels"] = rng.integers(0, cfg.vocab, (b, text)).astype(np.int32)
    if with_weights:
        w = rng.uniform(0, 1, (b,)).astype(np.float32)
        batch["loss_weights"] = w / w.sum()
    return {k: jnp.asarray(v) for k, v in batch.items()}


def input_specs(cfg: ArchConfig, shape: InputShape,
                with_weights: bool = True) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.mode == "decode":
        spec = {"tokens": sds((b, 1), jnp.int32),
                "pos": sds((), jnp.int32)}
        return spec
    specs: dict = {}
    text = s
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        text = s - cfg.frontend.n_prefix
        specs["vision"] = sds((b, cfg.frontend.n_prefix, cfg.frontend.d_frontend),
                              jnp.bfloat16)
    if cfg.frontend is not None and cfg.frontend.kind == "audio":
        specs["audio"] = sds((b, cfg.frontend.n_frames, cfg.frontend.d_frontend),
                             jnp.bfloat16)
    specs["tokens"] = sds((b, text), jnp.int32)
    if shape.mode == "train":
        specs["labels"] = sds((b, text), jnp.int32)
        if with_weights:
            specs["loss_weights"] = sds((b,), jnp.float32)
    return specs
