"""The paper's ML model: a 3-layer CNN with 199,213 parameters.

The paper reports 199,210 parameters for its 3-layer CNN on MNIST; the
closest integer-width realisation of conv(8) -> conv(16) -> fc(249) ->
fc(10) gives 199,213 (delta = 3, a bias-count difference — noted in
EXPERIMENTS.md).  Pure-functional JAX: ``init`` -> params pytree,
``apply`` -> logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

HIDDEN = 249


def init(key: jax.Array) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def he(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) * np.sqrt(2.0 / fan_in)

    return {
        "conv1": {"w": he(k1, (3, 3, 1, 8), 9), "b": jnp.zeros((8,))},
        "conv2": {"w": he(k2, (3, 3, 8, 16), 72), "b": jnp.zeros((16,))},
        "fc1": {"w": he(k3, (7 * 7 * 16, HIDDEN), 7 * 7 * 16), "b": jnp.zeros((HIDDEN,))},
        "fc2": {"w": he(k4, (HIDDEN, 10), HIDDEN), "b": jnp.zeros((10,))},
    }


def _conv(x, w, b):
    """3x3 SAME conv as an im2col matmul.

    Forward-identical to ``lax.conv_general_dilated`` (same contraction,
    same padding) but lowers to a plain dot, whose backward pass is two
    matmuls — XLA:CPU's conv/correlation gradient kernels are ~10x slower
    than its GEMMs at these shapes, and the FL engines take this gradient
    every round for every client cohort.
    """
    kh, kw, cin, cout = w.shape
    h, wd = x.shape[1], x.shape[2]
    xp = jnp.pad(x, ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2), (0, 0)))
    patches = jnp.stack([xp[:, i:i + h, j:j + wd, :]
                         for i in range(kh) for j in range(kw)], axis=3)
    flat = patches.reshape(x.shape[0], h, wd, kh * kw * cin)
    y = flat @ w.reshape(kh * kw * cin, cout)
    return y + b


def _pool(x):
    """2x2/stride-2 max pool via reshape (dims are even: 28 -> 14 -> 7).

    Equivalent to ``reduce_window(max)`` but avoids its select-and-scatter
    gradient, the single slowest op of the round step on XLA:CPU.
    """
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def apply(params: dict, images: jax.Array) -> jax.Array:
    """images [B, 28, 28, 1] -> logits [B, 10]."""
    x = jax.nn.relu(_conv(images, params["conv1"]["w"], params["conv1"]["b"]))
    x = _pool(x)                                    # 14x14x8
    x = jax.nn.relu(_conv(x, params["conv2"]["w"], params["conv2"]["b"]))
    x = _pool(x)                                    # 7x7x16
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def n_params(params: dict) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def loss_fn(params: dict, images: jax.Array, labels: jax.Array,
            sample_weights: jax.Array | None = None) -> jax.Array:
    """Weighted cross-entropy; weights implement eq. (4)'s alpha_i m_i."""
    logits = apply(params, images)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    if sample_weights is None:
        return jnp.mean(nll)
    return jnp.sum(nll * sample_weights)


def accuracy(params: dict, images: jax.Array, labels: jax.Array,
             batch: int = 512) -> float:
    correct = 0
    for i in range(0, images.shape[0], batch):
        logits = apply(params, images[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == labels[i:i + batch]))
    return correct / images.shape[0]
