"""Model assembly: ArchConfig -> init / forward / decode_step.

Layer stacks lower as ``lax.scan`` over *stacked period parameters*: the
arch's ``period`` (e.g. gemma2's (local, global)) is one scan body and the
depth dimension becomes the scan axis, so HLO size and compile time are
independent of depth (46-layer gemma2 compiles as fast as a 2-layer toy).
Irregular prefixes (deepseek's first dense layer) and tails (gemma3's
26 = 4*6 + 2) are unrolled.

Block kinds (configs/base.py): attn | gattn | mla | mamba | shared_attn.
``shared_attn`` (zamba2) applies an attention+MLP block whose parameters
are shared across all its occurrences, then the layer's own Mamba2 mixer.

Remat: each scan body is wrapped in ``jax.checkpoint`` (policy: nothing
saved) — the standard production memory/compute trade for long-sequence
training.

Vocab padding (perf iteration 2, EXPERIMENTS.md §Perf): embedding tables
are padded to a multiple of 256 so the vocabulary dimension always shards
over the 'model' mesh axis.  Without this, archs with awkward vocab sizes
(internvl 92553, mamba2 50280, whisper 51866) fall back to replicated
embeddings, and the [B, S, V] fp32 log-softmax materialises *globally* —
the dry-run showed a 362 GiB all-gather + 362 GiB all-reduce pair on
internvl2 train_4k from exactly this.  Logits beyond the true vocab are
masked to -inf at decode and ignored by the loss (labels < vocab).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import mla as MLA
from repro.models import moe as MOE

Params = dict
LayerKind = tuple  # (mixer, mlp) e.g. ("attn", "dense"), ("mla", "moe")

VOCAB_PAD = 256


def padded_vocab(cfg: ArchConfig) -> int:
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


# Residual-stream sharding constraint (perf iteration 3, EXPERIMENTS.md
# §Perf): without an explicit anchor, GSPMD propagates a pathological
# layout into the layer-scan body — batch *replicated* over 'data' and
# d_model sharded over 'model' — turning every TP psum into a
# full-batch fp32 all-reduce (observed 18 GiB/op on gemma2 train_4k).
# The launcher/dry-run sets the batch axes for the active mesh; None
# disables constraints (single-device tests).
_BATCH_AXES: tuple | None = None


def set_batch_axes(axes: tuple | None):
    global _BATCH_AXES
    _BATCH_AXES = axes


def _constrain_residual(x: jax.Array) -> jax.Array:
    if _BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(_BATCH_AXES, *([None] * (x.ndim - 1))))


# ----------------------------------------------------------------- planning

class LayerPlan(NamedTuple):
    prefix: tuple[LayerKind, ...]
    unit: tuple[LayerKind, ...]
    reps: int
    tail: tuple[LayerKind, ...]

    def all_layers(self) -> list[LayerKind]:
        return list(self.prefix) + list(self.unit) * self.reps + list(self.tail)


def _mlp_kind(cfg: ArchConfig, layer_idx: int, mixer: str) -> str:
    if mixer == "mamba":
        return "none"
    if cfg.moe is not None and layer_idx >= cfg.moe.first_dense:
        return "moe"
    return "dense"


def layer_plan(cfg: ArchConfig) -> LayerPlan:
    mixers = cfg.layer_kinds()
    kinds = [(m, _mlp_kind(cfg, i, m)) for i, m in enumerate(mixers)]
    plen = len(cfg.period)
    n_prefix = cfg.moe.first_dense if cfg.moe else 0
    prefix = tuple(kinds[:n_prefix])
    rest = kinds[n_prefix:]
    # the repeating unit must align with the period pattern of `rest`
    if len(rest) >= plen and n_prefix % plen == 0:
        unit = tuple(rest[:plen])
        reps = 0
        while (reps + 1) * plen <= len(rest) and \
                tuple(rest[reps * plen:(reps + 1) * plen]) == unit:
            reps += 1
        tail = tuple(rest[reps * plen:])
    else:
        unit, reps, tail = (), 0, tuple(rest)
    if reps <= 1:   # nothing gained by scanning
        return LayerPlan(prefix=prefix + tuple(unit) * reps + tail,
                         unit=(), reps=0, tail=())
    return LayerPlan(prefix=prefix, unit=unit, reps=reps, tail=tail)


# ---------------------------------------------------------- per-layer build

def _attn_spec(cfg: ArchConfig, mixer: str, causal: bool = True) -> L.AttnLayerSpec:
    spec = L.layer_spec(cfg.attn, mixer, causal)
    if cfg.family == "audio":     # whisper: absolute positions, no RoPE
        spec = spec._replace(use_rope=False)
    return spec


def init_layer(key, cfg: ArchConfig, kind: LayerKind) -> Params:
    mixer, mlp = kind
    k1, k2 = jax.random.split(key)
    p: Params = {}
    if mixer == "attn" and cfg.enc_layers:
        # whisper decoder layer: self-attn + cross-attn
        p = _init_dec_xattn_layer(key, cfg)
        return p
    if mixer in ("attn", "gattn"):
        p["ln1"] = L.rmsnorm_init(cfg.d_model)
        p["attn"] = L.attn_init(k1, cfg.d_model, _attn_spec(cfg, mixer))
    elif mixer == "mla":
        p["ln1"] = L.rmsnorm_init(cfg.d_model)
        p["mla"] = MLA.mla_init(k1, cfg.d_model, cfg.attn.n_heads, cfg.mla)
    elif mixer in ("mamba", "shared_attn"):
        p["ln"] = L.rmsnorm_init(cfg.d_model)
        p["mamba"] = M.mamba_init(k1, cfg.d_model, cfg.ssm)
    else:
        raise ValueError(mixer)
    if mlp == "dense":
        p["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff)
    elif mlp == "moe":
        p["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["moe"] = MOE.moe_init(k2, cfg.d_model, cfg.moe)
    return p


def _init_shared_attn(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attn_init(k1, cfg.d_model, _attn_spec(cfg, "attn")),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_xattn_layer(key, cfg: ArchConfig) -> Params:
    """Whisper decoder layer: self-attn + cross-attn + mlp."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attn_init(k1, cfg.d_model, _attn_spec(cfg, "attn")),
        "lnx": L.rmsnorm_init(cfg.d_model),
        "xattn": L.attn_init(k2, cfg.d_model, _attn_spec(cfg, "attn", causal=False)),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff),
    }


# --------------------------------------------------------------- forward

class FwdCtx(NamedTuple):
    positions: jax.Array
    shared: Optional[Params]              # zamba2 shared block
    enc_out: Optional[jax.Array]          # whisper encoder output
    enc_positions: Optional[jax.Array]
    q_chunk: int = 1024


def apply_layer(params: Params, x: jax.Array, cfg: ArchConfig,
                kind: LayerKind, ctx: FwdCtx):
    """Returns (x, aux) where aux is the MoE loss tuple or zeros."""
    mixer, mlp = kind
    aux = jnp.zeros((3,), jnp.float32)
    if mixer == "shared_attn":
        sp = ctx.shared
        spec = _attn_spec(cfg, "attn")
        x = x + L.attn_apply(sp["attn"], L.rmsnorm(sp["ln1"], x),
                             ctx.positions, spec, ctx.q_chunk)
        x = x + L.mlp_apply(sp["mlp"], L.rmsnorm(sp["ln2"], x), cfg.mlp_act)
        x = x + M.mamba_apply(params["mamba"], L.rmsnorm(params["ln"], x), cfg.ssm)
        return x, aux
    if mixer == "mamba":
        x = x + M.mamba_apply(params["mamba"], L.rmsnorm(params["ln"], x), cfg.ssm)
        return x, aux
    if mixer == "mla":
        x = x + MLA.mla_apply(params["mla"], L.rmsnorm(params["ln1"], x),
                              ctx.positions, cfg.attn.n_heads, cfg.mla,
                              cfg.attn.rope_theta, ctx.q_chunk)
    elif mixer == "xattn_dec":
        spec = _attn_spec(cfg, "attn")
        x = x + L.attn_apply(params["attn"], L.rmsnorm(params["ln1"], x),
                             ctx.positions, spec, ctx.q_chunk)
        xspec = _attn_spec(cfg, "attn", causal=False)
        x = x + L.attn_apply(params["xattn"], L.rmsnorm(params["lnx"], x),
                             ctx.positions, xspec, ctx.q_chunk,
                             kv_override=(ctx.enc_out, ctx.enc_out),
                             kv_positions=ctx.enc_positions)
    else:
        spec = _attn_spec(cfg, mixer)
        x = x + L.attn_apply(params["attn"], L.rmsnorm(params["ln1"], x),
                             ctx.positions, spec, ctx.q_chunk)
    if mlp == "dense":
        x = x + L.mlp_apply(params["mlp"], L.rmsnorm(params["ln2"], x), cfg.mlp_act)
    elif mlp == "moe":
        b, s, d = x.shape
        y, moe_aux = MOE.moe_apply(params["moe"],
                                   L.rmsnorm(params["ln2"], x).reshape(b * s, d),
                                   cfg.moe, cfg.mlp_act)
        x = x + y.reshape(b, s, d)
        aux = jnp.stack([moe_aux.load_balance, moe_aux.z_loss,
                         moe_aux.dropped_frac])
    return x, aux


# ----------------------------------------------------------------- model

def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    plan = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    v_pad = padded_vocab(cfg)
    p: Params = {
        "embed": jax.random.normal(keys[0], (v_pad, cfg.d_model),
                                   jnp.float32) * cfg.d_model ** -0.5,
        "final_ln": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(
            keys[6], (cfg.d_model, v_pad), jnp.float32) * cfg.d_model ** -0.5

    if any(k[0] == "shared_attn" for k in plan.all_layers()):
        p["shared_attn"] = _init_shared_attn(keys[1], cfg)

    if plan.prefix:
        p["prefix"] = [init_layer(k, cfg, kind) for k, kind in
                       zip(jax.random.split(keys[2], len(plan.prefix)), plan.prefix)]
    if plan.reps:
        def unit_init(key):
            ks = jax.random.split(key, len(plan.unit))
            return {f"l{j}": init_layer(ks[j], cfg, kind)
                    for j, kind in enumerate(plan.unit)}
        p["stack"] = jax.vmap(unit_init)(jax.random.split(keys[3], plan.reps))
    if plan.tail:
        p["tail"] = [init_layer(k, cfg, kind) for k, kind in
                     zip(jax.random.split(keys[4], len(plan.tail)), plan.tail)]

    if cfg.frontend is not None:
        p["frontend_proj"] = jax.random.normal(
            keys[5], (cfg.frontend.d_frontend, cfg.d_model),
            jnp.float32) * cfg.frontend.d_frontend ** -0.5
    if cfg.enc_layers:
        enc_kind = ("attn", "dense")
        ks = jax.random.split(keys[7], cfg.enc_layers)
        def enc_init(k):
            return init_layer(k, cfg, enc_kind)
        p["encoder"] = jax.vmap(enc_init)(ks)
        p["enc_ln"] = L.rmsnorm_init(cfg.d_model)
        # decoder layers get cross-attention: rebuild prefix/stack for audio
    return p


def _encoder_apply(cfg: ArchConfig, params: Params, frames: jax.Array,
                   q_chunk: int) -> jax.Array:
    """Whisper encoder: stub frame embeddings -> encoded features."""
    x = frames @ params["frontend_proj"]
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x = x + L.sinusoidal_embed(positions, cfg.d_model).astype(x.dtype)[None]
    spec = _attn_spec(cfg, "attn", causal=False)

    def body(x, lp):
        x = x + L.attn_apply(lp["attn"], L.rmsnorm(lp["ln1"], x),
                             positions, spec, q_chunk)
        x = x + L.mlp_apply(lp["mlp"], L.rmsnorm(lp["ln2"], x), cfg.mlp_act)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rmsnorm(params["enc_ln"], x)


def _embed_inputs(cfg: ArchConfig, params: Params, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    x = params["embed"][tokens] * np.sqrt(cfg.d_model).astype(np.float32)
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        vis = batch["vision"] @ params["frontend_proj"]   # [B, n_prefix, d]
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ArchConfig, params: Params, batch: dict,
            q_chunk: int = 1024, remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """batch: tokens [B,S_text] (+ vision [B,n_prefix,d_fe] | audio
    [B,n_frames,d_fe]).  Returns (logits [B,S,V], aux[3])."""
    plan = layer_plan(cfg)
    x = _constrain_residual(_embed_inputs(cfg, params, batch))
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    enc_out = enc_pos = None
    if cfg.enc_layers:
        enc_out = _encoder_apply(cfg, params, batch["audio"], q_chunk)
        enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        x = x + L.sinusoidal_embed(positions, cfg.d_model).astype(x.dtype)[None]

    ctx = FwdCtx(positions=positions, shared=params.get("shared_attn"),
                 enc_out=enc_out, enc_positions=enc_pos, q_chunk=q_chunk)
    aux_total = jnp.zeros((3,), jnp.float32)

    def run_layer(lp, x, kind):
        k = ("xattn_dec", kind[1]) if (cfg.enc_layers and kind[0] == "attn") else kind
        return apply_layer(lp, x, cfg, k, ctx)

    for lp, kind in zip(params.get("prefix", []), plan.prefix):
        x, aux = run_layer(lp, x, kind)
        aux_total += aux

    if plan.reps:
        def unit_body(x, unit_params):
            x = _constrain_residual(x)
            aux_u = jnp.zeros((3,), jnp.float32)
            for j, kind in enumerate(plan.unit):
                x, aux = run_layer(unit_params[f"l{j}"], x, kind)
                aux_u += aux
            return _constrain_residual(x), aux_u
        # remat: True/"full" = save nothing (max recompute, min memory);
        # "dots" = save matmul outputs (perf iteration 5: removes the
        # ~1/3 backward recompute flops when compute-bound).
        if remat == "dots":
            body = jax.checkpoint(
                unit_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif remat:
            body = jax.checkpoint(unit_body)
        else:
            body = unit_body
        x, aux_s = jax.lax.scan(lambda c, p: body(c, p), x, params["stack"])
        aux_total += aux_s.sum(0)

    for lp, kind in zip(params.get("tail", []), plan.tail):
        x, aux = run_layer(lp, x, kind)
        aux_total += aux

    x = L.rmsnorm(params["final_ln"], x)
    unembed = params["unembed"] if "unembed" in params else params["embed"].T
    logits = x @ unembed.astype(x.dtype)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(logits / cfg.final_logit_softcap)
    return logits, aux_total     # logits over padded_vocab(cfg) columns


def prefill_encoder(cfg: ArchConfig, params: Params, cache: dict,
                    batch: dict, q_chunk: int = 1024) -> dict:
    """Whisper serving: run the encoder once and fill every decoder
    layer's cross-attention K/V cache.  Returns the updated cache."""
    assert cfg.enc_layers, "prefill_encoder only applies to enc-dec archs"
    enc_out = _encoder_apply(cfg, params, batch["audio"], q_chunk)
    plan = layer_plan(cfg)
    h, dh = cfg.attn.n_kv_heads, cfg.attn.d_head

    def fill(lp, entry):
        b, f = enc_out.shape[:2]
        dtype = entry["cross_k"].dtype
        k = (enc_out @ lp["xattn"]["wk"]).reshape(b, f, h, dh)
        v = (enc_out @ lp["xattn"]["wv"]).reshape(b, f, h, dh)
        out = dict(entry)
        out["cross_k"] = k.astype(dtype)
        out["cross_v"] = v.astype(dtype)
        return out

    new_cache = dict(cache)
    if plan.prefix:
        new_cache["prefix"] = [fill(lp, e) for lp, e in
                               zip(params["prefix"], cache["prefix"])]
    if plan.reps:
        def fill_unit(unit_params, unit_cache):
            return {k: fill(unit_params[k], unit_cache[k])
                    if "cross_k" in unit_cache[k] else unit_cache[k]
                    for k in unit_cache}
        new_cache["stack"] = jax.vmap(fill_unit)(params["stack"], cache["stack"])
    if plan.tail:
        new_cache["tail"] = [fill(lp, e) for lp, e in
                             zip(params["tail"], cache["tail"])]
    return new_cache


# ------------------------------------------------------------------ decode

def init_layer_cache(cfg: ArchConfig, kind: LayerKind, batch: int,
                     cache_len: int, dtype=jnp.bfloat16):
    mixer, _ = kind
    if mixer == "shared_attn":
        spec = _attn_spec(cfg, "attn")
        return {"mamba": M.mamba_cache_init(batch, cfg.d_model, cfg.ssm, dtype),
                "shared_kv": L.kv_cache_init(batch, cache_len, spec, dtype)}
    if mixer == "mamba":
        return {"mamba": M.mamba_cache_init(batch, cfg.d_model, cfg.ssm, dtype)}
    if mixer == "mla":
        return {"mla": MLA.mla_cache_init(batch, cache_len, cfg.mla, dtype)}
    spec = _attn_spec(cfg, mixer)
    entry = {"kv": L.kv_cache_init(batch, cache_len, spec, dtype)}
    if cfg.enc_layers:
        h, dh = cfg.attn.n_kv_heads, cfg.attn.d_head
        entry["cross_k"] = jnp.zeros((batch, cfg.frontend.n_frames, h, dh), dtype)
        entry["cross_v"] = jnp.zeros((batch, cfg.frontend.n_frames, h, dh), dtype)
    return entry


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16) -> dict:
    plan = layer_plan(cfg)
    cache: dict = {}
    if plan.prefix:
        cache["prefix"] = [init_layer_cache(cfg, k, batch, cache_len, dtype)
                           for k in plan.prefix]
    if plan.reps:
        def one(_):
            return {f"l{j}": init_layer_cache(cfg, kind, batch, cache_len, dtype)
                    for j, kind in enumerate(plan.unit)}
        cache["stack"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (plan.reps,) + x.shape).copy()
            if hasattr(x, "shape") else x, one(0))
    if plan.tail:
        cache["tail"] = [init_layer_cache(cfg, k, batch, cache_len, dtype)
                         for k in plan.tail]
    return cache


def decode_layer(params: Params, x: jax.Array, cfg: ArchConfig,
                 kind: LayerKind, entry, pos: jax.Array,
                 shared: Optional[Params]):
    mixer, mlp = kind
    if mixer == "shared_attn":
        # shared attention sees only the sliding window at decode
        spec = _attn_spec(cfg, "attn")
        # NOTE: shared block's KV cache is carried inside the entry
        y, kv = L.attn_decode_step(shared["attn"],
                                   L.rmsnorm(shared["ln1"], x), pos,
                                   entry["shared_kv"], spec)
        x = x + y
        x = x + L.mlp_apply(shared["mlp"], L.rmsnorm(shared["ln2"], x), cfg.mlp_act)
        y, mc = M.mamba_decode_step(params["mamba"],
                                    L.rmsnorm(params["ln"], x), entry["mamba"], cfg.ssm)
        return x + y, {"shared_kv": kv, "mamba": mc}
    if mixer == "mamba":
        y, mc = M.mamba_decode_step(params["mamba"],
                                    L.rmsnorm(params["ln"], x), entry["mamba"], cfg.ssm)
        return x + y, {"mamba": mc}
    if mixer == "mla":
        y, c = MLA.mla_decode_step(params["mla"], L.rmsnorm(params["ln1"], x),
                                   pos, entry["mla"], cfg.attn.n_heads,
                                   cfg.mla, cfg.attn.rope_theta)
        x = x + y
        new_entry = {"mla": c}
    else:
        spec = _attn_spec(cfg, mixer)
        y, kv = L.attn_decode_step(params["attn"], L.rmsnorm(params["ln1"], x),
                                   pos, entry["kv"], spec)
        x = x + y
        new_entry = {"kv": kv}
        if cfg.enc_layers:   # whisper cross-attention against cached enc K/V
            xspec = _attn_spec(cfg, "attn", causal=False)
            b = x.shape[0]
            h, dh = spec.n_heads, spec.d_head
            xn = L.rmsnorm(params["lnx"], x)
            q = (xn @ params["xattn"]["wq"]).reshape(b, 1, h, dh)
            n_frames = entry["cross_k"].shape[1]
            kpos = jnp.arange(n_frames, dtype=jnp.int32)
            out = L._attend_block(q, L._repeat_kv(entry["cross_k"], h),
                                  L._repeat_kv(entry["cross_v"], h),
                                  pos[None] if pos.ndim == 0 else pos,
                                  kpos, xspec)
            x = x + out.reshape(b, 1, h * dh) @ params["xattn"]["wo"]
            new_entry["cross_k"] = entry["cross_k"]
            new_entry["cross_v"] = entry["cross_v"]
    if mlp == "dense":
        x = x + L.mlp_apply(params["mlp"], L.rmsnorm(params["ln2"], x), cfg.mlp_act)
    elif mlp == "moe":
        b = x.shape[0]
        y, _ = MOE.moe_apply(params["moe"],
                             L.rmsnorm(params["ln2"], x).reshape(b, -1),
                             cfg.moe, cfg.mlp_act)
        x = x + y.reshape(b, 1, -1)
    return x, new_entry


def decode_step(cfg: ArchConfig, params: Params, cache: dict,
                tokens: jax.Array, pos: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step: tokens [B,1], pos scalar int32 (next position)."""
    plan = layer_plan(cfg)
    x = params["embed"][tokens] * np.sqrt(cfg.d_model).astype(np.float32)
    if cfg.enc_layers:
        pos_vec = pos[None] if pos.ndim == 0 else pos
        x = x + L.sinusoidal_embed(pos_vec, cfg.d_model).astype(x.dtype)[None]
    shared = params.get("shared_attn")
    new_cache: dict = {}

    def run(lp, x, kind, entry):
        return decode_layer(lp, x, cfg, kind, entry, pos, shared)

    if plan.prefix:
        new_cache["prefix"] = []
        for lp, kind, entry in zip(params["prefix"], plan.prefix, cache["prefix"]):
            x, e = run(lp, x, kind, entry)
            new_cache["prefix"].append(e)
    if plan.reps:
        def body(x, inp):
            unit_params, unit_cache = inp
            new_entries = {}
            for j, kind in enumerate(plan.unit):
                x, e = run(unit_params[f"l{j}"], x, kind, unit_cache[f"l{j}"])
                new_entries[f"l{j}"] = e
            return x, new_entries
        x, new_cache["stack"] = jax.lax.scan(
            body, x, (params["stack"], cache["stack"]))
    if plan.tail:
        new_cache["tail"] = []
        for lp, kind, entry in zip(params["tail"], plan.tail, cache["tail"]):
            x, e = run(lp, x, kind, entry)
            new_cache["tail"].append(e)

    x = L.rmsnorm(params["final_ln"], x)
    unembed = params["unembed"] if "unembed" in params else params["embed"].T
    logits = x @ unembed.astype(x.dtype)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(logits / cfg.final_logit_softcap)
    if padded_vocab(cfg) != cfg.vocab:    # mask pad columns for sampling
        neg = jnp.finfo(logits.dtype).min
        logits = logits.at[..., cfg.vocab:].set(neg)
    return logits, new_cache
