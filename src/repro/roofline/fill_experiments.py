"""Fill EXPERIMENTS.md placeholders from experiment outputs.

    PYTHONPATH=src python -m repro.roofline.fill_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.roofline.report import dryrun_table, load, roofline_table

ROOT = Path(__file__).resolve().parents[3]


def repro_tables() -> dict[str, str]:
    out = {}
    names = {"REPRO_TABLE_1": "highly_biased", "REPRO_TABLE_2": "mildly_biased"}
    for tag, scen in names.items():
        f = ROOT / "experiments/paper_repro" / f"{scen}.json"
        if not f.exists():
            out[tag] = "_(run examples/paper_repro.py)_"
            continue
        res = json.loads(f.read_text())
        lo, hi = res["spec"]["targets"]
        lines = [f"| strategy | final acc | E[parts]/round | t→{lo:.0%} (s) "
                 f"| t→{hi:.0%} (s) | E→{lo:.0%} (J) | E→{hi:.0%} (J) |",
                 "|---|---|---|---|---|---|---|"]
        for strat, r in res["strategies"].items():
            t = r["table"]
            def f2(v):
                return "NA" if v is None else f"{v:.0f}"
            lines.append(
                f"| {strat} | {r['final_acc']:.3f} "
                f"| {r['mean_participants']:.2f} | {f2(t['time_to_low'])} "
                f"| {f2(t['time_to_high'])} | {f2(t['energy_to_low'])} "
                f"| {f2(t['energy_to_high'])} |")
        out[tag] = "\n".join(lines)
    return out


def compression_table() -> str:
    f = ROOT / "experiments/compression_study.json"
    if not f.exists():
        return "_(run examples/compression_study.py)_"
    res = json.loads(f.read_text())
    lines = ["| uplink bits | E[participants] | objective (7a) | final acc "
             "| sim time (s) | energy (J) |", "|---|---|---|---|---|---|"]
    for bits, r in sorted(res.items(), key=lambda kv: -int(kv[0])):
        lines.append(f"| {bits} | {r['expected_participants']:.2f} "
                     f"| {r['objective']:.4f} | {r['final_acc']:.3f} "
                     f"| {r['time_to_final']:.0f} | {r['energy']:.0f} |")
    return "\n".join(lines)


import re


def _parse_sweep_log(path: Path) -> dict:
    """arch/shape/mesh -> terms(ms) from a dry-run sweep log (the original
    baseline sweep's artifacts were partially overwritten by in-place
    iteration re-runs; the log is the pristine record)."""
    rx = re.compile(r"^(\S+)\s+(\S+)\s+(\S+)\s+compute=\s*([\d.]+)ms "
                    r"memory=\s*([\d.]+)ms collective=\s*([\d.]+)ms")
    out = {}
    for line in path.read_text().splitlines():
        m = rx.match(line)
        if m:
            out[(m.group(1), m.group(2), m.group(3))] = {
                "compute_s": float(m.group(4)) / 1e3,
                "memory_s": float(m.group(5)) / 1e3,
                "collective_s": float(m.group(6)) / 1e3}
    return out


def perf_before_after() -> str:
    base = _parse_sweep_log(ROOT / "experiments/dryrun_sweep.log")
    now = {(r["arch"], r["shape"], r["mesh"]): r
           for r in load(ROOT / "experiments/artifacts")
           if r.get("status") == "ok"}
    pairs = [("mamba2-780m", "prefill_32k"), ("internvl2-2b", "train_4k"),
             ("gemma2-27b", "train_4k")]
    lines = ["| pair | metric | baseline (paper-faithful, pre-§Perf) "
             "| optimized (final) | delta |", "|---|---|---|---|---|"]
    for arch, shape in pairs:
        kb = base.get((arch, shape, "single"))
        kn = now.get((arch, shape, "single"))
        if not (kb and kn):
            continue
        for metric, label in [("collective_s", "collective (ms)"),
                              ("memory_s", "HLO-memory (ms)"),
                              ("compute_s", "HLO-compute (ms)")]:
            vb = kb[metric] * 1e3
            vn = kn["roofline"][metric] * 1e3
            d = (vn / vb - 1) if vb else 0.0
            lines.append(f"| {arch} {shape} | {label} | {vb:.2f} | {vn:.2f} "
                         f"| {d:+.0%} |")
    return "\n".join(lines)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    recs = load(ROOT / "experiments/artifacts")
    subs = {
        "DRYRUN_TABLE": dryrun_table(recs),
        "ROOFLINE_TABLE_SINGLE": roofline_table(recs, "single"),
        "ROOFLINE_TABLE_MULTI": roofline_table(recs, "multi"),
        "PERF_BEFORE_AFTER": perf_before_after(),
        "COMPRESSION_TABLE": compression_table(),
        **repro_tables(),
    }
    for tag, content in subs.items():
        marker = f"<!-- {tag} -->"
        if marker in md:
            md = md.replace(marker, content)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated;",
          sum(1 for t in subs if f"<!-- {t} -->" not in md), "sections filled")


if __name__ == "__main__":
    main()
