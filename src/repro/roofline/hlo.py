"""Optimized-HLO text parsing: collective inventory for the roofline.

``compiled.as_text()`` (post-SPMD-partitioning HLO) names every collective
op explicitly; we sum the *output* bytes of each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (output-bytes is the
conventional "collective size" — for reduce-scatter it is the per-shard
result, for all-gather the full gathered tensor; we also record operand
bytes for completeness).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import NamedTuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# e.g.:  %all-gather.1 = bf16[16,4096]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\(?[\w\[\],{}\s/#*]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


class CollectiveStats(NamedTuple):
    bytes_by_kind: dict        # kind -> output bytes total
    count_by_kind: dict        # kind -> #ops
    total_bytes: int

    def as_dict(self) -> dict:
        return {"bytes_by_kind": dict(self.bytes_by_kind),
                "count_by_kind": dict(self.count_by_kind),
                "total_bytes": int(self.total_bytes)}


def shape_bytes(shape_str: str) -> int:
    """Sum bytes over every `dtype[dims]` occurrence in a shape string
    (handles tuple shapes)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        # avoid double counting async start/done pairs: count starts only
        if f"{kind}-done(" in line:
            continue
        bytes_by[kind] += shape_bytes(shape_str)
        count_by[kind] += 1
    total = sum(bytes_by.values())
    return CollectiveStats(bytes_by_kind=dict(bytes_by),
                           count_by_kind=dict(count_by), total_bytes=total)
