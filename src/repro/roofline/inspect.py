"""§Perf profiling helper: lower one (arch, shape, mesh) combo and print
the top collectives / largest ops from the optimized HLO, attributing
each to its enclosing computation (while-loop bodies are the layer scan —
their ops execute trip_count times, which the flat parse undercounts).

    PYTHONPATH=src python -m repro.roofline.inspect --arch internvl2-2b \
        --shape train_4k
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict

from repro.roofline.hlo import _OP_RE, shape_bytes


def computation_blocks(hlo_text: str):
    """Yield (computation_name, line) for every instruction line."""
    current = "<module>"
    for line in hlo_text.splitlines():
        m = re.match(r"\s*%?([\w\.\-]+)\s*(\([^)]*\))?\s*->.*\{?\s*$", line)
        if line and not line[0].isspace():
            m2 = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if m2 and "{" in line:
                current = m2.group(1)
        yield current, line


def analyse(hlo_text: str, top: int = 25):
    colls = []
    by_comp = defaultdict(lambda: defaultdict(int))
    trip_re = re.compile(r"trip_count=(\d+)")
    for comp, line in computation_blocks(hlo_text):
        m = _OP_RE.search(line)
        if m and f"{m.group(2)}-done(" not in line:
            b = shape_bytes(m.group(1))
            colls.append((b, m.group(2), comp, line.strip()[:140]))
            by_comp[comp][m.group(2)] += b
    colls.sort(reverse=True)
    print(f"top {top} collectives by output bytes:")
    for b, kind, comp, _line in colls[:top]:
        print(f"  {b / 2**20:10.1f} MiB {kind:20s} in {comp[:40]:40s}")
    print("\nbytes by computation (loop bodies execute trip_count times):")
    for comp, kinds in sorted(by_comp.items(),
                              key=lambda kv: -sum(kv[1].values()))[:12]:
        tot = sum(kinds.values())
        det = ", ".join(f"{k}:{v / 2**20:.0f}MiB" for k, v in
                        sorted(kinds.items(), key=lambda kv: -kv[1]))
        print(f"  {tot / 2**30:8.2f} GiB  {comp[:48]:48s} {det}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--mesh-shape", default=None)
    ap.add_argument("--dump", default=None, help="write full HLO here")
    args = ap.parse_args(argv)

    import jax
    from repro.launch import dryrun as dr
    mesh, label = dr.build_mesh(argparse.Namespace(
        mesh=args.mesh, mesh_shape=args.mesh_shape))
    from repro.configs import get_arch, get_shape
    from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
    from repro.models import transformer as T
    from repro.models.zoo import input_specs
    from repro.optim.optimizers import AdamState
    from repro.sharding.rules import batch_specs, cache_specs, param_specs
    from functools import partial
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    from repro.models import layers as _L
    _L.set_gqa_grouped(True)
    T.set_batch_axes(tuple(n for n in mesh.axis_names if n != "model"))
    pspecs = param_specs(cfg, mesh)
    param_shapes = jax.eval_shape(partial(T.init_params, cfg), jax.random.PRNGKey(0))
    bspecs = batch_specs(cfg, shape, mesh)
    batch_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                         sharding=NamedSharding(mesh, bspecs[k]))
                 for k, v in input_specs(cfg, shape).items()}
    with mesh:
        if shape.mode == "train":
            step, opt = make_train_step(cfg, q_chunk=1024)
            opt_shapes = jax.eval_shape(opt.init, param_shapes)
            args_ = (dr._sharded_sds(param_shapes, pspecs, mesh),
                     dr._sharded_sds(opt_shapes,
                                     AdamState(mu=pspecs, nu=pspecs, count=P()),
                                     mesh),
                     batch_sds)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, q_chunk=1024)
            args_ = (dr._sharded_sds(dr._cast_tree(param_shapes, jnp.bfloat16),
                                     pspecs, mesh), batch_sds)
        else:
            step = make_serve_step(cfg)
            cache_shapes = jax.eval_shape(
                lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
            args_ = (dr._sharded_sds(dr._cast_tree(param_shapes, jnp.bfloat16),
                                     pspecs, mesh),
                     dr._sharded_sds(cache_shapes,
                                     cache_specs(cfg, shape, mesh), mesh),
                     batch_sds)
        compiled = jax.jit(step).lower(*args_).compile()
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)
        print(f"HLO written to {args.dump} ({len(text) / 2**20:.1f} MiB)")
    analyse(text)


if __name__ == "__main__":
    main()
