"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs        / (chips * peak_FLOP/s)
    memory     = HLO_bytes        / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

Sources: ``compiled.cost_analysis()`` supplies flops + bytes accessed;
collective bytes come from the optimized-HLO parse (roofline/hlo.py).
The dominant term is the bottleneck the §Perf loop iterates on.

Caveats (measured, see EXPERIMENTS.md §Roofline):

* ``cost_analysis()`` is per-SPMD-program (= per-device) — good — but the
  CPU backend's HloCostAnalysis under-counts ``while``-loop bodies for
  some lowerings (we observe arch-dependent 1x..10x undercount of the
  layer-scan flops) and *over*-counts bytes (logical operand bytes, CPU
  fusion is shallow, so "bytes accessed" is ~2 orders above real HBM
  traffic on a TPU).
* We therefore report, next to the three spec terms, two *analytic*
  estimates derived from the architecture alone: ``compute_analytic_s``
  (matmul + attention flops) and ``hbm_est_s`` (a first-order traffic
  model: optimizer/weight streaming + remat activation traffic + KV
  cache reads).  ``dominant_est`` = argmax(analytic compute, est memory,
  collective) is what §Perf hillclimbs; the spec-formula ``dominant`` is
  kept verbatim for comparability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float                  # 6*N*D (dense) / 6*N_active*D (MoE)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0           # MODEL_FLOPS / (flops_per_device*chips)
    # analytic estimates (EXPERIMENTS.md §Roofline caveats)
    analytic_flops_total: float = 0.0
    hbm_est_bytes_per_device: float = 0.0
    compute_analytic_s: float = 0.0
    hbm_est_s: float = 0.0
    dominant_est: str = ""
    memory_analysis: Optional[dict] = None
    collectives: Optional[dict] = None

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops_per_device / PEAK_FLOPS_BF16
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes_per_device / ICI_BW_PER_LINK
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        fleet = self.flops_per_device * self.chips
        self.useful_ratio = self.model_flops / fleet if fleet else 0.0
        self.compute_analytic_s = (self.analytic_flops_total / self.chips
                                   / PEAK_FLOPS_BF16)
        self.hbm_est_s = self.hbm_est_bytes_per_device / HBM_BW
        est = {"compute": self.compute_analytic_s, "memory": self.hbm_est_s,
               "collective": self.collective_s}
        self.dominant_est = max(est, key=est.get)
        return self

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:24s} {self.shape:12s} {self.mesh:6s} "
                f"compute={self.compute_s * 1e3:9.2f}ms "
                f"memory={self.memory_s * 1e3:9.2f}ms "
                f"collective={self.collective_s * 1e3:9.2f}ms "
                f"dominant={self.dominant:10s} useful={self.useful_ratio:6.1%}")


def model_flops(cfg, shape) -> float:
    """6*N*D with N = active params, D = processed tokens (per step)."""
    n = cfg.n_active_params()
    if shape.mode == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.mode == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d                  # forward only
    return 2.0 * n * shape.global_batch     # decode: one token per sequence


def _attn_layers(cfg) -> list:
    """(kind, window) for attention-bearing layers (incl. zamba shared)."""
    out = []
    if cfg.attn is None:
        return out
    for k in cfg.layer_kinds():
        if k in ("attn", "shared_attn"):
            out.append(("attn", cfg.attn.window))
        elif k == "gattn":
            out.append(("gattn", None))
        elif k == "mla":
            out.append(("mla", None))
    if cfg.enc_layers:
        out += [("attn", None)] * cfg.enc_layers   # encoder self-attn
        out += [("xattn", None)] * cfg.n_layers    # decoder cross-attn
    return out


def analytic_flops(cfg, shape) -> float:
    """MODEL_FLOPS + the attention score/value flops (the part 6*N*D
    misses).  First-order: per attn layer, fwd flops = 4*B*S*W_eff*H*dh
    (scores + values), W_eff = average visible context."""
    base = model_flops(cfg, shape)
    if cfg.attn is None:
        return base
    h, dh = cfg.attn.n_heads, cfg.attn.d_head
    b, s = shape.global_batch, shape.seq_len
    mult = 3.0 if shape.mode == "train" else 1.0
    tokens = b * (s if shape.mode in ("train", "prefill") else 1)
    attn = 0.0
    for kind, window in _attn_layers(cfg):
        if shape.mode == "decode":
            ctx = s if window is None else min(window, s)
        else:
            ctx = s / 2 if window is None else min(window, s / 2)
        if kind == "xattn":
            ctx = cfg.frontend.n_frames if cfg.frontend else s
        attn += 4.0 * tokens * ctx * h * dh * mult
    return base + attn


def estimate_hbm_bytes(cfg, shape, chips: int) -> float:
    """First-order per-device HBM traffic per step (TPU target).

    train:   20 B/param (fp32 weights+grads+Adam moments R/W) / chips
             + remat activation traffic (~6 saved tensors x bf16)
             + logits (3x R/W at bf16)
    prefill: bf16 weights read + 2x activations + KV-cache write
    decode:  bf16 active weights read + KV/state cache read
    """
    n_total = cfg.n_params()
    n_active = cfg.n_active_params()
    d, l, v = cfg.d_model, cfg.n_layers, cfg.vocab
    b, s = shape.global_batch, shape.seq_len

    def cache_bytes() -> float:
        total = 0.0
        if cfg.attn is not None:
            kv_dim = cfg.attn.n_kv_heads * cfg.attn.d_head
            for kind, window in _attn_layers(cfg):
                if kind == "mla":
                    per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
                else:
                    per_tok = 2 * kv_dim
                ctx = s if window is None else min(window, s)
                total += b * ctx * per_tok * 2
        if cfg.ssm is not None:
            n_mamba = sum(1 for k in cfg.layer_kinds()
                          if k in ("mamba", "shared_attn"))
            di = cfg.ssm.d_inner(d)
            total += n_mamba * b * (di // cfg.ssm.head_dim) \
                * cfg.ssm.head_dim * cfg.ssm.d_state * 2
        return total

    if shape.mode == "train":
        tokens_dev = b * s / chips
        traffic = 20.0 * n_total / chips
        traffic += 6.0 * tokens_dev * d * l * 2
        traffic += 3.0 * tokens_dev * v * 2
        return traffic
    if shape.mode == "prefill":
        tokens_dev = b * s / chips
        return 2.0 * n_active / chips + 4.0 * tokens_dev * d * l * 2 \
            + cache_bytes() / chips
    return 2.0 * n_active / chips + cache_bytes() / chips


def build_roofline(arch: str, shape_name: str, mesh_name: str, chips: int,
                   cost: dict, collective_bytes_total: float,
                   mflops: float, memory_analysis: Optional[dict] = None,
                   collectives: Optional[dict] = None,
                   cfg=None, shape=None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0)))
    a_flops = analytic_flops(cfg, shape) if cfg is not None else 0.0
    hbm_est = estimate_hbm_bytes(cfg, shape, chips) if cfg is not None else 0.0
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=collective_bytes_total / max(chips, 1),
        model_flops=mflops,
        analytic_flops_total=a_flops, hbm_est_bytes_per_device=hbm_est,
        memory_analysis=memory_analysis, collectives=collectives,
    ).finalize()
