"""Render the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts.

    PYTHONPATH=src python -m repro.roofline.report \
        --artifacts experiments/artifacts --md
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(art_dir: Path) -> list[dict]:
    recs = []
    for f in sorted(art_dir.glob("*.json")):
        recs.append(_refresh(json.loads(f.read_text())))
    return recs


def _refresh(rec: dict) -> dict:
    """Recompute the analytic roofline fields from the stored artifact (so
    old artifacts pick up estimator improvements without a re-sweep)."""
    if rec.get("status") != "ok":
        return rec
    from repro.configs import get_arch, get_shape
    from repro.roofline.analysis import build_roofline
    cfg = get_arch(rec["arch"])
    shape = get_shape(rec["shape"])
    roof = build_roofline(
        rec["arch"], rec["shape"], rec["mesh"], rec["chips"],
        rec.get("cost_analysis", {}),
        rec["collectives"]["total_bytes"],
        rec["roofline"]["model_flops"],
        memory_analysis=rec.get("memory_analysis"),
        collectives=rec.get("collectives"),
        cfg=cfg, shape=shape)
    rec["roofline"] = roof.as_dict()
    return rec


def _fmt_bytes(n) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PiB"


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | status | params | compile (s) | "
             "peak mem/dev | collectives (bytes by kind) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP ({r['reason'][:40]}...) | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | |")
            continue
        mem = r.get("memory_analysis", {})
        peak = mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)
        coll = r["collectives"]["bytes_by_kind"]
        coll_s = ", ".join(f"{k}:{_fmt_bytes(v)}" for k, v in sorted(coll.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['n_params'] / 1e9:.2f}B | {r['compile_s']} "
            f"| {_fmt_bytes(peak)} | {coll_s or '-'} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "single") -> str:
    lines = ["| arch | shape | compute (ms) | memory (ms) | coll (ms) "
             "| dominant | cmp-an (ms) | hbm-est (ms) | dom-est "
             "| MODEL_FLOPS | useful |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['compute_s'] * 1e3:.2f} | {rf['memory_s'] * 1e3:.2f} "
            f"| {rf['collective_s'] * 1e3:.2f} | **{rf['dominant']}** "
            f"| {rf['compute_analytic_s'] * 1e3:.2f} "
            f"| {rf['hbm_est_s'] * 1e3:.2f} | **{rf['dominant_est']}** "
            f"| {rf['model_flops']:.2e} | {rf['useful_ratio']:.2f} |")
    return "\n".join(lines)


def interesting_pairs(recs: list[dict]) -> dict:
    """Hillclimb picks: worst est-roofline fraction (most headroom vs the
    analytic compute bound), most collective-bound, most
    paper-representative."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "single"]
    heavy = [r for r in ok if r["shape"] in ("train_4k", "prefill_32k")]

    def roof_fraction(r):
        rf = r["roofline"]
        tot = rf["compute_analytic_s"] + rf["hbm_est_s"] + rf["collective_s"]
        return rf["compute_analytic_s"] / max(tot, 1e-12)

    worst = min(heavy, key=roof_fraction)
    coll = max(heavy, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["compute_analytic_s"]
                     + r["roofline"]["hbm_est_s"], 1e-12))
    return {"worst_roofline_fraction": (worst["arch"], worst["shape"],
                                        round(roof_fraction(worst), 3)),
            "most_collective": (coll["arch"], coll["shape"])}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="experiments/artifacts")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args(argv)
    recs = load(Path(args.artifacts))
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(recs, "multi"))
    print("\nhillclimb candidates:", interesting_pairs(recs))


if __name__ == "__main__":
    main()
