import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

This proves the distribution config is coherent without TPU hardware:
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed on the
single-pod (16, 16) and multi-pod (2, 16, 16) meshes for every assigned
architecture and input shape, and the compiled artifact yields the
memory/cost analysis the roofline consumes.

Usage:
    python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out experiments/artifacts
    python -m repro.launch.dryrun --arch ... --mesh-shape 2,4   # small (tests)
"""
import argparse
import json
import sys
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import transformer as T
from repro.models.zoo import input_specs, param_count
from repro.optim.optimizers import AdamState
from repro.roofline.analysis import build_roofline, model_flops
from repro.roofline.hlo import parse_collectives
from repro.sharding.rules import batch_specs, cache_specs, param_specs


def _sharded_sds(shape_tree, spec_tree, mesh):
    def mk(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(mk, shape_tree, spec_tree)


def _cast_tree(shape_tree, dtype):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if s.dtype == jnp.float32 else s.dtype), shape_tree)


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def dryrun_one(arch_name: str, shape_name: str, mesh, mesh_name: str,
               verbose: bool = True) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if shape_name in cfg.skip_shapes:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "full-attention arch: long-context decode skipped "
                          "(DESIGN.md §4)"}
    t0 = time.time()
    chips = int(np.prod(mesh.devices.shape))
    # perf iterations 3+4 (EXPERIMENTS.md §Perf): anchor the residual
    # stream's batch axis to the data-parallel axes; grouped GQA attention
    from repro.models import layers as _L
    _L.set_gqa_grouped(True)
    T.set_batch_axes(tuple(n for n in mesh.axis_names if n != "model"))
    pspecs = param_specs(cfg, mesh)
    param_shapes = jax.eval_shape(partial(T.init_params, cfg),
                                  jax.random.PRNGKey(0))
    bspecs = batch_specs(cfg, shape, mesh)
    batch_sds = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
        for k, v in input_specs(cfg, shape).items()}

    with mesh:
        if shape.mode == "train":
            # perf iteration 5: save matmul outputs in remat for <30B
            # models (-12% flops, -16% collectives, +~0.5 GiB/dev acts);
            # llama4-scale keeps full remat for HBM headroom.
            policy = "full" if param_count(cfg) > 30e9 else "dots"
            step, opt = make_train_step(cfg, q_chunk=1024, remat=policy)
            opt_shapes = jax.eval_shape(opt.init, param_shapes)
            opt_specs = AdamState(mu=pspecs, nu=pspecs, count=P())
            args = (_sharded_sds(param_shapes, pspecs, mesh),
                    _sharded_sds(opt_shapes, opt_specs, mesh),
                    batch_sds)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, q_chunk=1024)
            bf16_params = _cast_tree(param_shapes, jnp.bfloat16)
            args = (_sharded_sds(bf16_params, pspecs, mesh), batch_sds)
        else:  # decode
            step = make_serve_step(cfg)
            bf16_params = _cast_tree(param_shapes, jnp.bfloat16)
            cache_shapes = jax.eval_shape(
                lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
            cspecs = cache_specs(cfg, shape, mesh)
            args = (_sharded_sds(bf16_params, pspecs, mesh),
                    _sharded_sds(cache_shapes, cspecs, mesh),
                    batch_sds)

        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = _memory_analysis_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    mflops = model_flops(cfg, shape)
    roof = build_roofline(arch_name, shape_name, mesh_name, chips,
                          cost or {}, coll.total_bytes, mflops,
                          memory_analysis=mem, collectives=coll.as_dict(),
                          cfg=cfg, shape=shape)
    rec = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "n_params": param_count(cfg),
        "n_active_params": param_count(cfg, active_only=True),
        "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                          if np.isscalar(v)},
        "memory_analysis": mem,
        "collectives": coll.as_dict(),
        "roofline": roof.as_dict(),
    }
    if verbose:
        print(roof.summary(), f"[lower {t_lower:.0f}s compile {t_compile:.0f}s]",
              flush=True)
    return rec


def build_mesh(args):
    if args.mesh_shape:
        dims = tuple(int(x) for x in args.mesh_shape.split(","))
        names = ("pod", "data", "model")[-len(dims):]
        return jax.make_mesh(dims, names), "x".join(map(str, dims))
    if args.mesh == "multi":
        return make_production_mesh(multi_pod=True), "multi"
    return make_production_mesh(multi_pod=False), "single"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--mesh-shape", default=None,
                    help="override, e.g. '2,4' (tests)")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) combination")
    ap.add_argument("--out", default="experiments/artifacts")
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_name in meshes:
        sub = argparse.Namespace(mesh=mesh_name, mesh_shape=args.mesh_shape)
        mesh, mesh_label = build_mesh(sub)
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{mesh_label}"
                try:
                    rec = dryrun_one(arch, shape, mesh, mesh_label)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_label,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    failures.append(tag)
                    print(f"FAIL {tag}: {rec['error']}", flush=True)
                (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                jax.clear_caches()   # bound compile-cache memory over 80 runs
    if failures:
        print(f"\n{len(failures)} failures: {failures}")
        sys.exit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
