"""Production meshes.

Target: TPU v5e, 256 chips/pod.  Single pod: (data=16, model=16).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis joins
the FSDP/data-parallel group (gradient all-reduce crosses DCI).

``make_production_mesh`` is a *function* so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first
jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e per-chip hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link (~ per axis direction)
