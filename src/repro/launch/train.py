"""Production training launcher: federated training of a model-zoo
architecture with the paper's joint selection/power scheduler.

Each optimizer step is one FL communication round over a cohort of N
clients: the scheduler's sampled participation mask enters the loss as
per-example weights (eq. 4, DESIGN.md §3), and the wireless simulation
accounts time/energy exactly as the paper does — with the gradient
payload S derived from the architecture's true parameter count.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch demo-100m \
        --steps 300 --batch 16 --seq 256
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --steps 50 --scheduler optimal
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_arch
from repro.core import ProbabilisticScheduler, sample_problem
from repro.data.lm import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.zoo import grad_size_bits


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16,
                    help="cohort size = clients per round")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-clients", type=int, default=64)
    ap.add_argument("--tau", type=float, default=2.0)
    ap.add_argument("--scheduler", choices=["alternating", "optimal"],
                    default="alternating")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None, help="write metrics JSON here")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab}")

    # --- the paper's problem, with S = this model's gradient size --------
    s_bits = grad_size_bits(cfg)
    problem = sample_problem(0, args.n_clients, tau_th=args.tau,
                             grad_size_bits=s_bits,
                             total_bandwidth_hz=args.n_clients * 10e6)
    sched = ProbabilisticScheduler(solver=args.scheduler)
    state = sched.precompute(problem)
    print(f"S = {s_bits / 8e6:.1f} MB gradient payload; "
          f"E[participants] = {float(state.a.sum()):.2f}/{args.n_clients}")

    # --- model + data ------------------------------------------------------
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    train_step, opt = make_train_step(cfg, lr=args.lr, q_chunk=max(args.seq, 128))
    opt_state = opt.init(params)
    step0 = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        step0, params, opt_state, _ = ckpt.restore(
            args.ckpt_dir, params_template=params, opt_template=opt_state)
        print(f"resumed from step {step0}")
    train_step = jax.jit(train_step)
    data = SyntheticLMData(args.n_clients, cfg.vocab, seed=1)
    rng = np.random.default_rng(2)
    key = jax.random.PRNGKey(3)

    alpha = np.asarray(state.agg_weights)
    ec = np.asarray(problem.compute_energy())
    sim_time = sim_energy = 0.0
    history = []
    t_wall = time.time()
    for step in range(step0, args.steps):
        key, sub = jax.random.split(key)
        draw = sched.sample(state, sub)
        mask = np.asarray(draw.mask)
        sel = np.where(mask)[0]
        if len(sel) == 0:
            continue
        # cohort batch: participating clients, data-sized sampling
        cohort = rng.choice(sel, size=args.batch, replace=True)
        batch = {k: jnp.asarray(v) for k, v in
                 data.batch(cohort, args.seq).items()}
        coef = alpha[cohort] * mask[cohort]
        coef = coef / max(coef.sum(), 1e-12)
        batch["loss_weights"] = jnp.asarray(coef, jnp.float32)

        params, opt_state, metrics = train_step(params, opt_state, batch)

        t_all = np.asarray(problem.tx_time(jnp.asarray(draw.power)))
        sim_time += float(t_all[sel].max())
        sim_energy += float((np.asarray(draw.power)[sel] * t_all[sel]
                             + ec[sel]).sum())
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step + 1:5d} loss={loss:.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"|g|={float(metrics['grad_norm']):.2f} "
                  f"sim_t={sim_time:.0f}s E={sim_energy:.0f}J "
                  f"wall={time.time() - t_wall:.0f}s", flush=True)
            history.append({"step": step + 1, "loss": loss,
                            "sim_time_s": sim_time,
                            "sim_energy_j": sim_energy})
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, params, opt_state)

    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, params, opt_state)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(history, indent=1))
    print("done")
    return history


if __name__ == "__main__":
    main()
