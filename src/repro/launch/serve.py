"""Serving launcher: batched greedy decoding with request padding /
slot reuse (a compact continuous-batching loop over the zoo's serve path).

Requests arrive with different prompt lengths; the server packs them into
a fixed batch of decode slots, prefilling token-by-token (the same
serve_step the dry-run lowers) and emitting completions as slots free up.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        --requests 12 --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = field(default_factory=list)


class BatchedServer:
    """Fixed-slot batched decoder (one shared KV cache, per-slot pos)."""

    def __init__(self, cfg, params, batch: int, cache_len: int):
        self.cfg, self.params, self.b = cfg, params, batch
        self.cache_len = cache_len
        self.cache = T.init_cache(cfg, batch, cache_len, dtype=jnp.float32)
        self.pos = 0
        self.step = jax.jit(
            lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))

    def run(self, requests: list[Request]) -> dict:
        """Serve requests in arrival order with slot packing.

        Decoding is lockstep across slots (shared pos): a production
        server would use per-slot positions; here requests are packed in
        waves, which exercises the same lowered serve_step."""
        done: list[Request] = []
        t0 = time.time()
        steps = 0
        queue = list(requests)
        while queue:
            wave = queue[: self.b]
            queue = queue[self.b:]
            # pad the wave to batch size by repeating the last request
            while len(wave) < self.b:
                wave.append(Request(-1, wave[-1].prompt, wave[-1].max_new))
            self.cache = T.init_cache(self.cfg, self.b, self.cache_len,
                                      dtype=jnp.float32)
            max_prompt = max(len(r.prompt) for r in wave)
            prompts = np.stack([
                np.pad(r.prompt, (max_prompt - len(r.prompt), 0),
                       constant_values=0) for r in wave])
            logits = None
            for i in range(max_prompt):
                tok = jnp.asarray(prompts[:, i:i + 1], jnp.int32)
                logits, self.cache = self.step(self.params, self.cache, tok,
                                               jnp.int32(i))
                steps += 1
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            max_new = max(r.max_new for r in wave)
            for j in range(max_new):
                toks = np.asarray(tok)[:, 0]
                for slot, r in enumerate(wave):
                    if r.rid >= 0 and j < r.max_new:
                        r.out.append(int(toks[slot]))
                logits, self.cache = self.step(self.params, self.cache, tok,
                                               jnp.int32(max_prompt + j))
                tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
                steps += 1
            done.extend(r for r in wave if r.rid >= 0)
        dt = time.time() - t0
        total_tokens = sum(len(r.out) for r in done)
        return {"requests": len(done), "tokens": total_tokens,
                "wall_s": dt, "tok_per_s": total_tokens / max(dt, 1e-9),
                "decode_steps": steps,
                "completions": {r.rid: r.out[:8] for r in done}}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        rng.integers(4, 24)).astype(np.int32),
                    max_new=args.gen)
            for i in range(args.requests)]
    server = BatchedServer(cfg, params, args.batch,
                           cache_len=64 + args.gen)
    stats = server.run(reqs)
    print(f"served {stats['requests']} requests, {stats['tokens']} tokens "
          f"in {stats['wall_s']:.1f}s ({stats['tok_per_s']:.1f} tok/s, "
          f"reduced {cfg.name} on CPU)")
    return stats


if __name__ == "__main__":
    main()
