"""train_step / serve_step builders shared by the launcher and dry-run.

``train_step`` is one FedSGD communication round over a client cohort
(DESIGN.md §3): the batch carries per-example ``loss_weights`` =
alpha_i * m_i (participation mask sampled from the paper's a*), so the
data-parallel gradient reduction *is* the server aggregation of eq. (4).
AdamW state is fp32 and sharded like the parameters (ZeRO); compute runs
in bf16.

``serve_step`` is one decode step against a KV cache.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.models.zoo import lm_loss
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm


def cast_bf16(tree):
    return jax.tree_util.tree_map(
        lambda p: p.astype(jnp.bfloat16)
        if (hasattr(p, "dtype") and p.dtype == jnp.float32) else p, tree)


def make_train_step(cfg: ArchConfig, lr: float = 1e-4,
                    q_chunk: int = 1024, remat="full",
                    clip_norm: float = 1.0) -> Callable:
    opt = adamw(lr)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, parts = lm_loss(cfg, cast_bf16(p), batch,
                                  q_chunk=q_chunk, remat=remat)
            return loss, parts

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(parts, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step, opt


def make_prefill_step(cfg: ArchConfig, q_chunk: int = 1024) -> Callable:
    def prefill_step(params, batch):
        logits, _ = T.forward(cfg, params, batch, q_chunk=q_chunk, remat=False)
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, cache, batch):
        logits, cache = T.decode_step(cfg, params, cache,
                                      batch["tokens"], batch["pos"])
        return logits, cache

    return serve_step
