"""Sharding rules: ArchConfig -> PartitionSpec pytrees for params,
optimizer state, batches and decode caches.

Strategy (DESIGN.md §6) — 2D FSDP x TP on mesh axes (data, model), with an
optional leading 'pod' axis folded into the FSDP group:

* weight matrices: contraction-adjacent dim sharded over the FSDP axes
  (gathered on use, ZeRO-3 style), the other dim over 'model'
  (Megatron TP) — *when divisible*; non-divisible dims fall back to
  replication (GSPMD would otherwise pad; we prefer explicit fallback so
  the roofline attributes the cost honestly).
* embeddings: vocab over 'model' (sharded logits/softmax), d_model
  replicated.
* MoE experts: expert dim over 'model' (EP=16), internals over FSDP.
* scan-stacked layer params ('stack', 'encoder'): leading depth axis
  replicated (it is the scan axis), inner dims per the rules above.
* decode caches: batch over FSDP when divisible, else sequence over FSDP
  (long_500k's batch=1); kv-heads over 'model' when divisible.

Everything returns plain ``PartitionSpec`` trees; callers wrap them in
``NamedSharding(mesh, spec)``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical axis names + sizes of the physical mesh."""
    fsdp: tuple[str, ...]       # ('data',) or ('pod', 'data')
    tp: str                     # 'model'
    fsdp_size: int
    tp_size: int

    @classmethod
    def from_mesh(cls, mesh) -> "MeshAxes":
        names = mesh.axis_names
        sizes = dict(mesh.shape)   # works for Mesh and AbstractMesh
        fsdp = tuple(n for n in names if n != "model")
        fsdp_size = int(np.prod([sizes[n] for n in fsdp]))
        return cls(fsdp=fsdp, tp="model", fsdp_size=fsdp_size,
                   tp_size=sizes["model"])


def _div(n: int, k: int) -> bool:
    return n % k == 0


class _Ruler:
    def __init__(self, ax: MeshAxes):
        self.ax = ax

    def fsdp(self, dim: int):
        return self.ax.fsdp if _div(dim, self.ax.fsdp_size) else None

    def tp(self, dim: int):
        return self.ax.tp if _div(dim, self.ax.tp_size) else None


def _param_rule(path_keys: tuple[str, ...], shape: tuple[int, ...],
                r: _Ruler) -> P:
    """Rule for one parameter leaf; `path_keys` are dict keys on the path."""
    ks = set(path_keys)
    name = path_keys[-1] if path_keys else ""
    stacked = ("stack" in ks or "encoder" in ks)
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape

    def spec(*axes):
        return P(*(lead + tuple(axes)))

    if len(core) == 0:
        return spec()
    if name in ("scale", "b", "conv_b", "A_log", "D", "dt_bias") or len(core) == 1:
        return spec(None)

    if name == "embed" or name == "unembed":
        v_first = name == "embed"
        vdim = core[0] if v_first else core[1]
        t = r.tp(vdim)
        return spec(t, None) if v_first else spec(None, t)

    if "experts" in ks:                        # [E, d, ff] / [E, ff, d]
        e, a, b = core
        if name == "w2":
            return spec(r.tp(e), None, r.fsdp(b))
        return spec(r.tp(e), r.fsdp(a), None)

    if name in ("wo", "w2", "out_proj"):       # [contract_out, d_model]
        return spec(r.tp(core[0]), r.fsdp(core[1]))
    if name in ("wq", "wk", "wv", "w1", "w3", "w_ukv",
                "in_z", "in_x", "in_dt"):      # Megatron column-parallel
        return spec(r.fsdp(core[0]), r.tp(core[1]))
    if name in ("router", "w_dkv", "frontend_proj", "in_b", "in_c",
                "xattn_proj"):
        return spec(r.fsdp(core[0]), None)
    if name.startswith("conv_"):
        return spec(*([None] * len(core)))
    # default: FSDP on the largest dim
    big = int(np.argmax(core))
    axes = [None] * len(core)
    axes[big] = r.fsdp(core[big])
    return spec(*axes)


def _leaf_path_keys(path) -> tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
    return tuple(keys)


def param_specs(cfg: ArchConfig, mesh) -> Any:
    """PartitionSpec tree matching init_params(cfg) structure."""
    ax = MeshAxes.from_mesh(mesh)
    r = _Ruler(ax)
    shapes = jax.eval_shape(partial(T.init_params, cfg), jax.random.PRNGKey(0))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(_leaf_path_keys(path), leaf.shape, r),
        shapes)


def batch_specs(cfg: ArchConfig, shape: InputShape, mesh) -> dict:
    ax = MeshAxes.from_mesh(mesh)
    dp = ax.fsdp if _div(shape.global_batch, ax.fsdp_size) else None
    specs: dict = {}
    if shape.mode == "decode":
        return {"tokens": P(dp, None), "pos": P()}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        specs["vision"] = P(dp, None, None)
    if cfg.frontend is not None and cfg.frontend.kind == "audio":
        specs["audio"] = P(dp, None, None)
    specs["tokens"] = P(dp, None)
    if shape.mode == "train":
        specs["labels"] = P(dp, None)
        specs["loss_weights"] = P(dp)
    return specs


def cache_specs(cfg: ArchConfig, shape: InputShape, mesh) -> Any:
    """Spec tree matching init_cache structure (incl. stacked leading axis)."""
    ax = MeshAxes.from_mesh(mesh)
    r = _Ruler(ax)
    b = shape.global_batch
    batch_ax = ax.fsdp if _div(b, ax.fsdp_size) else None

    def leaf_rule(path, leaf):
        keys = set(_leaf_path_keys(path))
        name = _leaf_path_keys(path)[-1] if path else ""
        stacked = "stack" in keys
        shape_ = leaf.shape[1:] if stacked else leaf.shape
        lead = (None,) if stacked else ()

        def spec(*axes):
            return P(*(lead + tuple(axes)))

        nd = len(shape_)
        if name == "pos":
            return spec(*([None] * nd))
        if name in ("k", "v"):                  # [B, W, Hkv, dh]
            _, w, hkv, _ = shape_
            seq_ax = None if batch_ax else (ax.fsdp if _div(w, ax.fsdp_size) else None)
            return spec(batch_ax, seq_ax, r.tp(hkv), None)
        if name in ("c_kv", "k_rope"):          # [B, L, r]
            _, l, _ = shape_
            seq_ax = None if batch_ax else (ax.fsdp if _div(l, ax.fsdp_size) else None)
            return spec(batch_ax, seq_ax, None)
        if name == "state":                     # [B, H, P, N]
            _, h, _, _ = shape_
            return spec(batch_ax, r.tp(h), None, None)
        if name == "conv":                      # [B, K-1, conv_dim]
            return spec(batch_ax, None, None)
        if name in ("cross_k", "cross_v"):      # [B, frames, H, dh]
            _, _, hkv, _ = shape_
            return spec(batch_ax, None, r.tp(hkv), None)
        return spec(*([None] * nd))

    cache_shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, b, shape.seq_len))
    return jax.tree_util.tree_map_with_path(leaf_rule, cache_shapes)
