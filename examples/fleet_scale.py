"""Fleet-scale solve on the scenario engine — a smoke benchmark.

Two axes of scale, both far beyond the paper's single 100-device instance,
both driven by the fused single-level solver (``method="fused"`` /
``solve_joint_fused``) with its chunked, element-sharded mega-fleet path:

1. **One huge fleet** (``--n``): the fused chunked driver against
   Algorithm 2 (nested loops), the exact bisection optimum, and the
   Pallas kernels on a single N-device scenario drawn from the registry
   (interpret mode on CPU; compiled on TPU).  Prints solved-devices/sec.
2. **Many scenarios at once** (``--batch``): a ``ProblemBatch`` of i.i.d.
   scenario draws solved by ``solve_joint_batch(method="fused")`` in one
   flat, device-sharded call, versus the PR-1 vmapped path and the naive
   per-instance python loop.

    PYTHONPATH=src python examples/fleet_scale.py --n 1000000
    PYTHONPATH=src python examples/fleet_scale.py --scenario mega_fleet_100k --n 100000
    PYTHONPATH=src python examples/fleet_scale.py --scenario rayleigh_fading --batch 64
"""
import argparse
import time

import jax

from repro.core import (
    solve_joint,
    solve_joint_batch,
    solve_joint_fused,
    solve_joint_optimal,
)
from repro.core.scenarios import SCENARIOS, make_batch, make_problem
from repro.kernels.selection_solve.ops import solve_joint_kernel


def _bench(fn):
    """Compile (warmup call), then time one blocked solve."""
    sol = fn()
    jax.block_until_ready(sol.a)
    t0 = time.perf_counter()
    sol = fn()
    jax.block_until_ready(sol.a)
    return sol, time.perf_counter() - t0


def bench_single_fleet(scenario: str, n: int, chunk: int) -> None:
    prob = make_problem(scenario, seed=0, n_devices=n)
    # fading solves n_rounds elements per device; report the honest unit
    n_elements = n * (prob.n_rounds if prob.fading is not None else 1)
    unit = "elements/sec" if prob.fading is not None else "devices/sec"
    print(f"--- one {n}-device '{scenario}' fleet "
          f"({len(jax.devices())} device(s)) ---")
    solvers = [
        ("fused chunked (mega-fleet)",
         jax.jit(lambda p: solve_joint_fused(p, chunk_elements=chunk,
                                             shard=True))),
        ("fused flat (single launch)", jax.jit(solve_joint_fused)),
        ("alternating (paper Alg 2)", jax.jit(solve_joint)),
        ("bisection optimum (ours)", jax.jit(solve_joint_optimal)),
        ("pallas kernel (interpret)",
         lambda p: solve_joint_kernel(p, interpret=True)),
    ]
    for name, fn in solvers:
        sol, dt = _bench(lambda fn=fn: fn(prob))
        feas = bool(prob.constraints_satisfied(sol.a, sol.power, rtol=1e-3).all())
        print(f"{name:28s}: objective={float(sol.objective):.6f} "
              f"E[participants]={float(sol.a.sum()):9.1f} "
              f"{dt * 1e3:8.1f} ms/solve "
              f"{n_elements / dt:12.0f} {unit} feasible={feas}")


def bench_scenario_batch(scenario: str, batch_size: int) -> None:
    n = SCENARIOS[scenario].n_devices
    batch = make_batch(scenario, batch_size, seed=0)
    n_devices_total = int(batch.fleet_sizes.sum())
    print(f"--- {batch_size} x {n}-device '{scenario}' instances, "
          f"{len(jax.devices())} device(s) ---")

    def run(label, fn):
        sol, dt = _bench(fn)
        print(f"{label:28s}: {batch_size / dt:10.1f} instances/sec "
              f"{n_devices_total / dt:12.0f} devices/sec "
              f"({dt * 1e3:.1f} ms total)")
        return sol, dt

    sol, dt_fused = run("fused (flat element set)",
                        lambda: solve_joint_batch(batch, method="fused"))
    _, dt_vmap = run("vmapped Alg 2 (PR-1 path)",
                     lambda: solve_joint_batch(batch))

    single = jax.jit(solve_joint)
    problems = batch.unstack()
    jax.block_until_ready(single(problems[0]).a)        # compile
    t0 = time.perf_counter()
    for p in problems:
        ref = single(p)
    jax.block_until_ready(ref.a)
    dt_loop = time.perf_counter() - t0
    print(f"{'per-instance python loop':28s}: {batch_size / dt_loop:10.1f} "
          f"instances/sec {n_devices_total / dt_loop:12.0f} devices/sec "
          f"({dt_loop * 1e3:.1f} ms total)")
    print(f"fused speedup: {dt_vmap / dt_fused:.1f}x vs vmapped, "
          f"{dt_loop / dt_fused:.1f}x vs loop")

    obj = sol.objective
    print(f"objective over the ensemble: mean={float(obj.mean()):.5f} "
          f"min={float(obj.min()):.5f} max={float(obj.max()):.5f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000,
                    help="fleet size for the single-fleet comparison")
    ap.add_argument("--scenario", default="paper_static",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--batch", type=int, default=32,
                    help="number of stacked scenario instances")
    ap.add_argument("--chunk-elements", type=int, default=16_384,
                    help="fused mega-fleet memory bound (elements per chunk)")
    args = ap.parse_args()

    bench_single_fleet(args.scenario, args.n, args.chunk_elements)
    bench_scenario_batch(args.scenario, args.batch)


if __name__ == "__main__":
    main()
