"""Fleet-scale solve: the paper optimises 100 devices; the framework's
vectorised formulation handles planetary fleets in one jit.  Compares the
paper's Algorithm 2, the exact bisection optimum, and the Pallas
selection_solve kernel (interpret mode on CPU; compiled on TPU).

    PYTHONPATH=src python examples/fleet_scale.py --n 1000000
"""
import argparse
import time

import jax
import numpy as np

from repro.core import sample_problem, solve_joint, solve_joint_optimal
from repro.kernels.selection_solve.ops import solve_joint_kernel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    args = ap.parse_args()

    prob = sample_problem(0, args.n)
    for name, fn in [("alternating (paper Alg 2)", jax.jit(solve_joint)),
                     ("bisection optimum (ours)", jax.jit(solve_joint_optimal)),
                     ("pallas kernel (interpret)",
                      lambda p: solve_joint_kernel(p, interpret=True))]:
        sol = fn(prob)          # compile
        jax.block_until_ready(sol.a)
        t0 = time.perf_counter()
        sol = fn(prob)
        jax.block_until_ready(sol.a)
        dt = time.perf_counter() - t0
        feas = bool(prob.constraints_satisfied(sol.a, sol.power, rtol=1e-3).all())
        print(f"{name:28s}: objective={float(sol.objective):.6f} "
              f"E[participants]={float(sol.a.sum()):9.1f} "
              f"{dt * 1e3:8.1f} ms/solve feasible={feas}")


if __name__ == "__main__":
    main()
