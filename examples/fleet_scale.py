"""Fleet-scale solve on the scenario engine.

Two axes of scale, both far beyond the paper's single 100-device instance:

1. **One huge fleet** (``--n``): Algorithm 2, the exact bisection optimum,
   and the Pallas selection_solve kernel on a single N-device scenario
   drawn from the registry (interpret mode on CPU; compiled on TPU).
2. **Many scenarios at once** (``--batch``): a ``ProblemBatch`` of i.i.d.
   scenario draws solved by ``solve_joint_batch`` in one vmapped,
   device-sharded call, versus the naive per-instance python loop.

    PYTHONPATH=src python examples/fleet_scale.py --n 1000000
    PYTHONPATH=src python examples/fleet_scale.py --scenario rayleigh_fading --batch 64
"""
import argparse
import time

import jax

from repro.core import solve_joint, solve_joint_batch, solve_joint_optimal
from repro.core.scenarios import SCENARIOS, make_batch, make_problem
from repro.kernels.selection_solve.ops import solve_joint_kernel


def bench_single_fleet(scenario: str, n: int) -> None:
    prob = make_problem(scenario, seed=0, n_devices=n)
    print(f"--- one {n}-device '{scenario}' fleet ---")
    for name, fn in [("alternating (paper Alg 2)", jax.jit(solve_joint)),
                     ("bisection optimum (ours)", jax.jit(solve_joint_optimal)),
                     ("pallas kernel (interpret)",
                      lambda p: solve_joint_kernel(p, interpret=True))]:
        sol = fn(prob)          # compile
        jax.block_until_ready(sol.a)
        t0 = time.perf_counter()
        sol = fn(prob)
        jax.block_until_ready(sol.a)
        dt = time.perf_counter() - t0
        feas = bool(prob.constraints_satisfied(sol.a, sol.power, rtol=1e-3).all())
        print(f"{name:28s}: objective={float(sol.objective):.6f} "
              f"E[participants]={float(sol.a.sum()):9.1f} "
              f"{dt * 1e3:8.1f} ms/solve feasible={feas}")


def bench_scenario_batch(scenario: str, batch_size: int) -> None:
    n = SCENARIOS[scenario].n_devices
    batch = make_batch(scenario, batch_size, seed=0)
    print(f"--- {batch_size} x {n}-device '{scenario}' instances, "
          f"{len(jax.devices())} device(s) ---")

    sol = solve_joint_batch(batch)                      # compile
    jax.block_until_ready(sol.a)
    t0 = time.perf_counter()
    sol = solve_joint_batch(batch)
    jax.block_until_ready(sol.a)
    dt_batch = time.perf_counter() - t0

    single = jax.jit(solve_joint)
    problems = batch.unstack()
    jax.block_until_ready(single(problems[0]).a)        # compile
    t0 = time.perf_counter()
    for p in problems:
        ref = single(p)
    jax.block_until_ready(ref.a)
    dt_loop = time.perf_counter() - t0

    obj = sol.objective
    print(f"batched : {batch_size / dt_batch:10.1f} instances/sec "
          f"({dt_batch * 1e3:.1f} ms total)")
    print(f"loop    : {batch_size / dt_loop:10.1f} instances/sec "
          f"({dt_loop * 1e3:.1f} ms total)  -> "
          f"batched speedup {dt_loop / dt_batch:.1f}x")
    print(f"objective over the ensemble: mean={float(obj.mean()):.5f} "
          f"min={float(obj.min()):.5f} max={float(obj.max()):.5f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000,
                    help="fleet size for the single-fleet comparison")
    ap.add_argument("--scenario", default="paper_static",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--batch", type=int, default=32,
                    help="number of stacked scenario instances")
    args = ap.parse_args()

    bench_single_fleet(args.scenario, args.n)
    bench_scenario_batch(args.scenario, args.batch)


if __name__ == "__main__":
    main()
