"""Closed-loop demo: drift-aware online control plane driving FL training.

Runs the full loop of ``repro.fl.closed_loop`` on a Gauss-Markov drifting
metro cell: every round's selection probabilities and powers come from a
warm-started ``FleetControlService`` solve on that round's channel, the
benchmark-strategy suite (proposed probabilistic, per-round deterministic
top-k, uniform, channel-aware greedy, Lyapunov virtual queues) maps the
solutions to per-round participation plans, and the scan-fused sweep
engine trains and accounts every strategy in one compiled call.  Prints
the paper-style (Sec. V) comparison table.

    PYTHONPATH=src python examples/closed_loop_demo.py
    PYTHONPATH=src python examples/closed_loop_demo.py \
        --devices 32 --rounds 12 --coherence 0.95 --seeds 2
"""
import argparse

from repro.fl.closed_loop import (
    CLOSED_LOOP_STRATEGIES,
    ClosedLoopConfig,
    format_closed_loop_table,
    run_closed_loop_grid,
)
from repro.serve import FleetControlService, ServiceConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=24,
                    help="devices in the drifting cell")
    ap.add_argument("--rounds", type=int, default=8, help="FL rounds")
    ap.add_argument("--coherence", type=float, default=0.9,
                    help="Gauss-Markov channel coherence in [0, 1)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="FL seeds per strategy (shared control plane)")
    ap.add_argument("--train", type=int, default=1024,
                    help="training-set size")
    ap.add_argument("--power-solver", default=None,
                    choices=["dinkelbach", "analytic"],
                    help="service inner power solver (dinkelbach shows "
                         "the warm-start iteration drop)")
    args = ap.parse_args(argv)

    cfg = ClosedLoopConfig(n_devices=args.devices, n_rounds=args.rounds,
                           coherence=args.coherence, n_seeds=args.seeds,
                           n_train=args.train, n_test=max(args.train // 4, 64),
                           eval_every=max(args.rounds // 2, 1))
    service = None
    if args.power_solver:
        service = FleetControlService(ServiceConfig(
            method="alternating" if args.power_solver == "dinkelbach"
            else "fused", power_solver=args.power_solver))
    out = run_closed_loop_grid(cfg, CLOSED_LOOP_STRATEGIES, service=service)
    print(format_closed_loop_table(out))
    svc = out["control"]["service"]
    print(f"control plane: warm_fraction={svc['warm_fraction']:.2f} "
          f"p50={svc['p50_latency_s'] * 1e3:.1f} ms "
          f"p99={svc['p99_latency_s'] * 1e3:.1f} ms "
          f"mean_inner_iters={svc['mean_inner_iters']:.1f}")

    prop = out["strategies"]["probabilistic"]
    uni = out["strategies"]["uniform"]
    print(f"proposed vs uniform: energy {prop['total_energy_j']:.2f} J "
          f"vs {uni['total_energy_j']:.2f} J "
          f"({uni['total_energy_j'] / max(prop['total_energy_j'], 1e-9):.1f}x)")


if __name__ == "__main__":
    main()
