"""Beyond-paper: per-round block fading makes a*_ik genuinely
round-dependent (the paper's channel is static, so its k index is
vestigial — every round shares one solution).  With Rayleigh block fading
g_ik, the same closed forms solve an [N, K] batch of subproblems in one
jit, and participation tracks channel quality round by round.

    PYTHONPATH=src python examples/fading_rounds.py
"""
import numpy as np

from repro.core import sample_problem, solve_joint_optimal


def main():
    k_rounds = 24
    prob = sample_problem(7, 64, n_rounds=k_rounds, with_fading=True)
    sol = solve_joint_optimal(prob)
    a = np.asarray(sol.a)                       # [N, K]
    g = np.asarray(prob.fading)

    print(f"solution shape {a.shape}: selection probabilities per "
          f"(device, round)")
    print(f"E[participants] per round: min={a.sum(0).min():.2f} "
          f"mean={a.sum(0).mean():.2f} max={a.sum(0).max():.2f}")
    per_device_var = a.std(1).mean()
    print(f"mean per-device std of a over rounds: {per_device_var:.4f} "
          f"(static channel would give 0)")
    # fading quality should correlate positively with selection probability
    corr = np.corrcoef(g.reshape(-1), a.reshape(-1))[0, 1]
    print(f"corr(channel gain, selection probability) = {corr:.3f}")
    assert corr > 0.1, "selection should favour good channel rounds"
    feas = bool(prob.constraints_satisfied(sol.a, sol.power).all())
    print(f"all (i,k) constraints satisfied: {feas}")


if __name__ == "__main__":
    main()
