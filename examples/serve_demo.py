"""Serving demo: batched greedy decoding with a reduced model-zoo
architecture (KV caches, ring buffers, the real serve_step path).

    PYTHONPATH=src python examples/serve_demo.py --arch gemma3-1b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=48)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    print(f"serving reduced {cfg.name}: {cfg.n_layers}L d={cfg.d_model}")
    rng = np.random.default_rng(0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b = args.batch
    total = args.prompt_len + args.gen_len
    cache = T.init_cache(cfg, b, cache_len=total, dtype=jnp.float32)

    step = jax.jit(lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))

    # prefill by token-stepping (exercises the same serve path end to end)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, args.prompt_len)),
                         jnp.int32)
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))

    generated = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(args.prompt_len, total):
        generated.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(generated, 1)
    print(f"generated {gen.shape} tokens in {dt:.1f}s "
          f"({b * args.gen_len / dt:.1f} tok/s batched, CPU, reduced model)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
