"""Serving demo: the online fleet control plane on a drifting channel.

Streams per-cell solve requests for a metro area through
``repro.serve.FleetControlService`` — micro-batched, padded into fixed
slot shapes, warm-started from each cell's cached previous solution —
and prints steady-state throughput, latency percentiles and the
warm-start iteration drop versus a cold-started service.

    PYTHONPATH=src python examples/serve_demo.py
    PYTHONPATH=src python examples/serve_demo.py \
        --cells 16 --rounds 12 --devices 100 --coherence 0.95
"""
import argparse

from repro.core import make_problem, slice_round
from repro.serve import FleetControlService, ServiceConfig


def stream_rounds(service, cells, n_rounds, skip_stats_rounds=2):
    """Push every cell's per-round request through the service.

    The first two rounds carry the jit compiles (round 0 the cold
    ``init=None`` program, round 1 the first warm-started one), so the
    steady-state stats start after them — the caches keep their state
    across the reset.  Short runs keep at least the final round in the
    stats rather than resetting them away.
    """
    skip = min(skip_stats_rounds, n_rounds - 1)
    for k in range(n_rounds):
        for cell_id, prob in enumerate(cells):
            service.submit(cell_id, slice_round(prob, k))
        service.run()
        if k + 1 == skip:
            service.stats.reset()
    return service.stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=8,
                    help="base-station cells submitting requests")
    ap.add_argument("--rounds", type=int, default=8,
                    help="FL rounds (requests per cell)")
    ap.add_argument("--devices", type=int, default=64,
                    help="devices per cell")
    ap.add_argument("--coherence", type=float, default=0.9,
                    help="Gauss-Markov channel coherence in [0, 1)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="micro-batch instance slots")
    ap.add_argument("--power-solver", default="dinkelbach",
                    choices=["dinkelbach", "analytic"],
                    help="dinkelbach (paper Algorithm 1, shows the "
                         "warm-start iteration drop) or the closed-form "
                         "analytic fast path")
    args = ap.parse_args(argv)

    cells = [make_problem("drifting_metro", seed=s, n_devices=args.devices,
                          n_rounds=args.rounds, coherence=args.coherence)
             for s in range(args.cells)]
    print(f"fleet control plane: {args.cells} cells x {args.devices} "
          f"devices, {args.rounds} rounds, coherence {args.coherence}")

    results = {}
    for label, warm in (("warm", True), ("cold", False)):
        svc = FleetControlService(ServiceConfig(
            max_batch=args.max_batch, power_solver=args.power_solver,
            warm_start=warm))
        stats = stream_rounds(svc, cells, args.rounds)
        s = stats.summary()
        results[label] = s
        print(f"[{label:4s}] {s['solves_per_sec']:8.1f} solves/s   "
              f"p50 {s['p50_latency_s'] * 1e3:7.2f} ms   "
              f"p99 {s['p99_latency_s'] * 1e3:7.2f} ms   "
              f"warm {s['warm_fraction']:.0%}   "
              f"inner iters/batch {s['mean_inner_iters']:.1f}")

    if args.power_solver == "dinkelbach":
        ratio = (results["cold"]["mean_inner_iters"]
                 / max(results["warm"]["mean_inner_iters"], 1e-9))
        print(f"warm start cuts Algorithm-1 iterations "
              f"{ratio:.1f}x on this channel")
    return results


if __name__ == "__main__":
    main()
