"""Serving demo: the online fleet control plane on a drifting channel.

Streams per-cell solve requests for a metro area through
``repro.serve.FleetControlService`` — micro-batched, padded into fixed
slot shapes, warm-started from each cell's cached previous solution —
and prints steady-state throughput, latency percentiles and the
warm-start iteration drop versus a cold-started service.

``--open-loop`` switches to the arrival-driven mode instead: AOT-warm
every jit bucket, measure full-batch capacity, then drive a seeded
Poisson arrival trace at a fraction of it — with per-request deadlines,
the adaptive batch-close policy and the priority lane live — and print
sustained throughput, latency percentiles, deadline misses and
preemption counts (the ``fleet_service_openloop`` bench family's loop).

    PYTHONPATH=src python examples/serve_demo.py
    PYTHONPATH=src python examples/serve_demo.py \
        --cells 16 --rounds 12 --devices 100 --coherence 0.95
    PYTHONPATH=src python examples/serve_demo.py \
        --open-loop --load 0.7 --requests 200
"""
import argparse

from repro.core import make_problem, slice_round
from repro.serve import (
    FleetControlService,
    ServiceConfig,
    drive,
    measure_capacity,
    poisson_trace,
)


def stream_rounds(service, cells, n_rounds, skip_stats_rounds=2):
    """Push every cell's per-round request through the service.

    The first two rounds carry the jit compiles (round 0 the cold
    ``init=None`` program, round 1 the first warm-started one), so the
    steady-state stats start after them — the caches keep their state
    across the reset.  Short runs keep at least the final round in the
    stats rather than resetting them away.
    """
    skip = min(skip_stats_rounds, n_rounds - 1)
    for k in range(n_rounds):
        for cell_id, prob in enumerate(cells):
            service.submit(cell_id, slice_round(prob, k))
        service.run()
        if k + 1 == skip:
            service.stats.reset()
    return service.stats


def run_open_loop(cells, args):
    """Arrival-driven mode: warmup -> measured capacity -> seeded
    Poisson trace at ``--load`` x capacity with deadline budgets of 8
    measured batch costs."""
    svc = FleetControlService(ServiceConfig(
        max_batch=args.max_batch, power_solver=args.power_solver))
    probe = [slice_round(c, 0) for c in cells]
    wtimes = svc.warmup(probe[0], max_devices=args.devices)
    print(f"warmup: buckets {sorted(wtimes)} in "
          f"{sum(wtimes.values()):.2f} s")
    cap = measure_capacity(svc, probe)
    svc.stats.reset()
    print(f"measured capacity: {cap:.1f} solves/s "
          f"(full {args.max_batch}-slot batches)")

    deadline = 8.0 * args.max_batch / cap
    trace = poisson_trace(cells, rate_hz=args.load * cap,
                          n_requests=args.requests, seed=args.seed,
                          deadline_s=deadline)
    rep = drive(svc, trace, reset_stats_after=args.requests // 4)
    s = svc.stats.summary()
    print(f"open loop @ {args.load:.0%} capacity "
          f"({rep.offered_rate_hz:.1f} req/s offered, deadline "
          f"{deadline * 1e3:.1f} ms):")
    print(f"  sustained {rep.sustained_rate_hz:8.1f} solves/s   "
          f"p50 {s['p50_latency_s'] * 1e3:7.2f} ms   "
          f"p99 {s['p99_latency_s'] * 1e3:7.2f} ms")
    print(f"  deadline misses {s['deadline_miss_rate']:.1%}   "
          f"warm {s['warm_fraction']:.0%}   "
          f"preemptions {s['preemptions']}   closes {s['closes']}")
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=8,
                    help="base-station cells submitting requests")
    ap.add_argument("--rounds", type=int, default=8,
                    help="FL rounds (requests per cell)")
    ap.add_argument("--devices", type=int, default=64,
                    help="devices per cell")
    ap.add_argument("--coherence", type=float, default=0.9,
                    help="Gauss-Markov channel coherence in [0, 1)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="micro-batch instance slots")
    ap.add_argument("--power-solver", default="dinkelbach",
                    choices=["dinkelbach", "analytic"],
                    help="dinkelbach (paper Algorithm 1, shows the "
                         "warm-start iteration drop) or the closed-form "
                         "analytic fast path")
    ap.add_argument("--open-loop", action="store_true",
                    help="arrival-driven mode: AOT warmup + seeded "
                         "Poisson trace with deadlines")
    ap.add_argument("--load", type=float, default=0.7,
                    help="open-loop offered rate as a fraction of the "
                         "measured capacity")
    ap.add_argument("--requests", type=int, default=120,
                    help="open-loop trace length")
    ap.add_argument("--seed", type=int, default=1,
                    help="open-loop arrival trace seed")
    args = ap.parse_args(argv)

    cells = [make_problem("drifting_metro", seed=s, n_devices=args.devices,
                          n_rounds=args.rounds, coherence=args.coherence)
             for s in range(args.cells)]
    print(f"fleet control plane: {args.cells} cells x {args.devices} "
          f"devices, {args.rounds} rounds, coherence {args.coherence}")

    if args.open_loop:
        return run_open_loop(cells, args)

    results = {}
    for label, warm in (("warm", True), ("cold", False)):
        svc = FleetControlService(ServiceConfig(
            max_batch=args.max_batch, power_solver=args.power_solver,
            warm_start=warm))
        stats = stream_rounds(svc, cells, args.rounds)
        s = stats.summary()
        results[label] = s
        print(f"[{label:4s}] {s['solves_per_sec']:8.1f} solves/s   "
              f"p50 {s['p50_latency_s'] * 1e3:7.2f} ms   "
              f"p99 {s['p99_latency_s'] * 1e3:7.2f} ms   "
              f"warm {s['warm_fraction']:.0%}   "
              f"inner iters/batch {s['mean_inner_iters']:.1f}")

    if args.power_solver == "dinkelbach":
        ratio = (results["cold"]["mean_inner_iters"]
                 / max(results["warm"]["mean_inner_iters"], 1e-9))
        print(f"warm start cuts Algorithm-1 iterations "
              f"{ratio:.1f}x on this channel")
    return results


if __name__ == "__main__":
    main()
