"""Metro demo: one coupled multi-cell control tick (``core.multicell``).

Builds a 16-cell ``metro_coupled`` metro — per-cell paper problems on a
square grid with inter-cell interference and one shared backhaul link —
and solves a coupled control tick through ``FleetControlService``:
dual-decomposition outer loop, one fused union solve per iteration.
Prints per-cell expected participation coupled vs uncoupled, the
backhaul price / load, and the warm-dual effect of a second tick.

    PYTHONPATH=src python examples/metro_demo.py
    PYTHONPATH=src python examples/metro_demo.py \
        --cells 8 --devices 32 --no-budget
"""
import argparse

import numpy as np

from repro.core import solve_joint_batch
from repro.core.scenarios import make_problem
from repro.serve import FleetControlService, ServiceConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=16, help="cells in the metro")
    ap.add_argument("--devices", type=int, default=64,
                    help="devices per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-budget", action="store_true",
                    help="drop the shared backhaul budget "
                         "(interference coupling only)")
    args = ap.parse_args(argv)

    kw = {"backhaul_fraction": None} if args.no_budget else {}
    metro = make_problem("metro_coupled", seed=args.seed,
                         n_cells=args.cells, n_devices=args.devices, **kw)
    uncoupled = solve_joint_batch(metro.cells, method="fused")

    svc = FleetControlService(ServiceConfig())
    tick = svc.solve_coupled("metro-demo", metro)
    sol = tick.solution

    print(f"metro_coupled: C={args.cells} cells x N={args.devices} devices, "
          f"seed={args.seed}")
    print(f"outer loop: {sol.outer_iters} iterations, "
          f"residual={sol.residual:.2e}, converged={sol.converged}")
    if metro.backhaul_bits is not None:
        load = float(np.max(np.atleast_1d(np.asarray(sol.backhaul_load))))
        mu = float(np.max(np.atleast_1d(np.asarray(sol.mu))))
        print(f"backhaul: load/budget={load / metro.backhaul_bits:.4f}, "
              f"price mu={mu:.3e}")
    else:
        print("backhaul: no shared budget (interference coupling only)")

    a_c = np.asarray(sol.batch.a)[:args.cells, :args.devices]
    a_u = np.asarray(uncoupled.a)
    print(f"\n{'cell':>4} {'uncoupled':>10} {'coupled':>10} {'delta':>8}   "
          f"interference (W)")
    for c in range(args.cells):
        i_c = float(np.max(np.atleast_1d(sol.interference[c])))
        print(f"{c:>4} {a_u[c].sum():>10.3f} {a_c[c].sum():>10.3f} "
              f"{a_c[c].sum() - a_u[c].sum():>8.3f}   {i_c:.3e}")
    print(f"{'sum':>4} {a_u.sum():>10.3f} {a_c.sum():>10.3f} "
          f"{a_c.sum() - a_u.sum():>8.3f}")

    tick2 = svc.solve_coupled("metro-demo", metro)
    print(f"\nwarm tick: {tick2.solution.outer_iters} outer iteration(s) "
          f"(cold: {sol.outer_iters}), "
          f"warm_started={tick2.warm_started}, "
          f"latency {tick2.latency_s * 1e3:.1f} ms "
          f"(cold: {tick.latency_s * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
