"""Quickstart: solve the paper's joint selection/power problem and run a
short federated training with it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (ProbabilisticScheduler, sample_problem,
                        solve_joint_optimal, solve_joint_trace)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_mnist_like
from repro.fl.engine import FLConfig, run_fl


def main():
    # --- 1. the wireless scenario (paper Sec. V-A) -----------------------
    problem = sample_problem(0, n_devices=100, tau_th=0.08)

    # --- 2. Algorithm 2: alternating closed-form solve -------------------
    sol, trace = solve_joint_trace(problem)
    print("Algorithm 2 objective trace:", [f"{t:.5f}" for t in trace])
    print(f"expected participants/round: {float(sol.a.sum()):.2f}")

    # --- 3. beyond-paper: exact bisection optimum -------------------------
    opt = solve_joint_optimal(problem)
    gain = float(opt.objective) / max(float(sol.objective), 1e-12) - 1
    print(f"global-optimal solver objective: +{gain:.1%} vs Algorithm 2")

    # --- 4. short FL run with probabilistic participation ------------------
    train, test = make_mnist_like(4000, 800, seed=0)
    parts = dirichlet_partition(train, 100, beta=0.3, seed=1)
    problem = sample_problem(
        2, 100, tau_th=0.5,
        dirichlet_sizes=np.array([len(p) for p in parts]))
    cfg = FLConfig(n_rounds=100, eval_every=25, lr=0.1, batch_per_client=8)
    res = run_fl(problem, ProbabilisticScheduler(), train, parts, test, cfg)
    h = res.history
    print(f"FL: acc={h.eval_acc[-1]:.3f} after {h.sim_time[-1]:.0f}s "
          f"simulated, {h.energy[-1]:.0f} J consumed, "
          f"{h.participants.mean():.1f} participants/round")


if __name__ == "__main__":
    main()
