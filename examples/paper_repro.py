"""End-to-end reproduction driver: the paper's two scenarios, four
selection strategies, accuracy-vs-time curves and Tables I-IV analogues.

    PYTHONPATH=src python examples/paper_repro.py            # full (slow)
    PYTHONPATH=src python examples/paper_repro.py --fast     # reduced
"""
import argparse
import dataclasses
import json
from pathlib import Path

from repro.fl.experiments import (HIGH_BIAS, MILD_BIAS, format_tables,
                                  run_scenario)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="experiments/paper_repro")
    args = ap.parse_args()

    scenarios = [HIGH_BIAS, MILD_BIAS]
    if args.fast:
        scenarios = [dataclasses.replace(
            s, n_rounds=120, n_runs=1, n_train=4000, n_test=800,
            n_devices=50) for s in scenarios]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    for spec in scenarios:
        print(f"\n### scenario: {spec.name} (beta={spec.beta}, "
              f"tau={spec.tau_th}s) ###")
        result = run_scenario(spec)
        (out_dir / f"{spec.name}.json").write_text(json.dumps(result, indent=1))
        print(format_tables(result, spec))


if __name__ == "__main__":
    main()
