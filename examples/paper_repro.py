"""End-to-end reproduction driver: the paper's two scenarios, four
selection strategies, accuracy-vs-time curves and Tables I-IV analogues.

    PYTHONPATH=src python examples/paper_repro.py            # full (slow)
    PYTHONPATH=src python examples/paper_repro.py --fast     # reduced
    PYTHONPATH=src python examples/paper_repro.py --engine scan
        # whole (seed x strategy x scenario) grid as one fused sweep call

``--engine scan`` routes through ``repro.fl.scan_engine``: each
trajectory is one ``lax.scan``, vmapped across the grid and sharded over
the local device mesh (see docs/experiments.md).
"""
import argparse
import dataclasses
import json
from pathlib import Path

from repro.fl.experiments import (HIGH_BIAS, MILD_BIAS, format_tables,
                                  run_grid)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--engine", choices=("loop", "scan"), default="loop",
                    help="'loop' = reference per-run engine; 'scan' = "
                         "scan-fused vmapped sweep (one jitted call)")
    ap.add_argument("--out", default="experiments/paper_repro")
    args = ap.parse_args()

    scenarios = [HIGH_BIAS, MILD_BIAS]
    if args.fast:
        scenarios = [dataclasses.replace(
            s, n_rounds=120, n_runs=1, n_train=4000, n_test=800,
            n_devices=50) for s in scenarios]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    results = run_grid(scenarios, engine=args.engine)
    for spec in scenarios:
        print(f"\n### scenario: {spec.name} (beta={spec.beta}, "
              f"tau={spec.tau_th}s, engine={args.engine}) ###")
        result = results[spec.name]
        (out_dir / f"{spec.name}.json").write_text(json.dumps(result, indent=1))
        print(format_tables(result, spec))


if __name__ == "__main__":
    main()
