"""Beyond-paper study: quantised uplink x joint selection/power.

The paper treats the gradient payload S as fixed (fp32).  Compressing the
uplink to b bits shrinks S by 32/b, which *relaxes the time constraint
(7c)* — the solver returns strictly higher selection probabilities, more
expected participants per round, and (up to quantisation noise) faster
convergence per simulated second.  This couples the paper's two worlds:
the wireless optimisation and the learning dynamics.

    PYTHONPATH=src python examples/compression_study.py
"""
import json
from pathlib import Path

import numpy as np

from repro.core import (GRAD_SIZE_BITS_FP32, ProbabilisticScheduler,
                        sample_problem)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_mnist_like
from repro.fl.engine import FLConfig, run_fl

BITS = [32, 8, 4]
# the fp32 payload every sampled problem carries (core.problem's default);
# an earlier copy of this constant had drifted to 199_213 params
BASE_S = GRAD_SIZE_BITS_FP32


def main():
    train, test = make_mnist_like(6000, 1000, seed=0)
    parts = dirichlet_partition(train, 100, beta=0.3, seed=1)
    sizes = np.array([len(p) for p in parts])

    results = {}
    for bits in BITS:
        prob = sample_problem(2, 100, tau_th=0.08,
                              grad_size_bits=BASE_S * bits / 32,
                              dirichlet_sizes=sizes)
        sch = ProbabilisticScheduler(solver="optimal")
        state = sch.precompute(prob)
        exp_parts = float(np.asarray(state.a).sum())
        cfg = FLConfig(n_rounds=150, eval_every=30, batch_per_client=8,
                       lr=0.1, aggregate="stacked",
                       uplink_bits=None if bits == 32 else bits, seed=3)
        res = run_fl(prob, sch, train, parts, test, cfg)
        h = res.history
        results[bits] = {
            "expected_participants": exp_parts,
            "objective": float(state.a @ np.asarray(prob.weights)),
            "final_acc": float(h.eval_acc[-1]),
            "time_to_final": float(h.sim_time[-1]),
            "energy": float(h.energy[-1]),
            "acc_curve": h.eval_acc.tolist(),
            "time_curve": h.eval_time.tolist(),
        }
        print(f"bits={bits:2d}: E[parts]={exp_parts:6.2f} "
              f"final_acc={h.eval_acc[-1]:.3f} "
              f"sim_time={h.sim_time[-1]:8.0f}s energy={h.energy[-1]:7.0f}J")

    out = Path("experiments/compression_study.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1))
    print(f"\nwritten to {out}")


if __name__ == "__main__":
    main()
