"""Static-analysis driver (CI ``analysis`` job).

Runs the four jaxpr-level passes and emits one JSON report:

* **recompile** — measure every registered hot path
  (``repro.analysis.hotpaths``) and compare steady-state compile counts
  against the committed ``analysis/budgets.json``;
* **prng** — every registered production program must show zero
  key-reuse findings;
* **rank** — the exhaustive [N]/[N,K] broadcast sweep over
  ``WirelessFLProblem`` must be clean;
* **hygiene** — host-sync / donation / weak-type audits must be clean.

Usage::

    PYTHONPATH=src python tools/run_analysis.py            # report only
    PYTHONPATH=src python tools/run_analysis.py --gate     # exit 1 on red
    PYTHONPATH=src python tools/run_analysis.py --json out.json

``--only recompile,prng`` restricts the run (handy while iterating on a
single pass).  The report is written to ``--json`` (default
``analysis/report.json``, uploaded as a CI artifact) and summarised on
stdout either way.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

PASSES = ("recompile", "prng", "rank", "hygiene")


def run_recompile() -> dict:
    from repro.analysis.hotpaths import load_budgets, measure_all

    measured = measure_all()
    budgets = load_budgets()
    failures = []
    for name, budget in sorted(budgets.items()):
        if name not in measured:
            failures.append(f"budgeted hot path {name!r} is not registered")
            continue
        got = measured[name]["steady_compiles"]
        if got > budget:
            failures.append(
                f"{name}: {got} steady-state compile(s), budget {budget}; "
                f"programs: {measured[name]['steady_programs']}")
    for name in sorted(set(measured) - set(budgets)):
        failures.append(f"hot path {name!r} has no entry in "
                        "analysis/budgets.json")
    return {"ok": not failures, "failures": failures, "measured": measured,
            "budgets": budgets}


def run_prng() -> dict:
    from repro.analysis.prng import PRNG_PROGRAMS

    findings = {}
    for name, prog in sorted(PRNG_PROGRAMS.items()):
        findings[name] = [str(f) for f in prog()]
    failures = [f"{name}: {fs}" for name, fs in findings.items() if fs]
    return {"ok": not failures, "failures": failures, "findings": findings}


def run_rank() -> dict:
    from repro.analysis.rank import sweep_rank_contract

    findings, stats = sweep_rank_contract()
    return {"ok": not findings, "failures": [str(f) for f in findings],
            "stats": stats}


def run_hygiene() -> dict:
    from repro.analysis.hygiene import run_hygiene as _run

    report = _run()
    return {"ok": report["n_findings"] == 0,
            "failures": report["findings"], "stats": {
                k: report[k] for k in ("host_sync", "donation", "weak_type")}}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 when any pass is red")
    ap.add_argument("--json", type=Path,
                    default=REPO / "analysis" / "report.json",
                    help="report path (default analysis/report.json)")
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {','.join(PASSES)}")
    args = ap.parse_args(argv)

    selected = PASSES if args.only is None else tuple(
        p.strip() for p in args.only.split(","))
    unknown = set(selected) - set(PASSES)
    if unknown:
        ap.error(f"unknown pass(es): {sorted(unknown)}")

    runners = {"recompile": run_recompile, "prng": run_prng,
               "rank": run_rank, "hygiene": run_hygiene}
    report: dict = {"passes": {}}
    red = []
    for name in selected:
        print(f"== {name} ==", flush=True)
        result = runners[name]()
        report["passes"][name] = result
        status = "ok" if result["ok"] else "RED"
        print(f"   {status}" + (
            "" if result["ok"] else
            "".join(f"\n   - {f}" for f in result["failures"])), flush=True)
        if not result["ok"]:
            red.append(name)
    report["ok"] = not red

    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    print(f"report -> {args.json}")

    if red:
        print(f"analysis gate RED: {', '.join(red)}")
        return 1 if args.gate else 0
    print("analysis gate green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
