"""Docs drift gate (CI lint job): fail when the documentation rots.

Three checks, all cheap enough for every PR:

* **links** — every relative markdown link in ``README.md`` and
  ``docs/*.md`` resolves to a file in the repo (anchors are stripped;
  ``http(s)``/``mailto`` targets are skipped — CI must not depend on
  external hosts being up);
* **code fences** — every ``python``-tagged fence in ``docs/*.md``
  compiles, and its import statements execute against the installed
  tree, so documented entry points cannot silently disappear;
* **scenario coverage** — every name in the ``repro.core.scenarios``
  registry appears in ``docs/scenarios.md``.

Usage::

    PYTHONPATH=src python tools/check_docs.py

Exit code 0 = green, 1 = drift found.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — target up to the first closing paren or whitespace;
# images (![alt](...)) match the same way and are checked the same way
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _md_files() -> list[Path]:
    return [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]


def check_links(problems: list[str]) -> int:
    n = 0
    for md in _md_files():
        for m in _LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:           # pure in-page anchor
                continue
            n += 1
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                problems.append(f"{md.relative_to(REPO)}: broken link "
                                f"-> {target}")
    return n


def check_code_fences(problems: list[str]) -> int:
    n = 0
    for md in sorted((REPO / "docs").glob("*.md")):
        for i, m in enumerate(_FENCE_RE.finditer(md.read_text()), 1):
            code, where = m.group(1), f"{md.relative_to(REPO)} fence #{i}"
            n += 1
            try:
                tree = ast.parse(code, where)
            except SyntaxError as e:
                problems.append(f"{where}: syntax error: {e}")
                continue
            imports = ast.Module(
                body=[node for node in tree.body
                      if isinstance(node, (ast.Import, ast.ImportFrom))],
                type_ignores=[])
            try:
                exec(compile(imports, where, "exec"), {})  # noqa: S102
            except Exception as e:
                problems.append(f"{where}: import failed: {e!r}")
    return n


def check_scenarios(problems: list[str]) -> int:
    from repro.core.scenarios import SCENARIOS
    text = (REPO / "docs" / "scenarios.md").read_text()
    for name in sorted(SCENARIOS):
        if name not in text:
            problems.append(f"docs/scenarios.md: registry scenario "
                            f"{name!r} is undocumented")
    return len(SCENARIOS)


def main() -> int:
    problems: list[str] = []
    n_links = check_links(problems)
    n_fences = check_code_fences(problems)
    n_scen = check_scenarios(problems)
    if problems:
        print(f"DOCS GATE FAILED ({len(problems)} problem(s)):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"docs gate OK ({n_links} links, {n_fences} python fences, "
          f"{n_scen} scenarios)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
